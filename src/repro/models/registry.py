"""Model registry: one uniform API over every architecture family.

``build_model(cfg)`` returns a :class:`Model` whose members are pure
functions suitable for ``jax.jit``/``jax.eval_shape``:

  loss_fn(params, batch)              → (scalar loss, metrics)   [train]
  prefill_fn(params, batch)           → (last logits, cache)     [prefill]
  decode_fn(params, cache, tok, pos)  → (logits, new cache)      [decode]

plus declarative metadata: ParamDef tree, cache ShapeDtypeStructs, logical
axis trees for params/batch/cache (resolved to meshes by repro.parallel).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv6 as rwkv_mod
from . import transformer as tx
from . import whisper as wh
from .common import ParamDef, abstract_params, init_params
from .config import ArchConfig

VOCAB_PAD = 512  # pad embeddings so the vocab dim shards cleanly (Megatron idiom)


def padded_vocab(cfg: ArchConfig) -> int:
    v = cfg.vocab_size
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    param_defs: Any
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable | None
    cache_defs_fn: Callable  # (batch, max_seq) -> ShapeDtypeStruct tree
    cache_logical_fn: Callable  # (cfg) -> logical tree

    def init(self, rng):
        return init_params(self.param_defs, rng)

    def abstract_params(self):
        return abstract_params(self.param_defs)

    def param_logical(self):
        return jax.tree_util.tree_map(
            lambda d: d.logical, self.param_defs,
            is_leaf=lambda x: isinstance(x, ParamDef),
        )

    # ---------------- input specs (ShapeDtypeStructs; no allocation) --------

    def train_inputs(self, batch: int, seq: int) -> dict:
        cfg = self.cfg
        i32 = jnp.int32
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct(
                    (batch, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype)
                ),
                "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            }
        if cfg.n_patches:
            text = seq - cfg.n_patches
            return {
                "patches": jax.ShapeDtypeStruct(
                    (batch, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype)
                ),
                "tokens": jax.ShapeDtypeStruct((batch, text), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }

    def train_input_logical(self) -> dict:
        cfg = self.cfg
        out = {"tokens": ("batch", None), "labels": ("batch", None)}
        if cfg.family == "encdec":
            out["frames"] = ("batch", None, None)
        if cfg.n_patches:
            out["patches"] = ("batch", None, None)
        return out

    def prefill_inputs(self, batch: int, seq: int) -> dict:
        specs = self.train_inputs(batch, seq)
        specs.pop("labels")
        return specs

    def prefill_input_logical(self) -> dict:
        out = self.train_input_logical()
        out.pop("labels")
        return out

    def decode_inputs(self, batch: int) -> dict:
        return {
            "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }


def build_model(cfg: ArchConfig) -> Model:
    # all families embed/unembed against the padded vocab
    cfg = cfg.replace() if cfg.vocab_size == padded_vocab(cfg) else cfg
    pcfg = cfg.replace(vocab_size=padded_vocab(cfg))

    if cfg.family == "dense":
        return Model(
            cfg=pcfg,
            param_defs=tx.dense_param_defs(pcfg),
            loss_fn=lambda p, b: tx.dense_loss(p, pcfg, b),
            prefill_fn=lambda p, b: tx.dense_prefill(
                p, pcfg, b["tokens"], patches=b.get("patches")
            ),
            decode_fn=lambda p, c, t, pos: tx.dense_decode_step(p, pcfg, c, t, pos),
            cache_defs_fn=lambda batch, seq: tx.dense_cache_defs(pcfg, batch, seq),
            cache_logical_fn=lambda: tx.cache_logical(pcfg),
        )
    if cfg.family == "moe":
        return Model(
            cfg=pcfg,
            param_defs=moe_mod.moe_param_defs(pcfg),
            loss_fn=lambda p, b: moe_mod.moe_loss(p, pcfg, b),
            prefill_fn=lambda p, b: moe_mod.moe_prefill(p, pcfg, b["tokens"]),
            decode_fn=lambda p, c, t, pos: moe_mod.moe_decode_step(p, pcfg, c, t, pos),
            cache_defs_fn=lambda batch, seq: moe_mod.moe_cache_defs(pcfg, batch, seq),
            cache_logical_fn=lambda: moe_mod.moe_cache_logical(pcfg),
        )
    if cfg.family == "rwkv6":
        return Model(
            cfg=pcfg,
            param_defs=rwkv_mod.rwkv_param_defs(pcfg),
            loss_fn=lambda p, b: rwkv_mod.rwkv_loss(p, pcfg, b),
            prefill_fn=lambda p, b: _rwkv_prefill(p, pcfg, b),
            decode_fn=lambda p, c, t, pos: rwkv_mod.rwkv_decode_step(p, pcfg, c, t, pos),
            cache_defs_fn=lambda batch, seq: rwkv_mod.rwkv_cache_defs(pcfg, batch, seq),
            cache_logical_fn=lambda: rwkv_mod.rwkv_cache_logical(pcfg),
        )
    if cfg.family == "rglru":
        return Model(
            cfg=pcfg,
            param_defs=rglru_mod.griffin_param_defs(pcfg),
            loss_fn=lambda p, b: rglru_mod.griffin_loss(p, pcfg, b),
            prefill_fn=lambda p, b: rglru_mod.griffin_prefill(p, pcfg, b["tokens"]),
            decode_fn=lambda p, c, t, pos: rglru_mod.griffin_decode_step(p, pcfg, c, t, pos),
            cache_defs_fn=lambda batch, seq: rglru_mod.griffin_cache_defs(pcfg, batch, seq),
            cache_logical_fn=lambda: rglru_mod.griffin_cache_logical(pcfg),
        )
    if cfg.family == "encdec":
        return Model(
            cfg=pcfg,
            param_defs=wh.whisper_param_defs(pcfg),
            loss_fn=lambda p, b: wh.whisper_loss(p, pcfg, b),
            prefill_fn=lambda p, b: wh.whisper_prefill(p, pcfg, b["frames"], b["tokens"]),
            decode_fn=lambda p, c, t, pos: wh.whisper_decode_step(p, pcfg, c, t, pos),
            cache_defs_fn=lambda batch, seq: wh.whisper_cache_defs(pcfg, batch, seq),
            cache_logical_fn=lambda: wh.whisper_cache_logical(pcfg),
        )
    raise ValueError(f"unknown family {cfg.family!r}")


def _rwkv_prefill(params, cfg, batch):
    logits, caches = rwkv_mod.rwkv_forward(params, cfg, batch["tokens"], collect_cache=True)
    return logits[:, -1:], caches
