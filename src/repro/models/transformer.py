"""Dense decoder-only transformer family.

Covers codeqwen1.5-7b, starcoder2-7b, mistral-large-123b (GQA), minicpm3-4b
(MLA — multi-head latent attention with a compressed KV cache and the
absorbed-matmul decode path) and llava-next-mistral-7b (visual-prefix stub).

Layout conventions
------------------
* Per-layer weights are stacked on a leading ``layers`` axis and executed via
  ``lax.scan`` (+ optional ``jax.checkpoint``) — HLO size is depth-independent.
* Projection weights are shaped (D, H, hd) so tensor parallelism is a logical
  axis annotation on the ``heads`` dim.
* KV caches are laid out (L, B, Hkv, S, hd) with the *sequence* dim sharded
  over the ``model`` axis at decode time (flash-decoding split-KV; see
  DESIGN.md) — mandatory for 32k×128 caches on 16 GB chips.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (
    ParamDef,
    apply_rope,
    attention_chunked,
    attention_single_shot,
    causal_mask,
    cross_entropy,
    rms_norm,
    shard,
    swiglu,
)
from .config import ArchConfig

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def _stack(n, d: ParamDef) -> ParamDef:
    return ParamDef(
        shape=(n, *d.shape),
        logical=("layers", *d.logical),
        dtype=d.dtype,
        init=d.init,
        scale=d.scale,
    )


def attn_defs(cfg: ArchConfig, pdt) -> dict:
    D, H, K = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        return {
            "wdq": ParamDef((D, cfg.q_lora_rank), ("embed", None), pdt),
            "q_ln": ParamDef((cfg.q_lora_rank,), (None,), pdt, "ones"),
            "wuq": ParamDef((cfg.q_lora_rank, H, qk), (None, "heads", None), pdt),
            "wdkv": ParamDef((D, cfg.kv_lora_rank), ("embed", None), pdt),
            "kv_ln": ParamDef((cfg.kv_lora_rank,), (None,), pdt, "ones"),
            "wukv": ParamDef(
                (cfg.kv_lora_rank, H, cfg.qk_nope_dim + cfg.v_head_dim),
                (None, "heads", None), pdt,
            ),
            "wkr": ParamDef((D, cfg.qk_rope_dim), ("embed", None), pdt),
            "wo": ParamDef((H, cfg.v_head_dim, D), ("heads", None, "embed"), pdt),
        }
    return {
        "wq": ParamDef((D, H, hd), ("embed", "heads", None), pdt),
        "wk": ParamDef((D, K, hd), ("embed", "kv_heads", None), pdt),
        "wv": ParamDef((D, K, hd), ("embed", "kv_heads", None), pdt),
        "wo": ParamDef((H, hd, D), ("heads", None, "embed"), pdt),
    }


def mlp_defs(cfg: ArchConfig, pdt, d_ff=None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wg": ParamDef((D, F), ("embed", "ff"), pdt),
        "wi": ParamDef((D, F), ("embed", "ff"), pdt),
        "wo": ParamDef((F, D), ("ff", "embed"), pdt),
    }


def block_defs(cfg: ArchConfig, pdt) -> dict:
    D = cfg.d_model
    return {
        "ln1": ParamDef((D,), (None,), pdt, "ones"),
        "attn": attn_defs(cfg, pdt),
        "ln2": ParamDef((D,), (None,), pdt, "ones"),
        "mlp": mlp_defs(cfg, pdt),
    }


def dense_param_defs(cfg: ArchConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), pdt),
        "blocks": jax.tree_util.tree_map(
            lambda d: _stack(L, d), block_defs(cfg, pdt), is_leaf=lambda x: isinstance(x, ParamDef)
        ),
        "final_ln": ParamDef((D,), (None,), pdt, "ones"),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((D, V), ("embed", "vocab"), pdt)
    return defs


# ---------------------------------------------------------------------------
# Attention (full-sequence / training path)
# ---------------------------------------------------------------------------


def gqa_attention(p, x, cfg: ArchConfig, positions, collect: bool = False):
    dt = jnp.dtype(cfg.dtype)
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(dt))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", "kv_heads", None, None)
    v = shard(v, "batch", "kv_heads", None, None)
    k_compact, v_compact = k, v  # cache keeps the Hkv layout
    if cfg.use_pallas:
        # Pallas flash kernel handles GQA in its index map (no KV expansion).
        from repro.kernels import ops as kops

        out = kops.attention(
            q, k, v,
            causal=True,
            window=cfg.window if cfg.attention == "local" else 0,
            logit_cap=cfg.logit_cap,
            kv_chunk=cfg.attn_chunk,
            use_pallas=True,
        )
    else:
        # Expand KV heads to Hq for the full-sequence path: with few KV heads
        # (e.g. 8 on a 16-way model axis) the grouped (Hkv, G) reshape would
        # not shard — the expanded Hq dim does. The decode path keeps the
        # grouped form and shards the KV *sequence* dim instead.
        G = cfg.n_heads // cfg.n_kv_heads
        if G > 1:
            k = jnp.repeat(k, G, axis=1)
            v = jnp.repeat(v, G, axis=1)
            k = shard(k, "batch", "heads", None, None)
            v = shard(v, "batch", "heads", None, None)
        out = attention_chunked(
            q, k, v,
            causal=True,
            window=cfg.window if cfg.attention == "local" else 0,
            kv_chunk=cfg.attn_chunk,
            logit_cap=cfg.logit_cap,
        )
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(dt))
    if collect:
        return y, {"k": k_compact, "v": v_compact}
    return y


def mla_attention(p, x, cfg: ArchConfig, positions, collect: bool = False):
    """Training-path MLA: expand latent projections to per-head q/k/v."""
    dt = jnp.dtype(cfg.dtype)
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"].astype(dt)), p["q_ln"])
    q = jnp.einsum("bsr,rhk->bhsk", cq, p["wuq"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(dt)), p["kv_ln"])
    kv = jnp.einsum("bsr,rhk->bhsk", ckv, p["wukv"].astype(dt))
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["wkr"].astype(dt))[:, None]  # shared head
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:-1], rope))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", "heads", None, None)
    out = attention_chunked(q, k, v, causal=True, kv_chunk=cfg.attn_chunk)
    y = jnp.einsum("bhsv,hvd->bsd", out, p["wo"].astype(dt))
    if collect:
        # compressed MLA cache: the latent ckv + shared roped k_rope
        return y, {"ckv": ckv, "krope": k_rope[:, 0]}
    return y


def dense_block(p, x, cfg: ArchConfig, positions):
    # Residual-stream constraint: ("batch", "seq", None). With seq_shard ON
    # (sequence parallelism) the "seq" rule maps to the model axis — norms
    # and residual elementwise run 1/TP-sized, and GSPMD turns each TP
    # region's all-reduce into reduce-scatter + all-gather (Megatron-SP).
    attn_fn = mla_attention if cfg.attention == "mla" else gqa_attention
    x = x + attn_fn(p["attn"], rms_norm(x, p["ln1"]), cfg, positions)
    x = shard(x, "batch", "seq", None)
    dt = jnp.dtype(cfg.dtype)
    m = p["mlp"]
    x = x + swiglu(rms_norm(x, p["ln2"]), m["wg"], m["wi"], m["wo"], dt)
    return shard(x, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Layer-stack execution (shared across families)
# ---------------------------------------------------------------------------


def remat_wrap(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def run_stack(blocks, x, cfg: ArchConfig, apply_block):
    """scan the layer stack (or unroll when cfg.use_scan=False)."""

    def body(h, layer_params):
        return apply_block(layer_params, h), None

    body = remat_wrap(body, cfg)
    if cfg.use_scan:
        x, _ = jax.lax.scan(body, x, blocks)
        return x
    n = jax.tree_util.tree_leaves(blocks)[0].shape[0]
    for i in range(n):
        layer = jax.tree_util.tree_map(lambda a: a[i], blocks)
        x, _ = body(x, layer)
    return x


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg: ArchConfig, tokens):
    dt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    return shard(h, "batch", "seq", None)


def unembed(params, cfg: ArchConfig, h):
    dt = jnp.dtype(cfg.dtype)
    table = (
        params["embed"].astype(dt).T
        if cfg.tie_embeddings
        else params["unembed"].astype(dt)
    )
    logits = jnp.einsum("bsd,dv->bsv", h, table)
    return shard(logits, "batch", None, "vocab")


def dense_forward(params, cfg: ArchConfig, tokens, patches=None):
    """tokens: (B, S_text) int32; patches: (B, P, D) visual-prefix stub."""
    h = embed_tokens(params, cfg, tokens)
    if patches is not None:
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)
        h = shard(h, "batch", None, None)
    S = h.shape[1]
    positions = jnp.arange(S)
    h = run_stack(
        params["blocks"], h, cfg, lambda p, y: dense_block(p, y, cfg, positions)
    )
    h = rms_norm(h, params["final_ln"])
    return unembed(params, cfg, h)


def dense_loss(params, cfg: ArchConfig, batch):
    logits = dense_forward(
        params, cfg, batch["tokens"], patches=batch.get("patches")
    )
    loss, metrics = cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
    return loss, metrics


def dense_prefill(params, cfg: ArchConfig, tokens, patches=None):
    """Inference prefill: full-sequence forward that also materialises the
    per-layer KV cache (compressed latent cache for MLA). Returns
    (last-position logits, cache)."""
    h = embed_tokens(params, cfg, tokens)
    if patches is not None:
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)
        h = shard(h, "batch", None, None)
    S = h.shape[1]
    positions = jnp.arange(S)
    attn_fn = mla_attention if cfg.attention == "mla" else gqa_attention

    def body(h, p):
        y, kv = attn_fn(p["attn"], rms_norm(h, p["ln1"]), cfg, positions, collect=True)
        h = h + y
        m = p["mlp"]
        dt = jnp.dtype(cfg.dtype)
        h = h + swiglu(rms_norm(h, p["ln2"]), m["wg"], m["wi"], m["wo"], dt)
        return h, kv

    h, cache = jax.lax.scan(remat_wrap(body, cfg), h, params["blocks"])
    h = rms_norm(h[:, -1:], params["final_ln"])
    return unembed(params, cfg, h), cache


# ---------------------------------------------------------------------------
# Decoding (KV cache; GQA standard path + MLA compressed-latent path)
# ---------------------------------------------------------------------------


def dense_cache_defs(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    """Abstract cache layout for (de)serialisation and the dry-run."""
    L, K = cfg.n_layers, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    if cfg.attention == "mla":
        return {
            "ckv": jax.ShapeDtypeStruct((L, batch, max_seq, cfg.kv_lora_rank), dt),
            "krope": jax.ShapeDtypeStruct((L, batch, max_seq, cfg.qk_rope_dim), dt),
        }
    return {
        "k": jax.ShapeDtypeStruct((L, batch, K, max_seq, hd), dt),
        "v": jax.ShapeDtypeStruct((L, batch, K, max_seq, hd), dt),
    }


def cache_logical(cfg: ArchConfig) -> dict:
    """Logical axes for each cache leaf (sequence sharded over `model`)."""
    if cfg.attention == "mla":
        return {
            "ckv": ("layers", "batch", "kv_seq", None),
            "krope": ("layers", "batch", "kv_seq", None),
        }
    return {
        "k": ("layers", "batch", None, "kv_seq", None),
        "v": ("layers", "batch", None, "kv_seq", None),
    }


def scatter_seq(buf, update, pos):
    """Write `update` (..., 1, d) into `buf` (..., S, d) at index `pos`.

    One-hot multiply-add instead of dynamic_update_slice: elementwise →
    GSPMD-shardable when S is sharded over the `model` axis.

    ``pos`` may be a scalar (whole batch at one position) or a (B,) vector
    (continuous batching: every slot at its own depth); vector positions
    assume ``buf``'s leading dim is the batch.
    """
    S = buf.shape[-2]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        onehot = (jnp.arange(S) == pos).astype(buf.dtype)[..., None]  # (S,1)
    else:
        B = buf.shape[0]
        oh = (jnp.arange(S)[None, :] == pos[:, None]).astype(buf.dtype)  # (B,S)
        onehot = oh.reshape((B,) + (1,) * (buf.ndim - 3) + (S, 1))
    return buf * (1 - onehot) + update.astype(buf.dtype) * onehot


def _pos_rope(pos, batch: int):
    """Positions for RoPE at decode: scalar → (1,); vector → (B,1,1) so the
    angle tensor broadcasts against (B, H, 1, dh/2)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jnp.full((1,), pos)
    return jnp.broadcast_to(pos, (batch,))[:, None, None]


def _pos_mask(pos, batch: int, skv: int):
    """(B,1,1,1,S) causal mask rows for scalar or per-row positions."""
    pos = jnp.asarray(pos)
    pos_b = jnp.broadcast_to(pos, (batch,)) if pos.ndim else jnp.full((batch,), pos)
    return jnp.arange(skv)[None, None, None, None, :] <= pos_b[:, None, None, None, None]


def gqa_decode_attn(p, layer_cache, x, cfg: ArchConfig, pos):
    """One-token attention against the cache; ``pos`` scalar or (B,)."""
    dt = jnp.dtype(cfg.dtype)
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(dt))
    k_new = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(dt))
    v_new = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(dt))
    positions = _pos_rope(pos, B)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    k = scatter_seq(layer_cache["k"], k_new, pos)
    v = scatter_seq(layer_cache["v"], v_new, pos)
    k = shard(k, "batch", None, "kv_seq", None)
    v = shard(v, "batch", None, "kv_seq", None)
    S = k.shape[-2]
    mask = _pos_mask(pos, B, S)
    if cfg.attention == "local" and cfg.window > 0:
        low = _pos_mask(jnp.asarray(pos) - cfg.window, B, S)
        mask &= ~low  # k_pos > pos - window
    out = attention_single_shot(q, k, v, mask=mask, logit_cap=cfg.logit_cap)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(dt))
    return y, {"k": k, "v": v}


def mla_decode_attn(p, layer_cache, x, cfg: ArchConfig, pos):
    """Absorbed-matmul MLA decode over the compressed (ckv, k_rope) cache.

    ``pos`` scalar or (B,) (continuous batching)."""
    dt = jnp.dtype(cfg.dtype)
    B = x.shape[0]
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    positions = _pos_rope(pos, B)
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"].astype(dt)), p["q_ln"])
    q = jnp.einsum("bsr,rhk->bhsk", cq, p["wuq"].astype(dt))
    q_nope, q_rope = q[..., :nope], apply_rope(q[..., nope:], positions, cfg.rope_theta)
    ckv_new = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(dt)), p["kv_ln"])
    krope_new = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["wkr"].astype(dt))[:, None], positions,
        cfg.rope_theta,
    )[:, 0]
    ckv = scatter_seq(layer_cache["ckv"], ckv_new, pos)
    krope = scatter_seq(layer_cache["krope"], krope_new, pos)
    ckv = shard(ckv, "batch", "kv_seq", None)
    krope = shard(krope, "batch", "kv_seq", None)
    wuk = p["wukv"][..., :nope].astype(dt)  # (r, H, nope)
    wuv = p["wukv"][..., nope:].astype(dt)  # (r, H, v)
    q_abs = jnp.einsum("bhsk,rhk->bhsr", q_nope, wuk)
    s = jnp.einsum("bhsr,btr->bhst", q_abs, ckv) + jnp.einsum(
        "bhsk,btk->bhst", q_rope, krope
    )
    s = s.astype(jnp.float32) * ((nope + rope_d) ** -0.5)
    S = ckv.shape[1]
    s = jnp.where(_pos_mask(pos, B, S)[:, :, 0], s, -1e30)  # (B,1,1,S)
    w = jax.nn.softmax(s, axis=-1).astype(dt)
    ctx = jnp.einsum("bhst,btr->bhsr", w, ckv)
    out_h = jnp.einsum("bhsr,rhv->bhsv", ctx, wuv)
    y = jnp.einsum("bhsv,hvd->bsd", out_h, p["wo"].astype(dt))
    return y, {"ckv": ckv, "krope": krope}


def dense_decode_step(params, cfg: ArchConfig, cache, tokens, pos):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32."""
    h = embed_tokens(params, cfg, tokens)
    decode_attn = mla_decode_attn if cfg.attention == "mla" else gqa_decode_attn

    def body(h, inp):
        layer_p, layer_c = inp
        y, new_c = decode_attn(layer_p["attn"], layer_c, rms_norm(h, layer_p["ln1"]), cfg, pos)
        h = h + y
        m = layer_p["mlp"]
        h = h + swiglu(rms_norm(h, layer_p["ln2"]), m["wg"], m["wi"], m["wo"], jnp.dtype(cfg.dtype))
        return h, new_c

    h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache))
    h = rms_norm(h, params["final_ln"])
    return unembed(params, cfg, h), new_cache
