"""Shared model machinery: parameter definitions with logical axes, logical
sharding rules, norms, RoPE, and memory-efficient attention.

Design notes
------------
* **No flax.** A model is described by a pytree of :class:`ParamDef`
  (shape + logical axis names + dtype). ``init_params`` materialises real
  arrays; the multi-pod dry-run only ever calls ``jax.eval_shape`` over it,
  so trillion-parameter configs never allocate.
* **Logical axes** ("embed", "heads", "ff", "experts", "vocab", ...) are
  resolved to mesh axes through a rules table (see :mod:`repro.parallel.sharding`),
  the MaxText idiom — one model definition serves every mesh.
* **Attention** ships two XLA paths: a chunked flash-style scan (online
  softmax over KV blocks; bounded memory for 32k prefill) and a single-shot
  path for tiny query lengths (decode). The Pallas TPU kernel in
  :mod:`repro.kernels` plugs in above these via ``repro.kernels.ops``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical axes (+ init scale)."""

    shape: tuple
    logical: tuple  # logical axis name per dim (None = replicated dim)
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | constant
    scale: float = 1.0  # stddev for normal / value for constant

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_defs(tree):
    return jax.tree_util.tree_leaves(tree, is_leaf=is_def)


def init_params(defs, rng):
    """Materialise a ParamDef tree into real arrays (small configs only)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        elif d.init == "constant":
            out.append(jnp.full(d.shape, d.scale, d.dtype))
        else:
            std = d.scale / math.sqrt(max(1, _fan_in(d)))
            out.append((jax.random.normal(k, d.shape) * std).astype(d.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs):
    """ShapeDtypeStruct tree — what the dry-run lowers against."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def _fan_in(d: ParamDef) -> int:
    if len(d.shape) == 0:
        return 1
    if len(d.shape) == 1:
        return d.shape[0]
    # stacked-layer leading dim ("layers") is not a fan-in dim
    dims = d.shape[1:] if d.logical and d.logical[0] == "layers" else d.shape
    return int(np.prod(dims[:-1])) if len(dims) > 1 else dims[0]


# ---------------------------------------------------------------------------
# Logical sharding constraints
# ---------------------------------------------------------------------------

# Active logical→mesh rules, installed by repro.parallel.sharding.use_rules().
_ACTIVE_RULES: dict | None = None
_ACTIVE_MESH = None


def set_logical_rules(rules: dict | None, mesh=None) -> None:
    global _ACTIVE_RULES, _ACTIVE_MESH
    _ACTIVE_RULES = rules
    _ACTIVE_MESH = mesh


def logical_to_spec(logical: tuple) -> "jax.sharding.PartitionSpec":
    from jax.sharding import PartitionSpec as P

    if _ACTIVE_RULES is None:
        return P()
    axes = []
    for name in logical:
        axes.append(_ACTIVE_RULES.get(name) if name is not None else None)
    return P(*axes)


def shard(x, *logical):
    """with_sharding_constraint by logical axis names (no-op without rules)."""
    if _ACTIVE_RULES is None or _ACTIVE_MESH is None:
        return x
    spec = logical_to_spec(tuple(logical))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_ACTIVE_MESH, spec)
    )


# ---------------------------------------------------------------------------
# Norms & embeddings
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def sinusoidal_positions(length: int, dim: int, dtype=jnp.float32):
    pos = np.arange(length)[:, None]
    div = np.exp(np.arange(0, dim, 2) / dim * -math.log(10000.0))
    emb = np.zeros((length, dim), np.float32)
    emb[:, 0::2] = np.sin(pos * div)
    emb[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(emb, dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, Dh); positions: (S,) or (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (XLA paths)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attention_chunked(
    q, k, v, *, causal: bool = True, window: int = 0,
    q_offset=0, kv_chunk: int = 1024, logit_cap: float = 0.0,
):
    """Flash-style double-blocked attention (query blocks × KV chunks).

    q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh); GQA via Hq = G·Hkv.
    Peak live memory is one (B, H, q_block, chunk) score tile (both the
    forward scan step and its rematerialised backward), so 32k×32k attention
    never materialises O(Sq·Skv).

    Causal self-attention (Sq == Skv, q_offset == 0) uses a *triangular*
    schedule: query blocks are unrolled and each scans only its ≤ diagonal
    KV chunks — no masked-out block is ever computed (2× FLOP saving vs the
    rectangular scan; local attention additionally clips at the window).
    Ragged lengths (whisper's 1500-frame encoder) are padded and masked.
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    Dv = v.shape[-1]  # MLA has v_head_dim != qk head dim
    G = Hq // Hkv
    chunk = min(kv_chunk, max(Skv, 1))
    valid_kv = Skv
    if Skv % chunk:  # pad ragged KV
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Skv += pad
    n_kv = Skv // chunk
    valid_q = Sq
    qb = min(chunk, Sq)
    if Sq % qb:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qb - Sq % qb), (0, 0)))
        Sq = q.shape[2]
    n_q = Sq // qb

    qg = q.reshape(B, Hkv, G, n_q, qb, Dh) * (Dh**-0.5)
    kc = k.reshape(B, Hkv, n_kv, chunk, Dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, n_kv, chunk, Dv).transpose(2, 0, 1, 3, 4)

    def tile(carry, q_blk, k_blk, v_blk, q_pos, k_pos):
        """One (q_block × kv_chunk) online-softmax update."""
        m, l, acc = carry
        s = jnp.einsum(
            "bhgqd,bhcd->bhgqc", q_blk, k_blk, preferred_element_type=jnp.float32
        )
        if logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        mask = jnp.broadcast_to(k_pos[None, :] < valid_kv, (qb, chunk))
        mask &= q_pos[:, None] < valid_q + q_offset
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + p.sum(axis=-1)
        acc_new = acc * correction[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    def init_carry():
        return (
            jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, qb), jnp.float32),
            jnp.zeros((B, Hkv, G, qb, Dv), jnp.float32),
        )

    triangular = causal and Sq == Skv and not _is_traced(q_offset) and q_offset == 0

    if triangular:
        outs = []
        for qi in range(n_q):
            lo = 0
            if window > 0:
                lo = max(0, qi - (window + chunk - 1) // chunk)
            q_blk = qg[:, :, :, qi]
            q_pos = qi * qb + jnp.arange(qb)

            @jax.checkpoint
            def q_block_fn(q_blk, ks, vs, lo=lo, qi=qi, q_pos=q_pos):
                def step(carry, inp):
                    ci, k_blk, v_blk = inp
                    k_pos = ci * chunk + jnp.arange(chunk)
                    return tile(carry, q_blk, k_blk, v_blk, q_pos, k_pos), None

                carry, _ = jax.lax.scan(
                    jax.checkpoint(step), init_carry(), (jnp.arange(lo, qi + 1), ks, vs)
                )
                m, l, acc = carry
                return acc / jnp.maximum(l, 1e-30)[..., None]

            outs.append(q_block_fn(q_blk, kc[lo : qi + 1], vc[lo : qi + 1]))
        out = jnp.stack(outs, axis=3)  # (B,Hkv,G,n_q,qb,Dv)
    else:
        # rectangular: outer scan over q blocks, inner scan over all KV chunks
        @jax.checkpoint
        def q_block_fn(q_blk, q_pos):
            def step(carry, inp):
                ci, k_blk, v_blk = inp
                k_pos = ci * chunk + jnp.arange(chunk)
                return tile(carry, q_blk, k_blk, v_blk, q_pos, k_pos), None

            carry, _ = jax.lax.scan(
                jax.checkpoint(step), init_carry(), (jnp.arange(n_kv), kc, vc)
            )
            m, l, acc = carry
            return acc / jnp.maximum(l, 1e-30)[..., None]

        def outer(_, inp):
            qi, q_blk = inp
            q_pos = q_offset + qi * qb + jnp.arange(qb)
            return None, q_block_fn(q_blk, q_pos)

        _, out = jax.lax.scan(
            outer, None, (jnp.arange(n_q), qg.transpose(3, 0, 1, 2, 4, 5))
        )
        out = out.transpose(1, 2, 3, 0, 4, 5)  # → (B,Hkv,G,n_q,qb,Dv)

    out = out.reshape(B, Hq, Sq, Dv)[:, :, :valid_q]
    return out.astype(q.dtype)


def _is_traced(x) -> bool:
    return isinstance(x, jax.core.Tracer)


def attention_single_shot(q, k, v, *, mask=None, logit_cap: float = 0.0):
    """Naive attention for tiny Sq (decode): one (B,H,Sq,Skv) score tensor.

    With the KV sequence dim sharded over the ``model`` mesh axis this is
    exactly flash-decoding's split-KV: GSPMD turns the softmax reductions
    into tiny per-shard partials + an all-reduce.
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, Dh) * (Dh**-0.5)
    s = jnp.einsum("bhgqd,bhsd->bhgqs", qg, k, preferred_element_type=jnp.float32)
    if logit_cap > 0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum(
        "bhgqs,bhsd->bhgqd", (p / jnp.maximum(l, 1e-30)).astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Hq, Sq, Dh).astype(q.dtype)


def causal_mask(sq: int, skv: int, q_offset=0):
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    return (k_pos[None, :] <= q_pos[:, None])[None, None, None]


# ---------------------------------------------------------------------------
# Activations / misc
# ---------------------------------------------------------------------------


def swiglu(x, wg, wi, wo, dtype):
    g = jnp.einsum("bsd,df->bsf", x, wg.astype(dtype))
    h = jnp.einsum("bsd,df->bsf", x, wi.astype(dtype))
    h = jax.nn.silu(g) * h
    h = shard(h, "batch", None, "ff")
    return jnp.einsum("bsf,fd->bsd", h, wo.astype(dtype))


def geglu(x, wg, wi, wo, dtype):
    g = jnp.einsum("bsd,df->bsf", x, wg.astype(dtype))
    h = jnp.einsum("bsd,df->bsf", x, wi.astype(dtype))
    h = jax.nn.gelu(g) * h
    h = shard(h, "batch", None, "ff")
    return jnp.einsum("bsf,fd->bsd", h, wo.astype(dtype))


def cross_entropy(logits, labels, *, z_loss: float = 1e-4):
    """Token-level CE with optional z-loss; logits may be vocab-sharded.

    Returns (mean loss, metrics). labels == -100 are masked out.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    # gold logit via masked reduce (not take_along_axis): elementwise over the
    # (possibly vocab-sharded) logits + a partial-sum reduce — GSPMD keeps the
    # big tensor sharded instead of all-gathering it for a gather op.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    mask = (labels >= 0).astype(jnp.float32)
    ce = (lse - gold) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (ce + zl).sum() / denom
    acc = ((logits.argmax(-1) == labels) * mask).sum() / denom
    return loss, {"ce": ce.sum() / denom, "accuracy": acc}
