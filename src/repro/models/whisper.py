"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the conv audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, enc_len, D) — the log-mel + 2×conv
stem would produce exactly this. The transformer backbone (bidirectional
encoder, causal decoder with cross-attention) is implemented in full.

Positions are sinusoidal, computed functionally (not as a baked table) so a
32k-slot decode cache does not embed a 100 MB constant in the HLO.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import (
    ParamDef,
    attention_chunked,
    attention_single_shot,
    cross_entropy,
    layer_norm,
    shard,
)
from .config import ArchConfig
from .transformer import _stack, remat_wrap

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def _attn_defs(cfg: ArchConfig, pdt) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    hd = cfg.resolved_head_dim
    return {
        "wq": ParamDef((D, H, hd), ("embed", "heads", None), pdt),
        "wk": ParamDef((D, H, hd), ("embed", "heads", None), pdt),
        "wv": ParamDef((D, H, hd), ("embed", "heads", None), pdt),
        "wo": ParamDef((H, hd, D), ("heads", None, "embed"), pdt),
    }


def _mlp_defs(cfg: ArchConfig, pdt) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "wi": ParamDef((D, F), ("embed", "ff"), pdt),
        "bi": ParamDef((F,), ("ff",), pdt, "zeros"),
        "wo": ParamDef((F, D), ("ff", "embed"), pdt),
        "bo": ParamDef((D,), (None,), pdt, "zeros"),
    }


def enc_layer_defs(cfg: ArchConfig, pdt) -> dict:
    D = cfg.d_model
    return {
        "ln1_w": ParamDef((D,), (None,), pdt, "ones"),
        "ln1_b": ParamDef((D,), (None,), pdt, "zeros"),
        "attn": _attn_defs(cfg, pdt),
        "ln2_w": ParamDef((D,), (None,), pdt, "ones"),
        "ln2_b": ParamDef((D,), (None,), pdt, "zeros"),
        "mlp": _mlp_defs(cfg, pdt),
    }


def dec_layer_defs(cfg: ArchConfig, pdt) -> dict:
    D = cfg.d_model
    return {
        "ln1_w": ParamDef((D,), (None,), pdt, "ones"),
        "ln1_b": ParamDef((D,), (None,), pdt, "zeros"),
        "self_attn": _attn_defs(cfg, pdt),
        "ln2_w": ParamDef((D,), (None,), pdt, "ones"),
        "ln2_b": ParamDef((D,), (None,), pdt, "zeros"),
        "cross_attn": _attn_defs(cfg, pdt),
        "ln3_w": ParamDef((D,), (None,), pdt, "ones"),
        "ln3_b": ParamDef((D,), (None,), pdt, "zeros"),
        "mlp": _mlp_defs(cfg, pdt),
    }


def whisper_param_defs(cfg: ArchConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    V, D = cfg.vocab_size, cfg.d_model
    is_def = lambda x: isinstance(x, ParamDef)
    stack = lambda n, tree: jax.tree_util.tree_map(
        lambda d: _stack(n, d), tree, is_leaf=is_def
    )
    return {
        "enc_blocks": stack(cfg.n_enc_layers, enc_layer_defs(cfg, pdt)),
        "enc_ln_w": ParamDef((D,), (None,), pdt, "ones"),
        "enc_ln_b": ParamDef((D,), (None,), pdt, "zeros"),
        "embed": ParamDef((V, D), ("vocab", "embed"), pdt),
        "dec_blocks": stack(cfg.n_layers, dec_layer_defs(cfg, pdt)),
        "dec_ln_w": ParamDef((D,), (None,), pdt, "ones"),
        "dec_ln_b": ParamDef((D,), (None,), pdt, "zeros"),
    }


# ---------------------------------------------------------------------------
# Functional sinusoidal positions
# ---------------------------------------------------------------------------


def sinusoid(positions, dim: int, dtype):
    """positions: (S,) int → (S, dim), computed in-graph."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(1, half - 1))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Attention helpers
# ---------------------------------------------------------------------------


def _mha(p, xq, xkv, cfg: ArchConfig, *, causal: bool, collect: bool = False):
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bhsk", xq, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bhsk", xkv, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bhsk", xkv, p["wv"].astype(dt))
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", "heads", None, None)
    out = attention_chunked(q, k, v, causal=causal, kv_chunk=cfg.attn_chunk)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(dt))
    if collect:
        return y, k, v
    return y


def _mlp(p, x):
    dt = x.dtype
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt)) + p["bi"].astype(dt))
    h = shard(h, "batch", None, "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt)) + p["bo"].astype(dt)


# ---------------------------------------------------------------------------
# Encoder / decoder stacks
# ---------------------------------------------------------------------------


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, enc_len, D) stub-frontend embeddings → encoder memory."""
    dt = jnp.dtype(cfg.dtype)
    B, T, D = frames.shape
    h = frames.astype(dt) + sinusoid(jnp.arange(T), D, dt)[None]
    h = shard(h, "batch", None, None)

    def body(h, p):
        h = h + _mha(p["attn"], layer_norm(h, p["ln1_w"], p["ln1_b"]),
                     layer_norm(h, p["ln1_w"], p["ln1_b"]), cfg, causal=False)
        h = h + _mlp(p["mlp"], layer_norm(h, p["ln2_w"], p["ln2_b"]))
        return h, None

    h, _ = jax.lax.scan(remat_wrap(body, cfg), h, params["enc_blocks"])
    return layer_norm(h, params["enc_ln_w"], params["enc_ln_b"])


def decode_train(params, cfg: ArchConfig, tokens, memory):
    dt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    h = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    h = h + sinusoid(jnp.arange(S), cfg.d_model, dt)[None]
    h = shard(h, "batch", None, None)

    def body(h, p):
        xn = layer_norm(h, p["ln1_w"], p["ln1_b"])
        h = h + _mha(p["self_attn"], xn, xn, cfg, causal=True)
        h = h + _mha(
            p["cross_attn"], layer_norm(h, p["ln2_w"], p["ln2_b"]), memory, cfg,
            causal=False,
        )
        h = h + _mlp(p["mlp"], layer_norm(h, p["ln3_w"], p["ln3_b"]))
        return h, None

    h, _ = jax.lax.scan(remat_wrap(body, cfg), h, params["dec_blocks"])
    h = layer_norm(h, params["dec_ln_w"], params["dec_ln_b"])
    return jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(dt))  # tied head


def whisper_loss(params, cfg: ArchConfig, batch):
    memory = encode(params, cfg, batch["frames"])
    logits = decode_train(params, cfg, batch["tokens"], memory)
    logits = shard(logits, "batch", None, "vocab")
    loss, metrics = cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
    return loss, metrics


def whisper_prefill(params, cfg: ArchConfig, frames, tokens):
    """Encode the audio memory, prefill the decoder over `tokens`, and return
    (last-position logits, cache with self-KV + precomputed cross-KV)."""
    memory = encode(params, cfg, frames)
    dt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    h = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    h = h + sinusoid(jnp.arange(S), cfg.d_model, dt)[None]
    h = shard(h, "batch", None, None)

    def body(h, p):
        xn = layer_norm(h, p["ln1_w"], p["ln1_b"])
        y, k, v = _mha(p["self_attn"], xn, xn, cfg, causal=True, collect=True)
        h = h + y
        y2, kc, vc = _mha(
            p["cross_attn"], layer_norm(h, p["ln2_w"], p["ln2_b"]), memory, cfg,
            causal=False, collect=True,
        )
        h = h + y2
        h = h + _mlp(p["mlp"], layer_norm(h, p["ln3_w"], p["ln3_b"]))
        return h, {"self_k": k, "self_v": v, "cross_k": kc, "cross_v": vc}

    h, cache = jax.lax.scan(remat_wrap(body, cfg), h, params["dec_blocks"])
    h = layer_norm(h[:, -1:], params["dec_ln_w"], params["dec_ln_b"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(dt))
    return shard(logits, "batch", None, "vocab"), cache


# ---------------------------------------------------------------------------
# Serving: cross-KV precomputed at prefill; self-KV ring grows to max_seq
# ---------------------------------------------------------------------------


def whisper_cache_defs(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    L, H = cfg.n_layers, cfg.n_heads
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "self_k": jax.ShapeDtypeStruct((L, batch, H, max_seq, hd), dt),
        "self_v": jax.ShapeDtypeStruct((L, batch, H, max_seq, hd), dt),
        "cross_k": jax.ShapeDtypeStruct((L, batch, H, cfg.enc_len, hd), dt),
        "cross_v": jax.ShapeDtypeStruct((L, batch, H, cfg.enc_len, hd), dt),
    }


def whisper_cache_logical(cfg: ArchConfig) -> dict:
    kv = ("layers", "batch", None, "kv_seq", None)
    return {"self_k": kv, "self_v": kv,
            "cross_k": ("layers", "batch", "heads", None, None),
            "cross_v": ("layers", "batch", "heads", None, None)}


def whisper_decode_step(params, cfg: ArchConfig, cache, tokens, pos):
    from .transformer import scatter_seq

    dt = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    h = h + sinusoid(jnp.full((1,), pos), cfg.d_model, dt)[None]

    def body(h, inp):
        p, c = inp
        xn = layer_norm(h, p["ln1_w"], p["ln1_b"])
        q = jnp.einsum("bsd,dhk->bhsk", xn, p["self_attn"]["wq"].astype(dt))
        k_new = jnp.einsum("bsd,dhk->bhsk", xn, p["self_attn"]["wk"].astype(dt))
        v_new = jnp.einsum("bsd,dhk->bhsk", xn, p["self_attn"]["wv"].astype(dt))
        k = scatter_seq(c["self_k"], k_new, pos)
        v = scatter_seq(c["self_v"], v_new, pos)
        S = k.shape[-2]
        mask = (jnp.arange(S) <= pos)[None, None, None, None, :]
        out = attention_single_shot(q, k, v, mask=mask)
        h = h + jnp.einsum("bhsk,hkd->bsd", out, p["self_attn"]["wo"].astype(dt))
        # cross-attention against the precomputed encoder memory KV
        xn2 = layer_norm(h, p["ln2_w"], p["ln2_b"])
        q2 = jnp.einsum("bsd,dhk->bhsk", xn2, p["cross_attn"]["wq"].astype(dt))
        out2 = attention_single_shot(q2, c["cross_k"], c["cross_v"])
        h = h + jnp.einsum("bhsk,hkd->bsd", out2, p["cross_attn"]["wo"].astype(dt))
        h = h + _mlp(p["mlp"], layer_norm(h, p["ln3_w"], p["ln3_b"]))
        return h, {"self_k": k, "self_v": v, "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    h, new_cache = jax.lax.scan(body, h, (params["dec_blocks"], cache))
    h = layer_norm(h, params["dec_ln_w"], params["dec_ln_b"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(dt))
    return shard(logits, "batch", None, "vocab"), new_cache
