"""Griffin / RecurrentGemma — RG-LRU recurrent blocks + local attention (1:2).

Block pattern (arXiv:2402.19427): repeating (recurrent, recurrent, local-attn)
residual pairs, each pair = temporal block + GeGLU MLP with pre-RMSNorm.
The RG-LRU recurrence:

    r_t = σ(w_a ⊙ x_t + b_a)            (recurrence gate, per-channel)
    i_t = σ(w_x ⊙ x_t + b_x)            (input gate)
    a_t = exp(-c · softplus(Λ) ⊙ r_t)    (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth parallel scan —
the TPU-native replacement for the paper's fused GPU scan kernel; the Pallas
kernel in repro/kernels/rglru_scan.py does the block-local version). Decode
keeps O(1) state per recurrent layer and a ring-buffer KV cache of
``window`` (2048) for local-attention layers — which is why this arch runs
the 500k-token cell.

Heterogeneous depth under ``lax.scan``: layers are grouped into scanned
"super-layers" of (rec, rec, attn); the remainder (26 = 3·8 + 2) is a
scanned tail of rec pairs.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .common import (
    ParamDef,
    apply_rope,
    attention_chunked,
    attention_single_shot,
    cross_entropy,
    geglu,
    rms_norm,
    shard,
)
from .config import ArchConfig
from .transformer import _stack, embed_tokens, remat_wrap, unembed

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def rec_pair_defs(cfg: ArchConfig, pdt) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    W = cfg.lru_width or cfg.d_model
    K = cfg.conv_width
    return {
        "ln1": ParamDef((D,), (None,), pdt, "ones"),
        "rec": {
            "w_gate": ParamDef((D, W), ("embed", "lru"), pdt),
            "w_in": ParamDef((D, W), ("embed", "lru"), pdt),
            "conv_w": ParamDef((W, K), ("lru", None), pdt, scale=0.5),
            "conv_b": ParamDef((W,), ("lru",), pdt, "zeros"),
            "a_gate_w": ParamDef((W,), ("lru",), pdt, "zeros"),
            "a_gate_b": ParamDef((W,), ("lru",), pdt, "zeros"),
            "in_gate_w": ParamDef((W,), ("lru",), pdt, "zeros"),
            "in_gate_b": ParamDef((W,), ("lru",), pdt, "zeros"),
            "lam": ParamDef((W,), ("lru",), pdt, "constant", scale=0.7),
            "w_out": ParamDef((W, D), ("lru", "embed"), pdt),
        },
        "ln2": ParamDef((D,), (None,), pdt, "ones"),
        "mlp": {
            "wg": ParamDef((D, F), ("embed", "ff"), pdt),
            "wi": ParamDef((D, F), ("embed", "ff"), pdt),
            "wo": ParamDef((F, D), ("ff", "embed"), pdt),
        },
    }


def attn_pair_defs(cfg: ArchConfig, pdt) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, K = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    return {
        "ln1": ParamDef((D,), (None,), pdt, "ones"),
        "attn": {
            "wq": ParamDef((D, H, hd), ("embed", "heads", None), pdt),
            "wk": ParamDef((D, K, hd), ("embed", "kv_heads", None), pdt),
            "wv": ParamDef((D, K, hd), ("embed", "kv_heads", None), pdt),
            "wo": ParamDef((H, hd, D), ("heads", None, "embed"), pdt),
        },
        "ln2": ParamDef((D,), (None,), pdt, "ones"),
        "mlp": {
            "wg": ParamDef((D, F), ("embed", "ff"), pdt),
            "wi": ParamDef((D, F), ("embed", "ff"), pdt),
            "wo": ParamDef((F, D), ("ff", "embed"), pdt),
        },
    }


def griffin_layout(cfg: ArchConfig) -> tuple[int, int]:
    """(n_super, n_tail_rec) for the (rec, rec, attn) pattern."""
    n_super = cfg.n_layers // 3
    tail = cfg.n_layers - 3 * n_super
    return n_super, tail


def griffin_param_defs(cfg: ArchConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    V, D = cfg.vocab_size, cfg.d_model
    n_super, tail = griffin_layout(cfg)
    is_def = lambda x: isinstance(x, ParamDef)
    stack = lambda n, tree: jax.tree_util.tree_map(
        lambda d: _stack(n, d), tree, is_leaf=is_def
    )
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), pdt),
        "super": {
            "rec1": stack(n_super, rec_pair_defs(cfg, pdt)),
            "rec2": stack(n_super, rec_pair_defs(cfg, pdt)),
            "attn": stack(n_super, attn_pair_defs(cfg, pdt)),
        },
        "final_ln": ParamDef((D,), (None,), pdt, "ones"),
    }
    if tail:
        defs["tail"] = stack(tail, rec_pair_defs(cfg, pdt))
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((D, V), ("embed", "vocab"), pdt)
    return defs


# ---------------------------------------------------------------------------
# RG-LRU + causal conv
# ---------------------------------------------------------------------------

_LRU_C = 8.0


def rglru_coeffs(p, xb):
    f32 = jnp.float32
    x = xb.astype(f32)
    r = jax.nn.sigmoid(x * p["a_gate_w"].astype(f32) + p["a_gate_b"].astype(f32))
    i = jax.nn.sigmoid(x * p["in_gate_w"].astype(f32) + p["in_gate_b"].astype(f32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"].astype(f32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)
    return a, b


def rglru_scan(p, xb, h0=None, use_pallas: bool = False):
    """xb: (B,S,W) conv output. Returns (h (B,S,W), h_last)."""
    a, b = rglru_coeffs(p, xb)
    if use_pallas and xb.shape[1] % 128 == 0:
        from repro.kernels import ops as kops

        h0f = h0 if h0 is not None else jnp.zeros(a[:, 0].shape, jnp.float32)
        h, h_last = kops.lru_scan(a, b, h0f, use_pallas=True)
        return h.astype(xb.dtype), h_last
    if h0 is not None:
        # fold the carried state into the first step: b_0 += a_0 * h0
        b = b.at[:, 0].add(a[:, 0] * h0.astype(a.dtype))

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(xb.dtype), h[:, -1]


def rglru_step(p, xb, h):
    """xb: (B,W) one token; h: (B,W) f32 state."""
    a, b = rglru_coeffs(p, xb[:, None])
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(xb.dtype), h_new


def causal_conv(p, xb, state=None):
    """Depthwise causal conv, width K. state: (B,K-1,W) trailing inputs."""
    K = p["conv_w"].shape[1]
    x = xb if state is None else jnp.concatenate([state.astype(xb.dtype), xb], axis=1)
    pad = 0 if state is not None else K - 1
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    out = sum(
        x[:, i : i + xb.shape[1]] * p["conv_w"].astype(xb.dtype)[:, i]
        for i in range(K)
    )
    return out + p["conv_b"].astype(xb.dtype), x[:, -(K - 1) :]


def rec_temporal(p, x, cfg: ArchConfig, cache=None):
    """Griffin recurrent temporal block. Returns (y, new_cache)."""
    dt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dt)))
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_in"].astype(dt))
    xb = shard(xb, "batch", None, "lru")
    conv_state = cache["conv"] if cache else None
    h0 = cache["h"] if cache else None
    xb, conv_tail = causal_conv(p, xb, conv_state)
    if x.shape[1] == 1 and cache is not None:
        h_seq, h_last = rglru_step(p, xb[:, 0], h0)
        h_seq = h_seq[:, None]
    else:
        h_seq, h_last = rglru_scan(p, xb, h0, use_pallas=cfg.use_pallas)
    y = jnp.einsum("bsw,wd->bsd", gate * h_seq, p["w_out"].astype(dt))
    return y, {"conv": conv_tail, "h": h_last}


# ---------------------------------------------------------------------------
# Local attention with ring-buffer cache
# ---------------------------------------------------------------------------


def local_attention(p, x, cfg: ArchConfig, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(dt))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "heads", None, None)
    out = attention_chunked(
        q, k, v, causal=True, window=cfg.window,
        kv_chunk=min(cfg.attn_chunk, cfg.window), logit_cap=cfg.logit_cap,
    )
    return jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(dt)), k, v


def attn_ring_decode(p, cache, x, cfg: ArchConfig, pos):
    """One-token local attention over a ring buffer of `window` slots."""
    dt = x.dtype
    W = cfg.window
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(dt))
    k_new = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(dt))
    v_new = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(dt))
    positions = jnp.full((1,), pos)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)  # roped at write time
    slot = pos % W
    onehot = (jnp.arange(W) == slot).astype(dt)[:, None]
    k = cache["k"] * (1 - onehot) + k_new.astype(dt) * onehot
    v = cache["v"] * (1 - onehot) + v_new.astype(dt) * onehot
    pos_buf = jnp.where(jnp.arange(W) == slot, pos, cache["pos"])
    valid = (pos_buf >= 0) & (pos_buf <= pos) & (pos_buf > pos - W)
    out = attention_single_shot(
        q, k, v, mask=valid[None, None, None, None, :], logit_cap=cfg.logit_cap
    )
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(dt))
    return y, {"k": k, "v": v, "pos": pos_buf}


# ---------------------------------------------------------------------------
# Pairs and stacks
# ---------------------------------------------------------------------------


def rec_pair(p, x, cfg: ArchConfig, cache=None):
    y, new_cache = rec_temporal(p["rec"], rms_norm(x, p["ln1"]), cfg, cache)
    x = x + y
    m = p["mlp"]
    x = x + geglu(rms_norm(x, p["ln2"]), m["wg"], m["wi"], m["wo"], x.dtype)
    return x, new_cache


def attn_pair(p, x, cfg: ArchConfig, positions):
    y, k, v = local_attention(p["attn"], rms_norm(x, p["ln1"]), cfg, positions)
    x = x + y
    m = p["mlp"]
    x = x + geglu(rms_norm(x, p["ln2"]), m["wg"], m["wi"], m["wo"], x.dtype)
    return x, (k, v)


def attn_pair_decode(p, x, cfg: ArchConfig, cache, pos):
    y, new_cache = attn_ring_decode(p["attn"], cache, rms_norm(x, p["ln1"]), cfg, pos)
    x = x + y
    m = p["mlp"]
    x = x + geglu(rms_norm(x, p["ln2"]), m["wg"], m["wi"], m["wo"], x.dtype)
    return x, new_cache


# ---------------------------------------------------------------------------
# Model-level entry points
# ---------------------------------------------------------------------------


def griffin_forward(params, cfg: ArchConfig, tokens):
    h = embed_tokens(params, cfg, tokens)
    S = h.shape[1]
    positions = jnp.arange(S)

    def super_body(h, p):
        h, _ = rec_pair(p["rec1"], h, cfg)
        h, _ = rec_pair(p["rec2"], h, cfg)
        h, _ = attn_pair(p["attn"], h, cfg, positions)
        return h, None

    h, _ = jax.lax.scan(remat_wrap(super_body, cfg), h, params["super"])
    if "tail" in params:

        def tail_body(h, p):
            h, _ = rec_pair(p, h, cfg)
            return h, None

        h, _ = jax.lax.scan(remat_wrap(tail_body, cfg), h, params["tail"])
    h = rms_norm(h, params["final_ln"])
    return unembed(params, cfg, h)


def griffin_loss(params, cfg: ArchConfig, batch):
    logits = griffin_forward(params, cfg, batch["tokens"])
    loss, metrics = cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
    return loss, metrics


def griffin_prefill(params, cfg: ArchConfig, tokens):
    """Prefill: full forward collecting recurrent states + local-attention
    ring buffers (last ``window`` keys/values, ring-ordered)."""
    h = embed_tokens(params, cfg, tokens)
    S = h.shape[1]
    positions = jnp.arange(S)

    def super_body(h, p):
        h, c1 = rec_pair(p["rec1"], h, cfg)
        h, c2 = rec_pair(p["rec2"], h, cfg)
        h, kv = attn_pair(p["attn"], h, cfg, positions)
        return h, (c1, c2, kv)

    h, (c1s, c2s, (ks, vs)) = jax.lax.scan(
        remat_wrap(super_body, cfg), h, params["super"]
    )
    cache = {"rec1": c1s, "rec2": c2s, "attn": _ring_from_full(ks, vs, cfg, S)}
    if "tail" in params:

        def tail_body(h, p):
            h, c = rec_pair(p, h, cfg)
            return h, c

        h, cache["tail"] = jax.lax.scan(remat_wrap(tail_body, cfg), h, params["tail"])
    h = rms_norm(h[:, -1:], params["final_ln"])
    return unembed(params, cfg, h), cache


def _ring_from_full(ks, vs, cfg: ArchConfig, S: int):
    """(n_super, B, Hkv, S, hd) full-seq K/V → ring buffers at slot p % W."""
    W = cfg.window
    n_super = ks.shape[0]
    if S >= W:
        last_pos = np.arange(S - W, S)
        k_slice, v_slice = ks[..., -W:, :], vs[..., -W:, :]
        order = np.argsort(last_pos % W)  # static permutation to ring order
        k_ring = k_slice[..., order, :]
        v_ring = v_slice[..., order, :]
        pos_buf = jnp.asarray(last_pos[order], jnp.int32)
    else:
        pad = W - S
        k_ring = jnp.pad(ks, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        v_ring = jnp.pad(vs, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        pos_buf = jnp.concatenate(
            [jnp.arange(S), jnp.full((pad,), -1)], dtype=None
        ).astype(jnp.int32)
    return {
        "k": k_ring,
        "v": v_ring,
        "pos": jnp.broadcast_to(pos_buf, (n_super, W)),
    }


def griffin_cache_defs(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    """O(window + lru_width) state — sequence-length-independent."""
    del max_seq  # decode state does not grow with context
    n_super, tail = griffin_layout(cfg)
    W = cfg.lru_width or cfg.d_model
    K = cfg.conv_width
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    rec = lambda n: {
        "conv": jax.ShapeDtypeStruct((n, batch, K - 1, W), dt),
        "h": jax.ShapeDtypeStruct((n, batch, W), jnp.float32),
    }
    out = {
        "rec1": rec(n_super),
        "rec2": rec(n_super),
        "attn": {
            "k": jax.ShapeDtypeStruct((n_super, batch, cfg.n_kv_heads, cfg.window, hd), dt),
            "v": jax.ShapeDtypeStruct((n_super, batch, cfg.n_kv_heads, cfg.window, hd), dt),
            "pos": jax.ShapeDtypeStruct((n_super, cfg.window), jnp.int32),
        },
    }
    if tail:
        out["tail"] = rec(tail)
    return out


def griffin_cache_logical(cfg: ArchConfig) -> dict:
    n_super, tail = griffin_layout(cfg)
    rec = {"conv": ("layers", "batch", None, "lru"), "h": ("layers", "batch", "lru")}
    out = {
        "rec1": dict(rec),
        "rec2": dict(rec),
        "attn": {
            "k": ("layers", "batch", None, "kv_seq", None),
            "v": ("layers", "batch", None, "kv_seq", None),
            "pos": ("layers", None),
        },
    }
    if tail:
        out["tail"] = dict(rec)
    return out


def griffin_decode_step(params, cfg: ArchConfig, cache, tokens, pos):
    h = embed_tokens(params, cfg, tokens)

    def super_body(h, inp):
        p, c = inp
        new_c = {}
        h, new_c["rec1"] = rec_pair(p["rec1"], h, cfg, c["rec1"])
        h, new_c["rec2"] = rec_pair(p["rec2"], h, cfg, c["rec2"])
        h, new_c["attn"] = attn_pair_decode(p["attn"], h, cfg, c["attn"], pos)
        return h, new_c

    sup_cache = {k: cache[k] for k in ("rec1", "rec2", "attn")}
    h, new_super = jax.lax.scan(super_body, h, (params["super"], sup_cache))
    new_cache = dict(new_super)
    if "tail" in params:

        def tail_body(h, inp):
            p, c = inp
            h, nc = rec_pair(p, h, cfg, c)
            return h, nc

        h, new_cache["tail"] = jax.lax.scan(tail_body, h, (params["tail"], cache["tail"]))
    h = rms_norm(h, params["final_ln"])
    return unembed(params, cfg, h), new_cache
