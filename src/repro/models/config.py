"""``ArchConfig`` — one declarative record per architecture.

Every assigned architecture is a pure-data config consumed by the model
registry; performance levers (remat, microbatching, attention chunking,
optimizer choice, MoE group size) live here too so the §Perf hillclimb is a
config diff, not a code fork.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | rwkv6 | rglru | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads

    # attention
    attention: str = "gqa"  # gqa | mla | local | none
    rope_theta: float = 1e4
    window: int = 0  # sliding-window size for local attention

    # MLA (MiniCPM3 / DeepSeek-style latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0  # leading dense layers (DeepSeek/Kimi style)
    capacity_factor: float = 1.25
    moe_group_tokens: int = 1024  # GShard dispatch group size (perf lever)
    router_aux_weight: float = 0.01

    # hybrid (Griffin / RecurrentGemma)
    block_pattern: tuple = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    conv_width: int = 4
    logit_cap: float = 0.0

    # RWKV6
    rwkv_head_size: int = 64
    rwkv_lora_rank: int = 32
    rwkv_decay_lora: int = 64

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_len: int = 1500  # encoder memory length (stub frontend output)

    # multimodal stub (llava)
    n_patches: int = 0  # visual tokens prepended by the stub frontend

    # numerics
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"  # activation/compute dtype

    # perf levers
    remat: str = "full"  # none | full | selective
    use_scan: bool = True
    use_pallas: bool = False  # Pallas kernels (Mosaic on TPU; interpret on CPU)
    seq_shard: bool = False  # sequence parallelism: residual stream S over `model`
    fsdp: bool = False  # ZeRO-3: weight/optimizer "embed" dim over the data axes
    #   (training only; serving keeps TP-only weights for per-token latency)
    optimizer: str = "adamw"  # adamw | adamw8bit | lion
    microbatch: int = 1  # gradient-accumulation microbatches
    attn_chunk: int = 1024  # KV chunk for flash-style attention
    tie_embeddings: bool = False
    z_loss: float = 1e-4

    # capability flags
    sub_quadratic: bool = False  # eligible for long_500k
    has_decoder: bool = True  # encoder-only archs skip decode shapes

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # -- bookkeeping used by launchers, rooflines and EXPERIMENTS.md ---------

    def param_count(self) -> int:
        """Total parameters (all experts), analytic."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv6":
            per = self._rwkv_layer_params()
            return emb + L * per + D
        if self.family == "rglru":
            return emb + self._griffin_params() + D
        attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
        if self.attention == "mla":
            attn = self._mla_layer_params()
        dense_mlp = 3 * D * F
        if self.family == "moe":
            n_moe = L - self.n_dense_layers
            moe_mlp = (
                self.n_experts * 3 * D * self.moe_d_ff
                + self.n_shared_experts * 3 * D * self.moe_d_ff
                + D * self.n_experts  # router
            )
            body = self.n_dense_layers * (attn + dense_mlp) + n_moe * (attn + moe_mlp)
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + dense_mlp)
            dec = L * (attn * 2 + dense_mlp)  # self + cross attention
            body = enc + dec
        else:
            body = L * (attn + dense_mlp)
        return emb + body + D

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
        n_moe = L - self.n_dense_layers
        active_mlp = (self.top_k + self.n_shared_experts) * 3 * D * self.moe_d_ff
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        return (
            emb
            + self.n_dense_layers * (attn + 3 * D * F)
            + n_moe * (attn + active_mlp + D * self.n_experts)
            + D
        )

    def _mla_layer_params(self) -> int:
        D = self.d_model
        H = self.n_heads
        qk = self.qk_nope_dim + self.qk_rope_dim
        return (
            D * self.q_lora_rank
            + self.q_lora_rank * H * qk
            + D * (self.kv_lora_rank + self.qk_rope_dim)
            + self.kv_lora_rank * H * (self.qk_nope_dim + self.v_head_dim)
            + H * self.v_head_dim * D
        )

    def _rwkv_layer_params(self) -> int:
        D, F = self.d_model, self.d_ff
        r = self.rwkv_lora_rank
        # time-mix: r/k/v/g/o square proj + 5 ddlerp loras + decay lora
        tm = 5 * D * D + 5 * (D * r + r * D) + (D * self.rwkv_decay_lora + self.rwkv_decay_lora * D)
        cm = 2 * D * F  # channel-mix key/value (+ receptance D*D)
        return tm + cm + D * D

    def _griffin_params(self) -> int:
        D, F = self.d_model, self.d_ff
        W = self.lru_width or D
        hd = self.resolved_head_dim
        n_attn = sum(1 for i in range(self.n_layers) if self._block_kind(i) == "attn")
        n_rec = self.n_layers - n_attn
        attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
        rec = 2 * D * W + W * self.conv_width + 2 * W + W * D  # in/gate, conv, lru gates, out
        mlp = 3 * D * F
        return n_attn * (attn + mlp) + n_rec * (rec + mlp)

    def _block_kind(self, i: int) -> str:
        if not self.block_pattern:
            return "attn"
        return self.block_pattern[i % len(self.block_pattern)]
