"""RWKV-6 "Finch" — attention-free RNN with data-dependent decay.

Faithful block structure (time-mix with ddlerp token-shift LoRAs,
data-dependent per-channel decay ``w_t``, per-head WKV state, group-norm +
SiLU gate; channel-mix with squared-ReLU), arXiv:2404.05892.

The WKV recurrence is evaluated in **chunked parallel form** (the TPU-native
adaptation of the paper's CUDA kernel — see DESIGN.md):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (per head, dk×dv state)
    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)

Within a chunk of C tokens all pairwise decay ratios
``exp(logcumsum(w)_t-1 - logcumsum(w)_s)`` (s<t, always ≤ 1 → numerically
safe) form a (C,C,dk) tensor contracted with r,k — O(T·C·dk) memory instead
of O(T²). Cross-chunk state is carried by ``lax.scan``. The same tiling is
the Pallas kernel's blocking (repro/kernels/rwkv6_scan.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, cross_entropy, layer_norm, shard
from .config import ArchConfig
from .transformer import _stack, embed_tokens, remat_wrap, unembed

# ---------------------------------------------------------------------------
# WKV recurrence — chunked parallel form (pure-JAX reference used on CPU; the
# Pallas kernel mirrors this blocking for TPU)
# ---------------------------------------------------------------------------


def wkv6_chunked(r, k, v, w, u, s0, chunk: int = 64):
    """r,k,w: (B,H,T,dk); v: (B,H,T,dv); u: (H,dk); s0: (B,H,dk,dv).

    Returns y: (B,H,T,dv), s_final. All accumulation in f32.
    """
    B, H, T, dk = r.shape
    dv = v.shape[-1]
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    n = T // C
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, H, n, C, dk).transpose(2, 0, 1, 3, 4)
    kc = k.astype(f32).reshape(B, H, n, C, dk).transpose(2, 0, 1, 3, 4)
    vc = v.astype(f32).reshape(B, H, n, C, dv).transpose(2, 0, 1, 3, 4)
    wc = w.astype(f32).reshape(B, H, n, C, dk).transpose(2, 0, 1, 3, 4)
    uf = u.astype(f32)

    def step(s, inp):
        rb, kb, vb, wb = inp  # (B,H,C,·)
        logw = jnp.log(jnp.maximum(wb, 1e-38))  # w ∈ (0,1)
        lc = jnp.cumsum(logw, axis=2)  # inclusive logcumsum (B,H,C,dk)
        lc_excl = lc - logw  # exclusive
        # In-chunk pairwise term: A[t,s] = Σ_i r_t,i k_s,i e^{lc_excl_t - lc_s}, s<t
        ratio = jnp.exp(
            lc_excl[:, :, :, None, :] - lc[:, :, None, :, :]
        )  # (B,H,C,C,dk), ≤1 below diagonal
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1)[None, None, :, :, None]
        ratio = jnp.where(tri, ratio, 0.0)
        A = jnp.einsum("bhti,bhtsi,bhsi->bhts", rb, ratio, kb)
        # bonus diagonal: y_t += (r_t · u ⊙ k_t) v_t
        diag = jnp.einsum("bhti,bhti->bht", rb * uf[None, :, None, :], kb)
        y = jnp.einsum("bhts,bhsv->bhtv", A, vb) + diag[..., None] * vb
        # cross-chunk: y_t += (r_t ⊙ e^{lc_excl_t}) S
        y = y + jnp.einsum("bhti,bhiv->bhtv", rb * jnp.exp(lc_excl), s)
        # state update: S' = e^{lc_C} ⊙ S + Σ_s (e^{lc_C - lc_s} ⊙ k_s) v_s
        decay_all = jnp.exp(lc[:, :, -1, :])  # (B,H,dk)
        k_scaled = kb * jnp.exp(lc[:, :, -1:, :] - lc)  # ≤ 1
        s_new = decay_all[..., None] * s + jnp.einsum("bhsi,bhsv->bhiv", k_scaled, vb)
        return s_new, y

    # checkpointed: the (C,C,dk) pairwise-decay block is recomputed in the
    # backward pass rather than saved for every chunk (O(T·C·dk) blowup).
    s_fin, ys = jax.lax.scan(jax.checkpoint(step), s0.astype(f32), (rc, kc, vc, wc))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, T, dv)
    return y.astype(r.dtype), s_fin


def wkv6_step(r, k, v, w, u, s):
    """Single-token recurrence for decode. r,k,w: (B,H,dk); v: (B,H,dv)."""
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    kv = k[..., :, None] * v[..., None, :]  # (B,H,dk,dv)
    y = jnp.einsum("bhi,bhiv->bhv", r, s + u.astype(f32)[None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    return y, s_new


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def rwkv_layer_defs(cfg: ArchConfig, pdt) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H = cfg.d_model // cfg.rwkv_head_size
    dk = cfg.rwkv_head_size
    r, dr = cfg.rwkv_lora_rank, cfg.rwkv_decay_lora
    return {
        "ln1_w": ParamDef((D,), (None,), pdt, "ones"),
        "ln1_b": ParamDef((D,), (None,), pdt, "zeros"),
        "ln2_w": ParamDef((D,), (None,), pdt, "ones"),
        "ln2_b": ParamDef((D,), (None,), pdt, "zeros"),
        "tm": {
            "mu_x": ParamDef((D,), (None,), pdt, "zeros"),
            "mu_rkvgw": ParamDef((5, D), (None, None), pdt, "zeros"),
            "maa_w1": ParamDef((D, 5 * r), ("embed", None), pdt, scale=0.1),
            "maa_w2": ParamDef((5, r, D), (None, None, "embed"), pdt, scale=0.1),
            "w0": ParamDef((D,), (None,), pdt, "constant", scale=-6.0),
            "ww1": ParamDef((D, dr), ("embed", None), pdt, scale=0.1),
            "ww2": ParamDef((dr, D), (None, "embed"), pdt, scale=0.1),
            "u": ParamDef((H, dk), ("heads", None), pdt, "zeros"),
            "wr": ParamDef((D, D), ("embed", "heads"), pdt),
            "wk": ParamDef((D, D), ("embed", "heads"), pdt),
            "wv": ParamDef((D, D), ("embed", "heads"), pdt),
            "wg": ParamDef((D, D), ("embed", "heads"), pdt),
            "wo": ParamDef((D, D), ("heads", "embed"), pdt),
            "gn_w": ParamDef((D,), (None,), pdt, "ones"),
            "gn_b": ParamDef((D,), (None,), pdt, "zeros"),
        },
        "cm": {
            "mu_k": ParamDef((D,), (None,), pdt, "zeros"),
            "mu_r": ParamDef((D,), (None,), pdt, "zeros"),
            "wk": ParamDef((D, F), ("embed", "ff"), pdt),
            "wv": ParamDef((F, D), ("ff", "embed"), pdt),
            "wr": ParamDef((D, D), ("embed", None), pdt),
        },
    }


def rwkv_param_defs(cfg: ArchConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    V, D, L = cfg.vocab_size, cfg.d_model, cfg.n_layers
    is_def = lambda x: isinstance(x, ParamDef)
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), pdt),
        "ln0_w": ParamDef((D,), (None,), pdt, "ones"),
        "ln0_b": ParamDef((D,), (None,), pdt, "zeros"),
        "blocks": jax.tree_util.tree_map(
            lambda d: _stack(L, d), rwkv_layer_defs(cfg, pdt), is_leaf=is_def
        ),
        "final_ln_w": ParamDef((D,), (None,), pdt, "ones"),
        "final_ln_b": ParamDef((D,), (None,), pdt, "zeros"),
        "unembed": ParamDef((D, V), ("embed", "vocab"), pdt),
    }


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _ddlerp(p, x, sx):
    """Data-dependent token-shift interpolation → (xw, xk, xv, xr, xg)."""
    dt = x.dtype
    r5 = p["maa_w1"].shape[1] // 5
    base = x + sx * p["mu_x"].astype(dt)
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, p["maa_w1"].astype(dt)))
    lora = lora.reshape(*lora.shape[:-1], 5, r5)
    delta = jnp.einsum("bsir,ird->bsid", lora, p["maa_w2"].astype(dt))  # (B,S,5,D)
    mixes = p["mu_rkvgw"].astype(dt)[None, None] + delta  # (B,S,5,D)
    return tuple(x + sx * mixes[:, :, i] for i in range(5))


def time_mix(p, x, cfg: ArchConfig, shift_state=None, wkv_state=None):
    """x: (B,S,D). Returns (y, new_shift, new_wkv)."""
    dt = x.dtype
    B, S, D = x.shape
    H = D // cfg.rwkv_head_size
    dk = cfg.rwkv_head_size
    if shift_state is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        x_prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    sx = x_prev - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt)))
    # data-dependent decay w_t ∈ (0,1): exp(-exp(w0 + lora(xw)))
    dlora = jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["ww1"].astype(dt))),
        p["ww2"].astype(dt),
    )
    w = jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + dlora.astype(jnp.float32)))

    def heads(t):  # (B,S,D) → (B,H,S,dk)
        return t.reshape(B, S, H, -1).transpose(0, 2, 1, 3)

    r_h, k_h, v_h, w_h = heads(r), heads(k), heads(v), heads(w.astype(dt))
    r_h = shard(r_h, "batch", "heads", None, None)
    k_h = shard(k_h, "batch", "heads", None, None)
    v_h = shard(v_h, "batch", "heads", None, None)
    if wkv_state is None:
        wkv_state = jnp.zeros((B, H, dk, dk), jnp.float32)
    if S == 1:
        y, s_new = wkv6_step(
            r_h[:, :, 0], k_h[:, :, 0], v_h[:, :, 0], w_h[:, :, 0], p["u"], wkv_state
        )
        y = y[:, :, None]
    elif cfg.use_pallas and S % 64 == 0:
        from repro.kernels import ops as kops

        y, s_new = kops.wkv6(r_h, k_h, v_h, w_h, p["u"], wkv_state, use_pallas=True)
    else:
        y, s_new = wkv6_chunked(r_h, k_h, v_h, w_h, p["u"], wkv_state)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, D)
    # per-head group norm, then SiLU gate
    yh = y.reshape(B, S, H, dk).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, D) * p["gn_w"].astype(jnp.float32) + p["gn_b"].astype(jnp.float32)
    y = (y.astype(dt)) * g
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(dt))
    return out, x[:, -1], s_new


def channel_mix(p, x, shift_state=None):
    dt = x.dtype
    if shift_state is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        x_prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    sx = x_prev - x
    xk = x + sx * p["mu_k"].astype(dt)
    xr = x + sx * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(dt))))
    k = shard(k, "batch", None, "ff")
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(dt))
    return jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt))) * kv, x[:, -1]


def rwkv_block(p, x, cfg: ArchConfig, cache=None):
    new_cache = {}
    tm_shift = cache["tm_shift"] if cache else None
    wkv = cache["wkv"] if cache else None
    cm_shift = cache["cm_shift"] if cache else None
    y, new_cache["tm_shift"], new_cache["wkv"] = time_mix(
        p["tm"], layer_norm(x, p["ln1_w"], p["ln1_b"]), cfg, tm_shift, wkv
    )
    x = x + y
    y, new_cache["cm_shift"] = channel_mix(
        p["cm"], layer_norm(x, p["ln2_w"], p["ln2_b"]), cm_shift
    )
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Model-level entry points
# ---------------------------------------------------------------------------


def rwkv_forward(params, cfg: ArchConfig, tokens, collect_cache: bool = False):
    h = embed_tokens(params, cfg, tokens)
    h = layer_norm(h, params["ln0_w"], params["ln0_b"])

    def body(h, layer_params):
        h, c = rwkv_block(layer_params, h, cfg)
        return h, (c if collect_cache else None)

    body = remat_wrap(body, cfg)
    h, caches = jax.lax.scan(body, h, params["blocks"])
    h = layer_norm(h, params["final_ln_w"], params["final_ln_b"])
    logits = unembed(params, cfg, h)
    return (logits, caches) if collect_cache else logits


def rwkv_loss(params, cfg: ArchConfig, batch):
    logits = rwkv_forward(params, cfg, batch["tokens"])
    loss, metrics = cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
    return loss, metrics


def rwkv_cache_defs(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    """Recurrent state is O(1) in sequence length — this is why rwkv6 is the
    long_500k arch."""
    D, L = cfg.d_model, cfg.n_layers
    H = D // cfg.rwkv_head_size
    dk = cfg.rwkv_head_size
    dt = jnp.dtype(cfg.dtype)
    return {
        "tm_shift": jax.ShapeDtypeStruct((L, batch, D), dt),
        "cm_shift": jax.ShapeDtypeStruct((L, batch, D), dt),
        "wkv": jax.ShapeDtypeStruct((L, batch, H, dk, dk), jnp.float32),
    }


def rwkv_cache_logical(cfg: ArchConfig) -> dict:
    return {
        "tm_shift": ("layers", "batch", None),
        "cm_shift": ("layers", "batch", None),
        "wkv": ("layers", "batch", "heads", None, None),
    }


def rwkv_decode_step(params, cfg: ArchConfig, cache, tokens, pos):
    del pos  # recurrent state is position-free
    h = embed_tokens(params, cfg, tokens)
    h = layer_norm(h, params["ln0_w"], params["ln0_b"])

    def body(h, inp):
        layer_params, layer_cache = inp
        h, new_cache = rwkv_block(layer_params, h, cfg, layer_cache)
        return h, new_cache

    h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache))
    h = layer_norm(h, params["final_ln_w"], params["final_ln_b"])
    return unembed(params, cfg, h), new_cache
