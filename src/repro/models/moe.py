"""Mixture-of-Experts family (DeepSeek-MoE fine-grained, Kimi-K2 scale).

GShard/MaxText-style capacity-based dispatch: tokens are grouped
(``moe_group_tokens`` per group), routed top-k with a per-expert capacity
``C = ceil(k·N/E · capacity_factor)``, dispatched to experts with one-hot
dispatch/combine einsums, and the expert dim is sharded over the ``model``
mesh axis (expert parallelism — GSPMD materialises the all-to-all).

Shared experts (DeepSeek's "2 shared + 64 routed") run densely for every
token. Leading ``n_dense_layers`` use an ordinary dense MLP (DeepSeek/Kimi
put a dense layer first for routing stability).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ParamDef, cross_entropy, rms_norm, shard, swiglu
from .config import ArchConfig
from .transformer import (
    _stack,
    attn_defs,
    block_defs,
    dense_block,
    embed_tokens,
    gqa_decode_attn,
    mlp_defs,
    remat_wrap,
    unembed,
)

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def moe_ffn_defs(cfg: ArchConfig, pdt) -> dict:
    D, E, Fm = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    defs = {
        "router": ParamDef((D, E), ("embed", None), pdt, scale=0.1),
        "wg": ParamDef((E, D, Fm), ("experts", "embed", None), pdt),
        "wi": ParamDef((E, D, Fm), ("experts", "embed", None), pdt),
        "wo": ParamDef((E, Fm, D), ("experts", None, "embed"), pdt),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * cfg.moe_d_ff
        defs["shared"] = {
            "wg": ParamDef((D, Fs), ("embed", "ff"), pdt),
            "wi": ParamDef((D, Fs), ("embed", "ff"), pdt),
            "wo": ParamDef((Fs, D), ("ff", "embed"), pdt),
        }
    return defs


def moe_block_defs(cfg: ArchConfig, pdt) -> dict:
    D = cfg.d_model
    return {
        "ln1": ParamDef((D,), (None,), pdt, "ones"),
        "attn": attn_defs(cfg, pdt),
        "ln2": ParamDef((D,), (None,), pdt, "ones"),
        "moe": moe_ffn_defs(cfg, pdt),
    }


def moe_param_defs(cfg: ArchConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    V, D = cfg.vocab_size, cfg.d_model
    n_moe = cfg.n_layers - cfg.n_dense_layers
    is_def = lambda x: isinstance(x, ParamDef)
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), pdt),
        "moe_blocks": jax.tree_util.tree_map(
            lambda d: _stack(n_moe, d), moe_block_defs(cfg, pdt), is_leaf=is_def
        ),
        "final_ln": ParamDef((D,), (None,), pdt, "ones"),
        "unembed": ParamDef((D, V), ("embed", "vocab"), pdt),
    }
    if cfg.n_dense_layers:
        defs["dense_blocks"] = jax.tree_util.tree_map(
            lambda d: _stack(cfg.n_dense_layers, d), block_defs(cfg, pdt), is_leaf=is_def
        )
    return defs


# ---------------------------------------------------------------------------
# Routing + dispatch
# ---------------------------------------------------------------------------


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = math.ceil(cfg.top_k * tokens_per_group / cfg.n_experts * cfg.capacity_factor)
    return max(4, int(c))


def top_k_routing(logits, cfg: ArchConfig, cap: int):
    """GShard top-k with per-slot positions. logits: (G, N, E) f32.

    Returns dispatch (G,N,E,C) bool-as-dtype, combine (G,N,E,C) f32,
    aux load-balance loss (scalar).
    """
    G, N, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)  # (G,N,k)
    # DeepSeek normalises the selected gates to sum to 1.
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    counts = jnp.zeros((G, E), jnp.int32)
    dispatch = jnp.zeros((G, N, E, cap), jnp.bool_)
    combine = jnp.zeros((G, N, E, cap), jnp.float32)
    for j in range(cfg.top_k):  # k is small and static — unrolled
        mask_j = jax.nn.one_hot(gate_idx[..., j], E, dtype=jnp.int32)  # (G,N,E)
        pos_j = counts[:, None, :] + jnp.cumsum(mask_j, axis=1) - mask_j
        keep = (pos_j < cap) & (mask_j > 0)  # (G,N,E)
        pos_oh = jax.nn.one_hot(pos_j, cap, dtype=jnp.bool_) & keep[..., None]
        dispatch = dispatch | pos_oh
        combine = combine + pos_oh * gate_vals[..., j, None, None]
        counts = counts + mask_j.sum(axis=1)

    # load-balance auxiliary loss (Switch/GShard): E * Σ_e f_e · p_e
    f = dispatch.any(-1).astype(jnp.float32).mean(axis=1)  # (G,E) fraction routed
    p = probs.mean(axis=1)  # (G,E) mean router prob
    aux = E * jnp.mean(jnp.sum(f * p, axis=-1))
    return dispatch, combine, aux


def moe_ffn(p, x, cfg: ArchConfig):
    """x: (B, S, D) → (B, S, D), plus aux loss."""
    dt = jnp.dtype(cfg.dtype)
    B, S, D = x.shape
    N = min(cfg.moe_group_tokens, B * S)
    G = (B * S) // N
    assert (B * S) % N == 0, (B, S, N)
    cap = capacity(cfg, N)

    xg = x.reshape(G, N, D)
    xg = shard(xg, "batch", None, None)
    logits = jnp.einsum(
        "gnd,de->gne", xg, p["router"].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    dispatch, combine, aux = top_k_routing(logits, cfg, cap)

    # dispatch → (E, G, C, D): expert dim sharded over `model` (EP all-to-all)
    expert_in = jnp.einsum("gnec,gnd->egcd", dispatch.astype(dt), xg)
    expert_in = shard(expert_in, "experts", "batch", None, None)
    g = jnp.einsum("egcd,edf->egcf", expert_in, p["wg"].astype(dt))
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["wi"].astype(dt))
    h = jax.nn.silu(g) * h
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(dt))
    expert_out = shard(expert_out, "experts", "batch", None, None)
    y = jnp.einsum("gnec,egcd->gnd", combine.astype(dt), expert_out)
    y = y.reshape(B, S, D)

    if cfg.n_shared_experts:
        sh = p["shared"]
        y = y + swiglu(x, sh["wg"], sh["wi"], sh["wo"], dt)
    return shard(y, "batch", None, None), aux


def moe_block(p, carry, cfg: ArchConfig, positions):
    x, aux_acc = carry
    from .transformer import gqa_attention, mla_attention

    attn_fn = mla_attention if cfg.attention == "mla" else gqa_attention
    x = x + attn_fn(p["attn"], rms_norm(x, p["ln1"]), cfg, positions)
    y, aux = moe_ffn(p["moe"], rms_norm(x, p["ln2"]), cfg)
    return x + y, aux_acc + aux


# ---------------------------------------------------------------------------
# Forward / loss / decode
# ---------------------------------------------------------------------------


def moe_forward(params, cfg: ArchConfig, tokens):
    h = embed_tokens(params, cfg, tokens)
    S = h.shape[1]
    positions = jnp.arange(S)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.n_dense_layers:
        from .transformer import run_stack

        h = run_stack(
            params["dense_blocks"], h, cfg,
            lambda p, y: dense_block(p, y, cfg, positions),
        )

    def body(carry, layer_params):
        return moe_block(layer_params, carry, cfg, positions), None

    body = remat_wrap(body, cfg)
    (h, aux_total), _ = jax.lax.scan(body, (h, aux_total), params["moe_blocks"])
    h = rms_norm(h, params["final_ln"])
    return unembed(params, cfg, h), aux_total


def moe_loss(params, cfg: ArchConfig, batch):
    logits, aux = moe_forward(params, cfg, batch["tokens"])
    loss, metrics = cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
    n_moe = cfg.n_layers - cfg.n_dense_layers
    aux_mean = aux / max(1, n_moe)
    metrics["aux_loss"] = aux_mean
    return loss + cfg.router_aux_weight * aux_mean, metrics


def moe_prefill(params, cfg: ArchConfig, tokens):
    """Prefill with KV-cache collection (attention KV only; MoE is stateless)."""
    from .transformer import gqa_attention, run_stack

    h = embed_tokens(params, cfg, tokens)
    S = h.shape[1]
    positions = jnp.arange(S)
    dt = jnp.dtype(cfg.dtype)
    cache = {}

    if cfg.n_dense_layers:

        def dense_body(h, p):
            y, kv = gqa_attention(p["attn"], rms_norm(h, p["ln1"]), cfg, positions, collect=True)
            h = h + y
            m = p["mlp"]
            h = h + swiglu(rms_norm(h, p["ln2"]), m["wg"], m["wi"], m["wo"], dt)
            return h, kv

        h, cache["dense"] = jax.lax.scan(
            remat_wrap(dense_body, cfg), h, params["dense_blocks"]
        )

    def moe_body(h, p):
        y, kv = gqa_attention(p["attn"], rms_norm(h, p["ln1"]), cfg, positions, collect=True)
        h = h + y
        y2, _aux = moe_ffn(p["moe"], rms_norm(h, p["ln2"]), cfg)
        return h + y2, kv

    h, cache["moe"] = jax.lax.scan(remat_wrap(moe_body, cfg), h, params["moe_blocks"])
    h = rms_norm(h[:, -1:], params["final_ln"])
    return unembed(params, cfg, h), cache


def moe_decode_ffn(p, x, cfg: ArchConfig):
    """Decode-time MoE: one group over the (tiny) token batch.

    Reuses the training dispatch math with G=1, N=B·S — per-expert capacity
    is then ``ceil(k·B/E·cf)`` so expert compute stays O(B·k·D·F), not
    O(B·E·D·F). The group dim (size 1) is left unsharded; the token dim is
    sharded over the batch axes instead.
    """
    dt = jnp.dtype(cfg.dtype)
    B, S, D = x.shape
    N = B * S
    cap = capacity(cfg, N)
    xg = x.reshape(1, N, D)
    xg = shard(xg, None, "batch", None)
    logits = jnp.einsum(
        "gnd,de->gne", xg, p["router"].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    dispatch, combine, _aux = top_k_routing(logits, cfg, cap)
    expert_in = jnp.einsum("gnec,gnd->egcd", dispatch.astype(dt), xg)
    expert_in = shard(expert_in, "experts", None, None, None)
    g = jnp.einsum("egcd,edf->egcf", expert_in, p["wg"].astype(dt))
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["wi"].astype(dt))
    h = jax.nn.silu(g) * h
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(dt))
    expert_out = shard(expert_out, "experts", None, None, None)
    y = jnp.einsum("gnec,egcd->gnd", combine.astype(dt), expert_out).reshape(B, S, D)
    if cfg.n_shared_experts:
        sh = p["shared"]
        y = y + swiglu(x, sh["wg"], sh["wi"], sh["wo"], dt)
    return y


def moe_cache_defs(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    from .transformer import dense_cache_defs

    L, K = cfg.n_layers, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    out = {
        "moe": {
            "k": jax.ShapeDtypeStruct(
                (cfg.n_layers - cfg.n_dense_layers, batch, K, max_seq, hd), dt
            ),
            "v": jax.ShapeDtypeStruct(
                (cfg.n_layers - cfg.n_dense_layers, batch, K, max_seq, hd), dt
            ),
        }
    }
    if cfg.n_dense_layers:
        out["dense"] = {
            "k": jax.ShapeDtypeStruct((cfg.n_dense_layers, batch, K, max_seq, hd), dt),
            "v": jax.ShapeDtypeStruct((cfg.n_dense_layers, batch, K, max_seq, hd), dt),
        }
    return out


def moe_cache_logical(cfg: ArchConfig) -> dict:
    leaf = {"k": ("layers", "batch", None, "kv_seq", None),
            "v": ("layers", "batch", None, "kv_seq", None)}
    out = {"moe": dict(leaf)}
    if cfg.n_dense_layers:
        out["dense"] = dict(leaf)
    return out


def moe_decode_step(params, cfg: ArchConfig, cache, tokens, pos):
    h = embed_tokens(params, cfg, tokens)
    dt = jnp.dtype(cfg.dtype)

    new_cache = {}
    if cfg.n_dense_layers:

        def dense_body(h, inp):
            p, c = inp
            y, nc = gqa_decode_attn(p["attn"], c, rms_norm(h, p["ln1"]), cfg, pos)
            h = h + y
            m = p["mlp"]
            h = h + swiglu(rms_norm(h, p["ln2"]), m["wg"], m["wi"], m["wo"], dt)
            return h, nc

        h, new_cache["dense"] = jax.lax.scan(
            dense_body, h, (params["dense_blocks"], cache["dense"])
        )

    def moe_body(h, inp):
        p, c = inp
        y, nc = gqa_decode_attn(p["attn"], c, rms_norm(h, p["ln1"]), cfg, pos)
        h = h + y
        h = h + moe_decode_ffn(p["moe"], rms_norm(h, p["ln2"]), cfg)
        return h, nc

    h, new_cache["moe"] = jax.lax.scan(
        moe_body, h, (params["moe_blocks"], cache["moe"])
    )
    h = rms_norm(h, params["final_ln"])
    return unembed(params, cfg, h), new_cache
