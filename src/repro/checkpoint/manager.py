"""Checkpoint manager: atomic, async, integrity-checked, elastic.

Layout (one directory per step)::

    <dir>/step_000000400/
        MANIFEST.json          # tree structure, shapes, dtypes, crc32s
        leaf_00000.npy         # one file per pytree leaf
        ...
    <dir>/step_000000400.tmp/  # never visible as a valid checkpoint

Design points, sized for the 1000+-node deployment this framework targets:

* **Atomicity** — writes go to ``<step>.tmp`` and are ``rename``d into
  place only after every leaf + manifest is fsync-complete. A job killed
  mid-save (preemption, node failure, eco-preemption at a peak-hours
  boundary) can never leave a half-checkpoint that restore would trust.
* **Async save** — ``save(..., blocking=False)`` snapshots the tree to host
  memory (device_get) and writes on a background thread; the training loop
  loses only the device→host copy time, not the filesystem time. ``wait()``
  joins the writer (called before exit and before the next async save).
* **Integrity** — every leaf records a crc32; restore verifies and raises
  on corruption (a torn page on a parallel filesystem must not silently
  poison a 1000-node restart).
* **Elastic restore** — leaves are stored *unsharded* (gathered). Restoring
  onto a different mesh/host count just re-applies that run's shardings —
  ``restore(..., shardings=tree)`` places each leaf directly onto the new
  topology. DP-resize, TP-resize and pod-count changes all reduce to "load
  + reshard", which is exactly what the elastic-rescale test exercises.
  On a real multi-host fleet the gather happens per-host through the same
  API (jax fetches only addressable shards); the file format is unchanged.
* **Retention** — ``keep`` newest checkpoints survive; older ones are
  removed after a successful save (never before).
* **Resume anything** — the manifest carries an opaque ``extra`` dict
  (data-pipeline cursor, RNG key, eco-preemption flag, ...) so a restart
  resumes the *whole job state*, not just the weights.
"""

from __future__ import annotations

import json
import shutil
import threading
import zlib
from pathlib import Path

import jax
import numpy as np

MANIFEST = "MANIFEST.json"
_FORMAT_VERSION = 1


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including ml_dtypes extras (bfloat16, fp8...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [
        jax.tree_util.keystr(kp)
        for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]
    return leaves, paths, treedef


def save_tree(path: Path, tree, *, extra: dict | None = None) -> None:
    """Write a pytree of arrays to ``path`` (must not exist) atomically."""
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, paths, _ = _flatten_with_paths(tree)
    records = []
    for i, (leaf, keypath) in enumerate(zip(leaves, paths)):
        arr = np.asarray(jax.device_get(leaf))
        # raw little-endian bytes + manifest (shape, dtype name): unlike .npy
        # this round-trips ml_dtypes (bfloat16/fp8) exactly
        fname = f"leaf_{i:05d}.bin"
        (tmp / fname).write_bytes(np.ascontiguousarray(arr).tobytes())
        records.append(
            {
                "index": i,
                "keypath": keypath,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": _crc32(arr),
            }
        )
    manifest = {
        "format_version": _FORMAT_VERSION,
        "n_leaves": len(records),
        "leaves": records,
        "extra": extra or {},
    }
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)  # atomic publish


def restore_tree(path: Path, target_tree, *, shardings=None, verify: bool = True):
    """Load a checkpoint into the structure of ``target_tree``.

    ``target_tree`` supplies the pytree structure (its leaf values are
    ignored — ShapeDtypeStructs are fine). ``shardings``: optional matching
    tree of :class:`jax.sharding.Sharding` — each leaf is placed onto it
    (the elastic-reshard path). Returns ``(tree, extra)``.
    """
    path = Path(path)
    manifest = json.loads((path / MANIFEST).read_text())
    leaves, _, treedef = _flatten_with_paths(target_tree)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves; "
            f"target structure has {len(leaves)}"
        )
    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
        if len(sh_leaves) != len(leaves):
            raise ValueError("shardings tree does not match target structure")
    out = []
    for rec in manifest["leaves"]:
        raw = (path / rec["file"]).read_bytes()
        arr = np.frombuffer(raw, dtype=_np_dtype(rec["dtype"])).reshape(
            rec["shape"]
        )
        if verify and _crc32(arr) != rec["crc32"]:
            raise IOError(f"checksum mismatch for {rec['keypath']} in {path}")
        want = leaves[rec["index"]]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"{rec['keypath']}: checkpoint shape {arr.shape} != "
                f"target {tuple(want.shape)}"
            )
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[rec["index"]]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest.get("extra", {})


class CheckpointManager:
    """Step-indexed checkpoints with retention and async writes."""

    def __init__(self, directory, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = int(keep)
        self._writer: threading.Thread | None = None
        self._writer_error: BaseException | None = None

    # -- paths -----------------------------------------------------------------

    def step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:09d}"

    def all_steps(self) -> list[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".tmp") or not (p / MANIFEST).exists():
                continue
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save --------------------------------------------------------------------

    def save(self, step: int, tree, *, extra: dict | None = None,
             blocking: bool = True) -> Path:
        """Checkpoint ``tree`` at ``step``. Non-blocking saves snapshot to
        host memory first, then write on a background thread."""
        self.wait()  # one async save in flight at a time
        target = self.step_dir(step)
        # snapshot with an explicit copy: device_get of host-resident arrays
        # can alias the caller's buffer, which the training loop donates/reuses
        host_tree = jax.tree_util.tree_map(
            lambda x: np.array(jax.device_get(x), copy=True), tree
        )

        def write():
            try:
                save_tree(target, host_tree, extra=extra)
                self._gc()
            except BaseException as e:  # re-raised in wait()
                self._writer_error = e

        if blocking:
            write()
            self._raise_writer_error()
        else:
            self._writer = threading.Thread(target=write, daemon=True, name="ckpt-writer")
            self._writer.start()
        return target

    def wait(self) -> None:
        """Join any in-flight async save (re-raises its error, if any)."""
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_writer_error()

    def _raise_writer_error(self):
        if self._writer_error is not None:
            err, self._writer_error = self._writer_error, None
            raise err

    # -- restore -----------------------------------------------------------------

    def restore(self, target_tree, *, step: int | None = None, shardings=None):
        """Restore ``step`` (default: latest). Returns (tree, extra, step)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.dir}")
        self.wait()
        tree, extra = restore_tree(self.step_dir(step), target_tree, shardings=shardings)
        return tree, extra, step

    # -- retention ------------------------------------------------------------------

    def _gc(self) -> None:
        steps = self.all_steps()
        for step in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.step_dir(step), ignore_errors=True)
        # clear orphaned tmp dirs from crashed saves
        for tmp in self.dir.glob("step_*.tmp"):
            shutil.rmtree(tmp, ignore_errors=True)
