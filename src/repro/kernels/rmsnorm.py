"""Pallas TPU fused RMSNorm kernel.

RMSNorm is memory-bound: unfused XLA issues read(x) → mean-of-squares →
read(x) again → scale, plus a weight broadcast. The fused kernel streams
each (block_rows, D) tile through VMEM exactly once: one pass computes the
f32 row moments and writes the scaled result — HBM traffic = x-in + y-out,
the streaming minimum.

Grid = (rows/block_rows,), fully parallel. D stays unblocked (the assigned
archs top out at D=12288 → a 128×12288 f32 tile is 6 MB, within VMEM; the
row-block shrinks automatically for wider models).

Validated in interpret mode against :func:`repro.kernels.ref.rmsnorm_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x, weight, *, eps: float = 1e-6, block_rows: int = 128,
                   interpret: bool = True):
    """x: (..., D); weight: (D,). Fused row-wise RMSNorm."""
    orig_shape = x.shape
    D = orig_shape[-1]
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    br = min(block_rows, N)
    # keep the f32 tile under ~8 MB of VMEM for very wide models
    while br > 1 and br * D * 4 > 8 * 1024 * 1024:
        br //= 2
    pad = (-N) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xf.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(xf, weight)
    return out[:N].reshape(orig_shape)
