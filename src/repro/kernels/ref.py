"""Pure-jnp oracles for every Pallas kernel.

Each function is the *semantic definition* the kernel must match; the
per-kernel test sweeps shapes/dtypes and asserts allclose against these.
They are deliberately naive (materialise the full score matrix, step the
recurrence token-by-token) — clarity over speed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window: int = 0, logit_cap: float = 0.0):
    """q: (B,Hq,Sq,d); k,v: (B,Hkv,Skv,d); GQA via Hq = G·Hkv. O(S²) softmax."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d**-0.5)
    if logit_cap > 0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def wkv6_ref(r, k, v, w, u, s0):
    """Token-by-token RWKV6 recurrence (the definition, O(T) sequential).

    r,k,w: (B,H,T,dk); v: (B,H,T,dv); u: (H,dk); s0: (B,H,dk,dv) f32.
        y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    f32 = jnp.float32
    rT = r.astype(f32).transpose(2, 0, 1, 3)
    kT = k.astype(f32).transpose(2, 0, 1, 3)
    vT = v.astype(f32).transpose(2, 0, 1, 3)
    wT = w.astype(f32).transpose(2, 0, 1, 3)
    uf = u.astype(f32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhiv->bhv", rt, s + uf[None, :, :, None] * kv)
        return wt[..., None] * s + kv, y

    s_fin, ys = jax.lax.scan(step, s0.astype(f32), (rT, kT, vT, wT))
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), s_fin


def lru_ref(a, b, h0):
    """Linear recurrence h_t = a_t ⊙ h_{t-1} + b_t (token-by-token).

    a, b: (B, T, W); h0: (B, W) f32. Returns (h_seq (B,T,W), h_final).
    """
    f32 = jnp.float32

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    aT = a.astype(f32).transpose(1, 0, 2)
    bT = b.astype(f32).transpose(1, 0, 2)
    h_fin, hs = jax.lax.scan(step, h0.astype(f32), (aT, bT))
    return hs.transpose(1, 0, 2).astype(a.dtype), h_fin


def moe_gating_ref(logits, *, top_k: int, capacity: int, renormalise=True):
    """Token-by-token gating oracle: softmax → iterated argmax → capacity.

    logits: (G, N, E) → (idx, gate, pos) each (G, N, k); pos = -1 = dropped.
    Sequential over tokens so the capacity semantics are unmistakable.
    """
    import numpy as np

    logits = np.asarray(logits, np.float32)
    G, N, E = logits.shape
    k = top_k
    idx = np.zeros((G, N, k), np.int32)
    gate = np.zeros((G, N, k), np.float32)
    pos = np.full((G, N, k), -1, np.int32)
    for g in range(G):
        # picks: iterated argmax per token (stable ties: lowest expert id)
        avail = np.exp(logits[g] - logits[g].max(-1, keepdims=True))
        avail = avail / avail.sum(-1, keepdims=True)
        for n in range(N):
            row = avail[n].copy()
            for j in range(k):
                e = int(np.argmax(row))
                idx[g, n, j] = e
                gate[g, n, j] = row[e]
                row[e] = -np.inf
        if renormalise:
            gate[g] = gate[g] / np.maximum(gate[g].sum(-1, keepdims=True), 1e-9)
        # capacity slots: j-major (GShard — rank-0 picks claim slots before
        # any rank-1 pick), tokens in group order within each rank
        counts = np.zeros(E, np.int64)
        for j in range(k):
            for n in range(N):
                e = idx[g, n, j]
                if counts[e] < capacity:
                    pos[g, n, j] = counts[e]
                counts[e] += 1
    return jnp.asarray(idx), jnp.asarray(gate), jnp.asarray(pos)


def rmsnorm_ref(x, weight, eps: float = 1e-6):
    """Row-wise RMSNorm in f32, cast back to x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return y.astype(x.dtype)
