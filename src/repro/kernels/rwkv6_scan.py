"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked parallel form).

The recurrence (per head, dk×dv state S):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)

CUDA RWKV ships a hand-written sequential kernel (one thread per channel).
The TPU-native adaptation instead processes the sequence in chunks of C
tokens: within a chunk, all pairwise decay ratios
``exp(lc_excl[t] - lc[s]) (s < t)`` form a (C, C, dk) tensor — every term is
≤ 1 because decays are in (0,1), so the exponentials are numerically safe —
and the in-chunk output is two MXU contractions instead of C sequential
vector ops. The cross-chunk state is carried in VMEM scratch across the
sequential chunk grid dimension (grid = (B, H, T/C), last dim sequential on
TPU).

VMEM budget per step (C=64, dk=dv=64, f32): tiles ~192 KB, the pairwise
ratio tensor 1 MB, state 16 KB — comfortably inside a v5e core's ~16 MB.

Validated in interpret mode against the token-by-token oracle
:func:`repro.kernels.ref.wkv6_ref` (forward); the training path uses the
identical-math XLA form in :mod:`repro.models.rwkv6` (jax.checkpoint-ed),
so kernel and model cross-check each other.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                y_ref, sout_ref, s_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)  # (C, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)  # (C, dv)
    w = w_ref[0, 0].astype(jnp.float32)  # (C, dk), in (0,1)
    u = u_ref[0].astype(jnp.float32)  # (dk,)
    s = s_ref[...]  # (dk, dv)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    lc = jnp.cumsum(logw, axis=0)  # inclusive (C, dk)
    lc_excl = lc - logw

    # in-chunk pairwise term: A[t,s] = Σ_i r[t,i] k[s,i] e^{lc_excl[t,i]-lc[s,i]}
    ratio = jnp.exp(lc_excl[:, None, :] - lc[None, :, :])  # (C, C, dk), ≤1 under tri
    C = chunk
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1) < jax.lax.broadcasted_iota(
        jnp.int32, (C, C), 0
    )  # s < t
    A = jnp.einsum(
        "ti,tsi,si->ts", r, ratio, k, preferred_element_type=jnp.float32
    )
    A = jnp.where(tri, A, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (C,)
    y = (
        jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + diag[:, None] * v
        + jax.lax.dot_general(r * jnp.exp(lc_excl), s, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S' = e^{lc[-1]} ⊙ S + Σ_s (k_s e^{lc[-1]-lc[s]}) v_s^T
    decay_all = jnp.exp(lc[-1])  # (dk,)
    k_scaled = k * jnp.exp(lc[-1][None, :] - lc)  # (C, dk), ≤1
    s_new = decay_all[:, None] * s + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sout_ref[0, 0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, s0, *, chunk: int = 64, interpret: bool = True):
    """r,k,w: (B,H,T,dk); v: (B,H,T,dv); u: (H,dk); s0: (B,H,dk,dv) f32.

    Returns (y: (B,H,T,dv) in r.dtype, s_final: (B,H,dk,dv) f32).
    """
    B, H, T, dk = r.shape
    dv = v.shape[-1]
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    n = T // C
    grid = (B, H, n)

    kernel = functools.partial(_wkv_kernel, chunk=C, n_chunks=n)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, C, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C, dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, dk), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, C, dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, dv), r.dtype),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_fin
