"""Pallas TPU kernel for the RG-LRU linear recurrence.

    h_t = a_t ⊙ h_{t-1} + b_t        a, b: (B, T, W);  h_0: (B, W)

The Griffin paper fuses this into a custom GPU scan kernel; on TPU the
natural blocking is (sequence chunks × width tiles): grid =
(B, W/bw, T/C) with the chunk dimension sequential, carrying the (1, bw)
state in VMEM scratch. Within a chunk the recurrence runs as a C-step
``fori_loop`` of pure VPU element-wise ops on rows already resident in
VMEM — there is no matmul here, so the MXU is idle by construction and the
kernel's job is purely to keep HBM traffic at the 2·C·bw streaming minimum
(a,b in; h out) instead of the scan's per-step round trips.

Width tiles are independent → the W/bw grid dimension is parallel
("embarrassingly channel-parallel", matching the GPU kernel's
thread-per-channel layout).

Validated in interpret mode against :func:`repro.kernels.ref.lru_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, h0_ref, y_ref, hout_ref, h_ref,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)  # (1, bw)

    a = a_ref[0].astype(jnp.float32)  # (C, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t][None, :] * h + b[t][None, :]
        y_ref[0, t] = h[0].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def lru_pallas(a, b, h0, *, chunk: int = 128, block_w: int = 512,
               interpret: bool = True):
    """a, b: (B, T, W); h0: (B, W). Returns (h_seq (B,T,W) in a.dtype, h_final f32)."""
    B, T, W = a.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    bw = min(block_w, W)
    assert W % bw == 0, (W, bw)
    n = T // C
    grid = (B, W // bw, n)

    kernel = functools.partial(_lru_kernel, chunk=C, n_chunks=n)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, bw), lambda b_, w_, c: (b_, c, w_)),
            pl.BlockSpec((1, C, bw), lambda b_, w_, c: (b_, c, w_)),
            pl.BlockSpec((1, bw), lambda b_, w_, c: (b_, w_)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, bw), lambda b_, w_, c: (b_, c, w_)),
            pl.BlockSpec((1, bw), lambda b_, w_, c: (b_, w_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, h0)
    return y, h_fin
