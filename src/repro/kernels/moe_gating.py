"""Pallas TPU kernel: fused MoE gating (softmax → top-k → capacity slots).

One kernel invocation routes one dispatch group: from router logits (N, E)
it produces, entirely in VMEM,

    idx  (N, k) int32  — expert chosen per slot (iterated-argmax order,
                          matching jax.lax.top_k's stable tie-breaking)
    gate (N, k) f32    — softmax gate weights renormalised over the k picks
    pos  (N, k) int32  — capacity slot within the expert's buffer, or -1
                          when the expert is over capacity (token dropped)

The XLA path materialises probs → top_k → k one-hot (N, E) masks → k
cumsums at HBM-visible boundaries; fused, the (N, E) intermediates stay in
VMEM (N=1024, E=384 f32 ≈ 1.6 MB/tile). Grid = (G,), fully parallel —
capacity state is per-group by construction (GShard semantics).

Dispatch/combine stay as the einsum path: per §Perf cell 3 the AR-combined
one-hot dispatch is wire-optimal at EP=16/top-8, so the *gating decision* is
the part worth fusing, not the data movement.

Validated in interpret mode against :func:`repro.kernels.ref.moe_gating_ref`
and cross-checked against :func:`repro.models.moe.top_k_routing` (the
dispatch/combine tensors rebuilt from (idx, gate, pos) must match exactly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _gating_kernel(logits_ref, idx_ref, gate_ref, pos_ref,
                   *, top_k: int, capacity: int, renormalise: bool):
    x = logits_ref[0].astype(jnp.float32)  # (N, E)
    N, E = x.shape
    # softmax over experts
    m = x.max(axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    probs = p / p.sum(axis=-1, keepdims=True)

    counts = jnp.zeros((E,), jnp.int32)
    remaining = probs
    gates = []
    for j in range(top_k):  # static k → unrolled
        g_j = remaining.max(axis=-1)  # (N,)
        e_j = jnp.argmax(remaining, axis=-1).astype(jnp.int32)  # first max wins
        onehot = jax.nn.one_hot(e_j, E, dtype=jnp.int32)  # (N, E)
        # capacity slot: tokens earlier in the group claim lower slots
        slot_grid = counts[None, :] + jnp.cumsum(onehot, axis=0) - onehot
        slot = jnp.sum(slot_grid * onehot, axis=-1)  # (N,)
        kept = slot < capacity
        idx_ref[0, :, j] = e_j
        pos_ref[0, :, j] = jnp.where(kept, slot, -1).astype(jnp.int32)
        gates.append(g_j)
        counts = counts + onehot.sum(axis=0)
        remaining = jnp.where(onehot > 0, -jnp.inf, remaining)
    gate = jnp.stack(gates, axis=-1)  # (N, k)
    if renormalise:
        gate = gate / jnp.maximum(gate.sum(axis=-1, keepdims=True), 1e-9)
    gate_ref[0] = gate


@functools.partial(
    jax.jit, static_argnames=("top_k", "capacity", "renormalise", "interpret")
)
def moe_gating_pallas(logits, *, top_k: int, capacity: int,
                      renormalise: bool = True, interpret: bool = True):
    """logits: (G, N, E) → (idx (G,N,k) i32, gate (G,N,k) f32, pos (G,N,k) i32)."""
    G, N, E = logits.shape
    kernel = functools.partial(
        _gating_kernel, top_k=top_k, capacity=capacity, renormalise=renormalise
    )
    return pl.pallas_call(
        kernel,
        grid=(G,),
        in_specs=[pl.BlockSpec((1, N, E), lambda g: (g, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, N, top_k), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, N, top_k), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, N, top_k), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, N, top_k), jnp.int32),
            jax.ShapeDtypeStruct((G, N, top_k), jnp.float32),
            jax.ShapeDtypeStruct((G, N, top_k), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(logits)
