"""Dispatch layer over the Pallas kernels.

Each op has three execution paths, chosen per call site:

* ``use_pallas=True`` → the Pallas kernel (Mosaic on TPU; ``interpret=True``
  executes the same kernel body in Python on CPU — how this container
  validates them);
* ``use_pallas=False`` → the XLA path (chunked-flash attention /
  chunked WKV / associative scan) — identical math, compiler-scheduled;
* gradients: the Pallas kernels are *forward* kernels wrapped in
  ``jax.custom_vjp`` whose backward recomputes through the XLA path
  (flash-style rematerialisation: save only (inputs, outputs), re-run the
  memory-bounded XLA forward under ``jax.vjp``). Training with
  ``use_pallas=True`` is therefore exact, at one extra forward of compute —
  the standard flash-attention trade.

Models call these via the ``ArchConfig.use_pallas`` flag, so kernel-vs-XLA
is a config diff (a §Perf lever), not a code fork.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .rglru_scan import lru_pallas
from .rmsnorm import rmsnorm_pallas
from .rwkv6_scan import wkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def _attention_pallas(q, k, v, causal, window, logit_cap, kv_chunk):
    return flash_attention(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        interpret=_interpret(),
    )


def _attention_xla(q, k, v, causal, window, logit_cap, kv_chunk):
    from repro.models.common import attention_chunked

    return attention_chunked(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        kv_chunk=kv_chunk,
    )


def _attention_fwd(q, k, v, causal, window, logit_cap, kv_chunk):
    out = _attention_pallas(q, k, v, causal, window, logit_cap, kv_chunk)
    return out, (q, k, v)


def _attention_bwd(causal, window, logit_cap, kv_chunk, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _attention_xla(q_, k_, v_, causal, window, logit_cap, kv_chunk),
        q, k, v,
    )
    return vjp(g)


_attention_pallas.defvjp(_attention_fwd, _attention_bwd)


def attention(
    q, k, v, *, causal: bool = True, window: int = 0, logit_cap: float = 0.0,
    kv_chunk: int = 1024, use_pallas: bool = False,
):
    """(B,Hq,Sq,d) × (B,Hkv,Skv,d)² → (B,Hq,Sq,dv); GQA by head ratio."""
    if use_pallas:
        return _attention_pallas(q, k, v, causal, window, logit_cap, kv_chunk)
    # XLA path expects expanded KV heads when grouped reshape is needed —
    # attention_chunked handles Hq=G·Hkv natively.
    return _attention_xla(q, k, v, causal, window, logit_cap, kv_chunk)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _wkv6_p(r, k, v, w, u, s0, chunk):
    return wkv6_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=_interpret())


def _wkv6_xla(r, k, v, w, u, s0, chunk):
    from repro.models.rwkv6 import wkv6_chunked

    return wkv6_chunked(r, k, v, w, u, s0, chunk=chunk)


def _wkv6_fwd(r, k, v, w, u, s0, chunk):
    out = _wkv6_p(r, k, v, w, u, s0, chunk)
    return out, (r, k, v, w, u, s0)


def _wkv6_bwd(chunk, res, g):
    r, k, v, w, u, s0 = res
    _, vjp = jax.vjp(lambda *a: _wkv6_xla(*a, chunk), r, k, v, w, u, s0)
    return vjp(g)


_wkv6_p.defvjp(_wkv6_fwd, _wkv6_bwd)


def wkv6(r, k, v, w, u, s0, *, chunk: int = 64, use_pallas: bool = False):
    """RWKV6 recurrence; returns (y, final_state)."""
    if use_pallas:
        return _wkv6_p(r, k, v, w, u, s0, chunk)
    return _wkv6_xla(r, k, v, w, u, s0, chunk)


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _lru_p(a, b, h0):
    return lru_pallas(a, b, h0, interpret=_interpret())


def _lru_xla(a, b, h0):
    """Log-depth associative scan: (a2, b2) ∘ (a1, b1) = (a1·a2, a2·b1 + b2)."""
    f32 = jnp.float32
    a_f, b_f = a.astype(f32), b.astype(f32)
    # fold h0 into the first step: b'_1 = a_1 h0 + b_1
    b_f = b_f.at[:, 0].add(a_f[:, 0] * h0.astype(f32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    A, Bc = jax.lax.associative_scan(combine, (a_f, b_f), axis=1)
    return Bc.astype(a.dtype), Bc[:, -1]


def _lru_fwd(a, b, h0):
    out = _lru_p(a, b, h0)
    return out, (a, b, h0)


def _lru_bwd(res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(_lru_xla, a, b, h0)
    return vjp(g)


_lru_p.defvjp(_lru_fwd, _lru_bwd)


def lru_scan(a, b, h0, *, use_pallas: bool = False):
    """h_t = a_t ⊙ h_{t-1} + b_t; returns (h_seq, h_final)."""
    if use_pallas:
        return _lru_p(a, b, h0)
    return _lru_xla(a, b, h0)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _rmsnorm_p(x, w):
    return rmsnorm_pallas(x, w, interpret=_interpret())


def _rmsnorm_xla(x, w):
    from repro.models.common import rms_norm

    return rms_norm(x, w)


def _rmsnorm_fwd(x, w):
    return _rmsnorm_p(x, w), (x, w)


def _rmsnorm_bwd(res, g):
    x, w = res
    _, vjp = jax.vjp(_rmsnorm_xla, x, w)
    return vjp(g)


_rmsnorm_p.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(x, w, *, use_pallas: bool = False):
    if use_pallas:
        return _rmsnorm_p(x, w)
    return _rmsnorm_xla(x, w)
