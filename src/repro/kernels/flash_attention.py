"""Pallas TPU flash-attention (forward) kernel.

TPU-native adaptation of FlashAttention: the (Sq × Skv) score matrix never
exists in HBM — each grid step loads one (block_q × d) query tile and one
(block_k × d) KV tile into VMEM, runs the online-softmax update on the MXU,
and carries running (m, l, acc) in VMEM scratch across the sequential
KV-block dimension.

Grid = (B, Hq, nQ, nK), with nK innermost — TPU grid semantics execute the
last dimension sequentially per core, so scratch written at step ki is
visible at ki+1 (this replaces the CUDA kernel's shared-memory loop).
Causal/local masking is positional; fully-masked KV tiles are skipped with
``pl.when`` (the compute simply does not issue — the TPU equivalent of
FlashAttention's block skipping).

Block shapes default to (128, 128) — MXU-aligned (the systolic array is
128×128) and small enough that q/k/v/o tiles + f32 scratch stay well under
the ~16 MB/core VMEM budget for every head_dim in the assigned archs
(d ≤ 256 → ~0.6 MB live).

GQA is handled in the index map (query head h reads KV head h // G): no
repeated K/V materialisation in HBM.

Validated in ``interpret=True`` mode against :func:`repro.kernels.ref.attention_ref`
(this container is CPU-only; on real v5e hardware the same call lowers to
Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref,  # VMEM tiles
    m_ref, l_ref, acc_ref,  # scratch (persist across the kv grid dim)
    *, scale: float, block_q: int, block_k: int, n_k: int,
    causal: bool, window: int, logit_cap: float, kv_valid: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile is live unless causal/local masking kills all of it
    live = True
    if causal:  # lowest q row sees k ≤ q_start + block_q - 1
        live = k_start <= q_start + block_q - 1
    if window > 0:  # highest q row q_start+block_q-1 sees k > q - window
        live = jnp.logical_and(
            live, k_start + block_k - 1 > q_start - window
        ) if causal else live

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < kv_valid  # padded KV columns never attended
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)  # (bq, bk); masked lanes exp(-inf)=0
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "logit_cap", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """q: (B,Hq,Sq,d); k,v: (B,Hkv,Skv,d) → (B,Hq,Sq,d). GQA via Hq=G·Hkv."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, dv = v.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = d**-0.5

    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    # pad ragged sequence lengths (masking keeps semantics exact: padded KV
    # columns have k_pos > every valid q_pos under causal; for non-causal we
    # mask explicitly below via window=0 ∧ causal=False edge case)
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k
    n_q = Sq_p // bq
    n_k = Skv_p // bk
    grid = (B, Hq, n_q, n_k)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale, block_q=bq, block_k=bk, n_k=n_k,
        causal=causal, window=window, logit_cap=logit_cap, kv_valid=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, dv), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
