"""Pallas TPU kernels for the perf-critical compute hot-spots.

| kernel              | hot-spot                          | oracle (ref.py)  |
|---------------------|-----------------------------------|------------------|
| flash_attention.py  | prefill/train attention           | attention_ref    |
| rwkv6_scan.py       | RWKV6 WKV recurrence (chunked)    | wkv6_ref         |
| rglru_scan.py       | RG-LRU linear recurrence          | lru_ref          |
| rmsnorm.py          | fused norm (memory-bound)         | rmsnorm_ref      |
| moe_gating.py       | softmax→top-k→capacity routing    | moe_gating_ref   |

``ops.py`` is the dispatch layer (Pallas ↔ XLA, custom_vjp training path);
models select it with ``ArchConfig.use_pallas``.
"""

from . import ops, ref
from .flash_attention import flash_attention
from .moe_gating import moe_gating_pallas
from .rglru_scan import lru_pallas
from .rmsnorm import rmsnorm_pallas
from .rwkv6_scan import wkv6_pallas

__all__ = [
    "ops", "ref",
    "flash_attention", "lru_pallas", "moe_gating_pallas", "rmsnorm_pallas",
    "wkv6_pallas",
]
