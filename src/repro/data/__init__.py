"""Data pipeline: deterministic synthetic LM streams, per-host sharding,
background prefetch with backup-fetch straggler mitigation."""

from .pipeline import (
    DataLoader,
    HostShard,
    SyntheticLMDataset,
    host_shard_for,
    make_train_loader,
)

__all__ = [
    "DataLoader",
    "HostShard",
    "SyntheticLMDataset",
    "host_shard_for",
    "make_train_loader",
]
