"""Deterministic synthetic data pipeline with straggler mitigation.

Layers (bottom-up):

* :class:`SyntheticLMDataset` — a *stateless, indexable* token source:
  ``batch(index, size, seq)`` is a pure function of ``(seed, index)``, so any
  host can materialise any batch at any time. That property is what makes
  every feature above it cheap: resume-from-checkpoint is "set the cursor",
  elastic rescale is "recompute your shard slice", and a backup fetch of
  batch *i* on another thread returns bit-identical data.

* :func:`host_shard_for` — per-host batch sharding: host ``h`` of ``H``
  owns rows ``[h·B/H, (h+1)·B/H)`` of every global batch, matching a
  ``("pod","data")``-sharded leading batch axis at 1000+-node scale (each
  host feeds exactly the rows that live on its local chips; no cross-host
  data exchange ever happens in the input pipeline).

* :class:`DataLoader` — background prefetch with **backup fetch** straggler
  mitigation (the MapReduce/backup-requests idiom): a pool of workers
  produces batches ahead of the consumer; if a fetch has not produced its
  batch within ``straggler_ms`` of becoming due, a *backup* fetch of the
  same index is issued to another worker and whichever finishes first wins
  (safe because fetches are deterministic and idempotent). Real clusters
  see this when a data host hits a slow disk/NFS stall; the unit tests
  inject delays via a ``fetch_hook``.

The loader's full iteration state is one integer (``cursor``), exposed via
``state_dict()``/``load_state_dict`` and saved inside training checkpoints —
restart resumes the stream exactly where it stopped, on any host count.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np


# ---------------------------------------------------------------------------
# Stateless synthetic dataset
# ---------------------------------------------------------------------------


class SyntheticLMDataset:
    """Deterministic LM token stream: ``batch(i)`` is pure in ``(seed, i)``.

    Tokens follow a Zipf-like marginal over the vocabulary with a short
    Markov "phrase" structure, so losses fall smoothly during the e2e
    example run instead of flat-lining at ``log(V)`` (uniform tokens are
    unlearnable). Labels are next-token shifted with the final position
    masked (-100).
    """

    def __init__(self, vocab_size: int, *, seed: int = 0, zipf_a: float = 1.2):
        if vocab_size < 4:
            raise ValueError("vocab too small")
        self.vocab_size = int(vocab_size)
        self.seed = int(seed)
        # Zipf-ish unnormalised weights over the vocab (deterministic).
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        w = 1.0 / ranks**zipf_a
        self._cdf = np.cumsum(w / w.sum())

    def _rng(self, index: int, stream: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence(
                entropy=self.seed, spawn_key=(int(index), int(stream))
            )
        )

    def tokens(self, index: int, rows: int, seq: int) -> np.ndarray:
        """(rows, seq+1) int32 tokens for global batch ``index``.

        Each random field draws from its own child stream, so generating
        the first ``rows`` rows yields a prefix of any larger request —
        the property host sharding relies on (a shard is a row-slice of
        the global batch, bit-identical across host counts).
        """
        u = self._rng(index, 0).random((rows, seq + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        # Markov phrase structure: with p=0.5 a token repeats its
        # predecessor + 1 (mod V) — a learnable local pattern.
        rep = self._rng(index, 1).random((rows, seq + 1)) < 0.5
        for t in range(1, seq + 1):
            prev = toks[:, t - 1]
            toks[:, t] = np.where(rep[:, t], (prev + 1) % self.vocab_size, toks[:, t])
        return toks

    def batch(self, index: int, rows: int, seq: int, row_offset: int = 0) -> dict:
        """One (shard of a) global batch: {"tokens","labels"} both (rows, seq).

        ``row_offset`` selects a host's slice *of the same global batch*:
        the full (global_rows, seq+1) block is generated and sliced, so the
        union over hosts is identical to the single-host stream.
        """
        full = self.tokens(index, rows + row_offset, seq)[row_offset:]
        tokens = full[:, :-1]
        labels = full[:, 1:].copy()
        return {"tokens": tokens, "labels": labels}


# ---------------------------------------------------------------------------
# Per-host sharding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HostShard:
    """This host's slice of every global batch."""

    host_index: int
    host_count: int
    global_batch: int

    @property
    def rows(self) -> int:
        return self.global_batch // self.host_count

    @property
    def row_offset(self) -> int:
        return self.host_index * self.rows


def host_shard_for(global_batch: int, host_index: int, host_count: int) -> HostShard:
    if global_batch % host_count:
        raise ValueError(
            f"global_batch {global_batch} not divisible by host_count {host_count}"
        )
    if not 0 <= host_index < host_count:
        raise ValueError(f"host_index {host_index} out of range 0..{host_count - 1}")
    return HostShard(host_index, host_count, global_batch)


# ---------------------------------------------------------------------------
# Prefetching loader with backup-fetch straggler mitigation
# ---------------------------------------------------------------------------


class DataLoader:
    """Background-prefetching loader over an indexable ``fetch(i)->batch``.

    * ``prefetch`` batches are produced ahead of the consumer by ``workers``
      threads (the XLA host is busy stepping; input production overlaps).
    * If the *due* batch is not ready ``straggler_ms`` after being awaited,
      a backup fetch of the same index is dispatched to a free worker; the
      first result wins, the loser is discarded (idempotent fetches).
    * Deterministic order: batches are always yielded in index order
      regardless of completion order.

    ``fetch_hook(index, attempt)`` is a test/diagnostics injection point
    called inside the worker before fetching (used to simulate stragglers).
    """

    def __init__(
        self,
        fetch,
        *,
        start: int = 0,
        prefetch: int = 4,
        workers: int = 2,
        straggler_ms: float = 1000.0,
        fetch_hook=None,
    ):
        self._fetch = fetch
        self._cursor = int(start)  # next index to hand to the consumer
        self._next_to_submit = int(start)
        self._prefetch = max(1, int(prefetch))
        self._straggler_ms = float(straggler_ms)
        self._fetch_hook = fetch_hook
        self._results: dict[int, object] = {}
        self._inflight: dict[int, float] = {}  # index → first-submit time
        self._backup_issued: set[int] = set()
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._tasks: queue.Queue = queue.Queue()
        self._stop = False
        self.stats = {"fetched": 0, "backups": 0, "backup_wins": 0}
        self._threads = [
            threading.Thread(target=self._worker, daemon=True, name=f"loader-{i}")
            for i in range(max(1, int(workers)))
        ]
        for t in self._threads:
            t.start()
        self._pump()

    # -- state (checkpointable) ---------------------------------------------

    def state_dict(self) -> dict:
        with self._lock:
            return {"cursor": self._cursor}

    def load_state_dict(self, state: dict) -> None:
        with self._lock:
            self._cursor = int(state["cursor"])
            self._next_to_submit = self._cursor
            self._results.clear()
            self._inflight.clear()
            self._backup_issued.clear()
        self._pump()

    # -- iteration -------------------------------------------------------------

    def __iter__(self):
        return self

    def __next__(self):
        idx = self._cursor
        deadline = time.monotonic() + self._straggler_ms / 1e3
        with self._ready:
            while idx not in self._results:
                remaining = deadline - time.monotonic()
                if remaining <= 0 and idx not in self._backup_issued:
                    # the due batch is late → backup fetch (straggler path)
                    self._backup_issued.add(idx)
                    self.stats["backups"] += 1
                    self._tasks.put((idx, 1))
                    deadline = float("inf")
                self._ready.wait(timeout=max(0.01, min(remaining, 0.1)) if remaining > 0 else 0.05)
            batch = self._results.pop(idx)
            self._cursor = idx + 1
        self._pump()
        return batch

    def close(self) -> None:
        self._stop = True
        for _ in self._threads:
            self._tasks.put(None)
        for t in self._threads:
            t.join(timeout=2)

    # -- internals ----------------------------------------------------------------

    def _pump(self) -> None:
        """Keep ``prefetch`` indices in flight."""
        with self._lock:
            while self._next_to_submit < self._cursor + self._prefetch:
                idx = self._next_to_submit
                self._next_to_submit += 1
                if idx in self._results or idx in self._inflight:
                    continue
                self._inflight[idx] = time.monotonic()
                self._tasks.put((idx, 0))

    def _worker(self) -> None:
        while not self._stop:
            task = self._tasks.get()
            if task is None:
                return
            idx, attempt = task
            with self._lock:
                if idx in self._results or idx < self._cursor:
                    continue  # already produced / consumed (losing backup)
            if self._fetch_hook is not None:
                self._fetch_hook(idx, attempt)
            try:
                batch = self._fetch(idx)
            except Exception as e:  # surface in the consumer thread
                batch = _FetchError(e)
            with self._ready:
                if idx not in self._results and idx >= self._cursor:
                    self._results[idx] = batch
                    self.stats["fetched"] += 1
                    if attempt == 1:
                        self.stats["backup_wins"] += 1
                self._inflight.pop(idx, None)
                self._ready.notify_all()


class _FetchError:
    def __init__(self, err):
        self.err = err


def make_train_loader(
    vocab_size: int,
    global_batch: int,
    seq: int,
    *,
    seed: int = 0,
    host_index: int = 0,
    host_count: int = 1,
    start: int = 0,
    prefetch: int = 4,
    workers: int = 2,
    straggler_ms: float = 1000.0,
    fetch_hook=None,
) -> DataLoader:
    """The standard training input pipeline for one host."""
    ds = SyntheticLMDataset(vocab_size, seed=seed)
    shard = host_shard_for(global_batch, host_index, host_count)

    def fetch(i: int) -> dict:
        return ds.batch(i, shard.rows, seq, row_offset=shard.row_offset)

    return DataLoader(
        fetch,
        start=start,
        prefetch=prefetch,
        workers=workers,
        straggler_ms=straggler_ms,
        fetch_hook=fetch_hook,
    )
