"""``JobTracer`` — per-job lifecycle spans derived from the event bus.

Every job's life is already announced as typed
:class:`~repro.core.events.JobEvent` s (natively by the simulator, or
synthesised by the :class:`~repro.core.events.PollingEventAdapter` on real
SLURM — the adapter emits the same vocabulary, so span timelines are
backend-agnostic; ``tests/test_trace_parity.py`` pins that). The tracer
subscribes once and folds the stream into :class:`JobSpan` s::

    submitted → (held) → released → started → COMPLETED/FAILED/…

recording, into the active :class:`~repro.obs.metrics.MetricsRegistry`:

* ``nbi_trace_events_total{type=}`` — every event seen;
* ``nbi_trace_spans_total{outcome=}`` — one per terminal event;
* ``nbi_trace_open_spans`` — gauge of jobs still in flight;
* ``nbi_trace_queue_wait_seconds{cluster=}`` — submit→start;
* ``nbi_trace_hold_seconds{cluster=}`` — submit→release of held jobs;
* ``nbi_trace_lifetime_seconds{cluster=}`` — submit→terminal.

The tracer also keeps its own plain-int counts (``finished``, outcome
tallies) independent of the registry, so span conservation — spans
finalized == jobs archived — can be asserted even with metrics disabled.
Finished spans themselves are retained in a bounded deque (``keep`` most
recent) for the ``nbimon --live`` ticker and tests; the counts are exact
regardless of the bound.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from datetime import datetime

from repro.core import events as ev
from .metrics import DURATION_BUCKETS, get_registry


@dataclass
class JobSpan:
    """One job's observed lifecycle timeline."""

    jobid: str
    cluster: str = ""
    name: str = ""
    user: str = ""
    submitted_at: "datetime | None" = None
    released_at: "datetime | None" = None
    started_at: "datetime | None" = None
    terminal_at: "datetime | None" = None
    outcome: str = ""  # terminal event type ("" while open)
    held: bool = False  # observed held (JobHeldUser) at submission
    #: the raw timeline: every (event type, instant) in arrival order
    events: "list[tuple[str, datetime]]" = field(default_factory=list)

    @property
    def is_open(self) -> bool:
        return not self.outcome

    @property
    def timeline(self) -> tuple:
        return tuple(self.events)

    def _delta(self, a: "datetime | None", b: "datetime | None"):
        if a is None or b is None:
            return None
        return (b - a).total_seconds()

    @property
    def queue_wait_s(self) -> "float | None":
        """Submit → start (None when either end was not observed)."""
        return self._delta(self.submitted_at, self.started_at)

    @property
    def hold_s(self) -> "float | None":
        """Submit → release, for jobs observed held at submission."""
        if not self.held:
            return None
        return self._delta(self.submitted_at, self.released_at)

    @property
    def lifetime_s(self) -> "float | None":
        return self._delta(self.submitted_at, self.terminal_at)

    def to_dict(self) -> dict:
        return {
            "jobid": self.jobid,
            "cluster": self.cluster,
            "name": self.name,
            "user": self.user,
            "outcome": self.outcome,
            "held": self.held,
            "queue_wait_s": self.queue_wait_s,
            "hold_s": self.hold_s,
            "lifetime_s": self.lifetime_s,
            "events": [(t, at) for t, at in self.events],
        }


class JobTracer:
    """Fold an :class:`~repro.core.events.EventBus` into job spans.

    Construct, then :meth:`attach` to a bus (or feed :meth:`on_event`
    directly). Detach before discarding — a subscribed tracer is kept
    alive by the bus otherwise.
    """

    def __init__(self, *, keep: int = 1024, registry=None):
        self.open: dict[str, JobSpan] = {}
        self.recent: deque[JobSpan] = deque(maxlen=keep)
        # exact tallies, independent of the metrics registry
        self.seen = 0
        self.finished = 0
        self.outcomes: dict[str, int] = {}
        self._bus_token: "tuple | None" = None
        # metric handles resolved ONCE — on_event is the per-event hot path,
        # so construct the tracer after enable() (nbimon/bench do); with
        # metrics disabled these are shared no-ops
        reg = registry if registry is not None else get_registry()
        self._m_events = reg.counter(
            "nbi_trace_events_total", "job events seen by the tracer",
            labels=("type",),
        )
        self._m_spans = reg.counter(
            "nbi_trace_spans_total", "job spans finalized, by outcome",
            labels=("outcome",),
        )
        self._m_open = reg.gauge(
            "nbi_trace_open_spans", "jobs currently in flight"
        )
        self._m_hold = reg.histogram(
            "nbi_trace_hold_seconds",
            "submit-to-release of held (eco-deferred) jobs",
            labels=("cluster",), buckets=DURATION_BUCKETS,
        )
        self._m_wait = reg.histogram(
            "nbi_trace_queue_wait_seconds", "submit-to-start queue wait",
            labels=("cluster",), buckets=DURATION_BUCKETS,
        )
        self._m_life = reg.histogram(
            "nbi_trace_lifetime_seconds", "submit-to-terminal lifetime",
            labels=("cluster",), buckets=DURATION_BUCKETS,
        )
        # labeled-child caches: labels(**kw) memoizes inside the family but
        # still pays kwargs + sort + lock per call; a plain dict keyed on the
        # one label value is ~5x cheaper on the per-event path
        self._ev_children: dict = {}
        self._outcome_children: dict = {}
        self._hold_children: dict = {}
        self._wait_children: dict = {}
        self._life_children: dict = {}

    # -- wiring ----------------------------------------------------------------

    def attach(self, bus) -> "JobTracer":
        if self._bus_token is not None:
            old_bus, token = self._bus_token
            old_bus.unsubscribe(token)
        self._bus_token = (bus, bus.subscribe(self.on_event))
        return self

    def detach(self) -> None:
        if self._bus_token is not None:
            bus, token = self._bus_token
            bus.unsubscribe(token)
            self._bus_token = None

    # -- event folding ---------------------------------------------------------

    def on_event(self, event) -> None:
        self.seen += 1
        c = self._ev_children.get(event.type)
        if c is None:
            c = self._ev_children[event.type] = \
                self._m_events.labels(type=event.type)
        c.inc()

        span = self.open.get(event.jobid)
        if span is None:
            # first sighting — usually SUBMITTED, but a tracer attached
            # mid-life still gets a span (with an empty front half)
            span = JobSpan(jobid=event.jobid, cluster=event.cluster,
                           name=event.name, user=event.user)
            self.open[event.jobid] = span
            self._m_open.set(len(self.open))
        if event.cluster and not span.cluster:
            span.cluster = event.cluster
        if event.name and not span.name:
            span.name = event.name
        if event.user and not span.user:
            span.user = event.user
        span.events.append((event.type, event.at))

        if event.type == ev.SUBMITTED:
            span.submitted_at = event.at
            if event.reason == ev.HELD_REASON:
                span.held = True
        elif event.type == ev.RELEASED:
            span.released_at = event.at
            span.held = True  # a release implies it was held
            hold = span.hold_s
            if hold is not None:
                self._observe(self._hold_children, self._m_hold,
                              span.cluster, hold)
        elif event.type == ev.STARTED:
            span.started_at = event.at
            wait = span.queue_wait_s
            if wait is not None:
                self._observe(self._wait_children, self._m_wait,
                              span.cluster, wait)
        elif event.type == ev.REQUEUED:
            span.started_at = None  # back to pending; next start re-times
        elif event.is_terminal:
            span.terminal_at = event.at
            span.outcome = event.type
            self._finalize(span)

    @staticmethod
    def _observe(cache: dict, family, cluster: str, value: float) -> None:
        child = cache.get(cluster)
        if child is None:
            child = cache[cluster] = family.labels(cluster=cluster)
        child.observe(value)

    def _finalize(self, span: JobSpan) -> None:
        self.open.pop(span.jobid, None)
        self.recent.append(span)
        self.finished += 1
        self.outcomes[span.outcome] = self.outcomes.get(span.outcome, 0) + 1
        c = self._outcome_children.get(span.outcome)
        if c is None:
            c = self._outcome_children[span.outcome] = \
                self._m_spans.labels(outcome=span.outcome)
        c.inc()
        life = span.lifetime_s
        if life is not None:
            self._observe(self._life_children, self._m_life,
                          span.cluster, life)
        self._m_open.set(len(self.open))

    # -- summaries ---------------------------------------------------------------

    def timeline(self, jobid: str) -> tuple:
        """The (type, at) timeline of one job, open or recently finished."""
        span = self.open.get(jobid)
        if span is not None:
            return span.timeline
        for s in self.recent:
            if s.jobid == jobid:
                return s.timeline
        return ()

    def to_dict(self) -> dict:
        return {
            "events_seen": self.seen,
            "spans_finished": self.finished,
            "spans_open": len(self.open),
            "outcomes": dict(sorted(self.outcomes.items())),
        }
