"""Exporters for the metrics registry: Prometheus textfile + JSON snapshot.

Two faithful views of one :class:`~repro.obs.metrics.MetricsRegistry`:

* :func:`snapshot` — a plain JSON-able dict (serialized through the CLI
  suite's shared :func:`repro.cli.render.to_json` dialect, so ``nbimon
  --json`` output reads exactly like every other tool's ``--json``);
* :func:`to_prometheus` — the Prometheus *text exposition format*
  (``# HELP`` / ``# TYPE`` headers, ``_bucket{le=…}`` / ``_sum`` /
  ``_count`` expansion for histograms), suitable for the node-exporter
  textfile collector or a one-shot scrape.

:func:`parse_textfile` is the matching validator: it re-parses an
exposition file, checks label syntax, histogram bucket monotonicity and
``_count``/``+Inf`` agreement, and returns per-family sample counts — CI
runs it (via ``nbimon --check-textfile``) over the benchmark's published
textfile so a malformed exporter can never land silently.
"""

from __future__ import annotations

import math
import re
from pathlib import Path

from .metrics import MetricsRegistry, get_registry

_INF = float("inf")


# ---------------------------------------------------------------------------
# JSON snapshot
# ---------------------------------------------------------------------------


def snapshot(registry=None, *, meta: "dict | None" = None) -> dict:
    """The registry as one JSON-able dict (the ``nbimon --json`` payload)."""
    registry = registry if registry is not None else get_registry()
    metrics: dict = {}
    for fam in registry.families():
        series = []
        for labels, child in fam.samples():
            if fam.kind == "histogram":
                series.append({
                    "labels": labels,
                    "buckets": _cumulative(fam.buckets, child.counts),
                    "sum": child.sum,
                    "count": child.count,
                })
            else:
                series.append({"labels": labels, "value": child.value})
        metrics[fam.name] = {
            "type": fam.kind,
            "help": fam.help,
            "series": series,
        }
    out = {"metrics": metrics}
    if meta:
        out["meta"] = dict(meta)
    return out


def _cumulative(buckets: tuple, counts: list) -> "list[list]":
    """Per-bucket counts → Prometheus-style cumulative ``[le, count]``."""
    out = []
    total = 0
    for bound, n in zip(buckets, counts):
        total += n
        out.append([bound, total])
    total += counts[-1]
    out.append(["+Inf", total])
    return out


def write_snapshot(path, registry=None, *, meta: "dict | None" = None) -> dict:
    """Serialize :func:`snapshot` to ``path`` in the shared JSON dialect."""
    from repro.cli.render import to_json

    snap = snapshot(registry, meta=meta)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(to_json(snap) + "\n", encoding="utf-8")
    return snap


def load_snapshot(path) -> dict:
    import json

    return json.loads(Path(path).read_text(encoding="utf-8"))


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------


def _escape_help(s: str) -> str:
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels_text(labels: dict, extra: "tuple | None" = None) -> str:
    pairs = [f'{k}="{_escape_label(str(v))}"' for k, v in labels.items()]
    if extra is not None:
        pairs.append(f'{extra[0]}="{extra[1]}"')
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt(v: float) -> str:
    if v == _INF:
        return "+Inf"
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(float(v))


def prometheus_from_snapshot(snap: dict) -> str:
    """Render a :func:`snapshot` dict as Prometheus exposition text."""
    lines: list[str] = []
    for name in sorted(snap.get("metrics", {})):
        fam = snap["metrics"][name]
        kind = fam.get("type", "counter")
        if fam.get("help"):
            lines.append(f"# HELP {name} {_escape_help(fam['help'])}")
        lines.append(f"# TYPE {name} {kind}")
        for s in fam.get("series", []):
            labels = s.get("labels", {})
            if kind == "histogram":
                for le, count in s.get("buckets", []):
                    lines.append(
                        f"{name}_bucket"
                        f"{_labels_text(labels, ('le', _fmt(le) if le != '+Inf' else '+Inf'))}"
                        f" {int(count)}"
                    )
                lines.append(f"{name}_sum{_labels_text(labels)} {_fmt(float(s['sum']))}")
                lines.append(f"{name}_count{_labels_text(labels)} {int(s['count'])}")
            else:
                lines.append(f"{name}{_labels_text(labels)} {_fmt(float(s['value']))}")
    return "\n".join(lines) + ("\n" if lines else "")


def to_prometheus(registry=None) -> str:
    return prometheus_from_snapshot(snapshot(registry))


def write_textfile(path, registry=None, *, snap: "dict | None" = None) -> str:
    """Write the exposition text (from a registry or a snapshot dict)."""
    text = prometheus_from_snapshot(snap) if snap is not None else to_prometheus(registry)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text, encoding="utf-8")
    return text


# ---------------------------------------------------------------------------
# Validator / parser
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s]+)\s*$"
)
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_textfile(text: str) -> dict:
    """Parse (and validate) Prometheus exposition text.

    Returns ``{family name: {"type": ..., "samples": N}}``. Raises
    :class:`ValueError` on any malformed line, unparseable value,
    non-monotone histogram buckets, or a histogram whose ``_count``
    disagrees with its ``+Inf`` bucket.
    """
    families: dict = {}
    hist: dict = {}  # (name, labels-frozen) → {"buckets": [...], "count": ..}

    def family_for(sample_name: str) -> "tuple[str, str]":
        for suffix in ("_bucket", "_sum", "_count"):
            base = sample_name[: -len(suffix)]
            if sample_name.endswith(suffix) and families.get(base, {}).get(
                "type"
            ) == "histogram":
                return base, suffix
        return sample_name, ""

    for lineno, line in enumerate(text.splitlines(), start=1):
        line = line.rstrip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                name, kind = parts[2], (parts[3] if len(parts) > 3 else "")
                if not _NAME_RE.match(name):
                    raise ValueError(f"line {lineno}: bad metric name {name!r}")
                if kind not in ("counter", "gauge", "histogram", "summary", "untyped"):
                    raise ValueError(f"line {lineno}: bad TYPE {kind!r}")
                families.setdefault(name, {"type": kind, "samples": 0})
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        raw_labels = m.group("labels")
        labels: dict = {}
        if raw_labels:
            consumed = _LABEL_RE.findall(raw_labels)
            rebuilt = ",".join(f'{k}="{v}"' for k, v in consumed)
            if rebuilt != raw_labels:
                raise ValueError(f"line {lineno}: malformed labels {{{raw_labels}}}")
            labels = dict(consumed)
        value_s = m.group("value")
        try:
            value = float(value_s.replace("+Inf", "inf").replace("-Inf", "-inf"))
        except ValueError:
            raise ValueError(
                f"line {lineno}: unparseable value {value_s!r}"
            ) from None
        if math.isnan(value):
            raise ValueError(f"line {lineno}: NaN sample value")
        base, suffix = family_for(m.group("name"))
        fam = families.setdefault(base, {"type": "untyped", "samples": 0})
        fam["samples"] += 1
        if suffix in ("_bucket", "_count"):
            key_labels = {k: v for k, v in labels.items() if k != "le"}
            key = (base, tuple(sorted(key_labels.items())))
            h = hist.setdefault(key, {"buckets": [], "count": None})
            if suffix == "_bucket":
                if "le" not in labels:
                    raise ValueError(f"line {lineno}: _bucket without le=")
                le = float(labels["le"].replace("+Inf", "inf"))
                h["buckets"].append((le, value))
            else:
                h["count"] = value

    for (name, _), h in hist.items():
        counts = [c for _, c in h["buckets"]]
        if counts != sorted(counts):
            raise ValueError(f"{name}: histogram buckets not cumulative")
        les = [le for le, _ in h["buckets"]]
        if les != sorted(les):
            raise ValueError(f"{name}: histogram le= bounds not sorted")
        if les and les[-1] != _INF:
            raise ValueError(f"{name}: histogram missing +Inf bucket")
        if counts and h["count"] is not None and h["count"] != counts[-1]:
            raise ValueError(
                f"{name}: _count {h['count']} != +Inf bucket {counts[-1]}"
            )
    return families


# ---------------------------------------------------------------------------
# Session stats (waitjobs/viewjobs --stats, nbimon summary)
# ---------------------------------------------------------------------------


def session_stats(cache=None, registry=None, *, tracer=None) -> dict:
    """One process's observability summary, CLI-friendly.

    ``cache`` (a :class:`~repro.core.engine.QueueCache`) contributes the
    poll-dedup headline numbers even when metrics were never enabled —
    the cache keeps plain-int counters of its own.
    """
    out: dict = {}
    if cache is not None:
        polls = int(getattr(cache, "polls", 0))
        hits = int(getattr(cache, "hits", 0))
        calls = polls + hits
        out["queue_cache"] = {
            "polls": polls,
            "hits": hits,
            "polls_saved": hits,
            "hit_rate": (hits / calls) if calls else 0.0,
            "event_invalidations": int(getattr(cache, "event_invalidations", 0)),
        }
    if tracer is not None:
        out["trace"] = tracer.to_dict()
    registry = registry if registry is not None else get_registry()
    if getattr(registry, "enabled", False):
        out["registry"] = snapshot(registry)["metrics"]
    return out


def make_registry() -> MetricsRegistry:
    """A fresh standalone registry (benchmarks compare several)."""
    return MetricsRegistry()
