"""``MetricsRegistry`` — the stack's runtime metrics substrate (stdlib-only).

Counters, gauges and histograms with Prometheus-style labels, behind one
thread-safe registry. Instrumentation sites across the stack (SubmitEngine,
QueueCache, Placer, EcoController, the history index, the event bus) call
:func:`get_registry` at use time and record into whatever registry is
active:

* **disabled by default** — the active registry is a :class:`NullRegistry`
  whose metric objects are shared no-op singletons, so an un-instrumented
  run pays a couple of attribute lookups per *batch*, never per job (the
  overhead on the 20k-job simulated day is measured by
  ``benchmarks/bench_obs.py`` and gated ≤5% in CI);
* :func:`enable` (or ``NBI_OBS=1`` in the environment) swaps in a real
  :class:`MetricsRegistry`; every site starts recording immediately — no
  re-wiring, because sites never cache the registry across calls.

Naming follows Prometheus conventions: ``nbi_<subsystem>_<what>_<unit>``,
``_total`` suffix on counters, seconds for time. Label keys are declared
per family (``cluster=``, ``tier=``, ``path=`` …); see
``docs/observability.md`` for the full catalogue.

Exporters live in :mod:`repro.obs.export`; per-job lifecycle tracing in
:mod:`repro.obs.trace`. This module imports nothing from ``repro`` so any
layer (including ``repro.core.events``) can use it without cycles.
"""

from __future__ import annotations

import os
import threading
import time as _time

#: default buckets for latency histograms (seconds) — sub-ms to minutes
LATENCY_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0,
)

#: default buckets for job-scale durations (seconds) — minutes to a week
DURATION_BUCKETS = (
    60.0, 300.0, 900.0, 1800.0, 3600.0, 7200.0, 14400.0, 28800.0,
    57600.0, 86400.0, 172800.0, 604800.0,
)

_INF = float("inf")


def _label_values(names: tuple, kw: dict) -> tuple:
    if set(kw) != set(names):
        raise ValueError(
            f"labels {sorted(kw)} do not match declared {sorted(names)}"
        )
    return tuple(str(kw[n]) for n in names)


class _Child:
    """One (labelset → value) sample of a counter or gauge family."""

    __slots__ = ("_family", "value")

    def __init__(self, family):
        self._family = family
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        with self._family._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    def set(self, value: float) -> None:
        with self._family._lock:
            self.value = float(value)


class _HistogramChild:
    """One labelset of a histogram family: bucket counts + sum + count."""

    __slots__ = ("_family", "counts", "sum", "count")

    def __init__(self, family):
        self._family = family
        self.counts = [0] * (len(family.buckets) + 1)  # last slot = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        fam = self._family
        with fam._lock:
            i = 0
            for bound in fam.buckets:
                if value <= bound:
                    break
                i += 1
            self.counts[i] += 1
            self.sum += value
            self.count += 1


class MetricFamily:
    """A named metric with declared label keys and per-labelset children.

    A family declared with no labels IS its own single sample — call
    ``inc()`` / ``set()`` / ``observe()`` on it directly. With labels,
    ``labels(key=value, ...)`` resolves (and memoizes) the child.
    """

    def __init__(self, name: str, kind: str, help: str = "",
                 label_names: tuple = (), buckets: tuple = ()):
        self.name = name
        self.kind = kind  # counter | gauge | histogram
        self.help = help
        self.label_names = tuple(label_names)
        self.buckets = tuple(float(b) for b in buckets)
        self._lock = threading.Lock()
        self._children: dict[tuple, object] = {}
        self._default = None
        if not self.label_names:
            self._default = self._make_child()
            self._children[()] = self._default

    def _make_child(self):
        if self.kind == "histogram":
            return _HistogramChild(self)
        return _Child(self)

    def labels(self, **kw):
        key = _label_values(self.label_names, kw)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child())
        return child

    # -- label-less conveniences (raise when the family declares labels) ------

    def _require_default(self):
        if self._default is None:
            raise ValueError(
                f"{self.name} declares labels {self.label_names}; "
                f"use .labels(...)"
            )
        return self._default

    def inc(self, amount: float = 1.0) -> None:
        self._require_default().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._require_default().dec(amount)

    def set(self, value: float) -> None:
        self._require_default().set(value)

    def observe(self, value: float) -> None:
        self._require_default().observe(value)

    @property
    def value(self) -> float:
        return self._require_default().value

    # -- read side -------------------------------------------------------------

    def samples(self) -> "list[tuple[dict, object]]":
        """``[(labels_dict, child), ...]`` in insertion order."""
        with self._lock:
            items = list(self._children.items())
        return [
            (dict(zip(self.label_names, key)), child) for key, child in items
        ]


class MetricsRegistry:
    """Thread-safe collection of :class:`MetricFamily` s.

    ``counter`` / ``gauge`` / ``histogram`` are idempotent: the first call
    declares the family, later calls return it (and must agree on kind —
    re-declaring a name as a different kind raises).
    """

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, MetricFamily] = {}

    def _family(self, name: str, kind: str, help: str, labels: tuple,
                buckets: tuple = ()) -> MetricFamily:
        fam = self._families.get(name)
        if fam is None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = MetricFamily(name, kind, help, labels, buckets)
                    self._families[name] = fam
        if fam.kind != kind:
            raise ValueError(
                f"{name} already registered as {fam.kind}, not {kind}"
            )
        return fam

    def counter(self, name: str, help: str = "", labels: tuple = ()) -> MetricFamily:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "", labels: tuple = ()) -> MetricFamily:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "", labels: tuple = (),
                  buckets: tuple = LATENCY_BUCKETS) -> MetricFamily:
        return self._family(name, "histogram", help, labels, buckets)

    def families(self) -> "list[MetricFamily]":
        with self._lock:
            return list(self._families.values())

    def get(self, name: str) -> "MetricFamily | None":
        return self._families.get(name)

    def reset(self) -> None:
        """Drop every family (tests; a long-lived daemon keeps its own)."""
        with self._lock:
            self._families.clear()


# ---------------------------------------------------------------------------
# No-op twin — the disabled-by-default fast path
# ---------------------------------------------------------------------------


class _NullMetric:
    """Shared do-nothing stand-in for every metric object."""

    __slots__ = ()
    value = 0.0
    sum = 0.0
    count = 0

    def labels(self, **kw):
        return self

    def inc(self, amount: float = 1.0) -> None:
        pass

    def dec(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def samples(self):
        return []


_NULL_METRIC = _NullMetric()


class _NullTimer:
    """Shared context manager that never reads the clock."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()


class NullRegistry:
    """API-compatible registry whose metrics are shared no-ops."""

    enabled = False

    def counter(self, name: str, help: str = "", labels: tuple = ()):
        return _NULL_METRIC

    def gauge(self, name: str, help: str = "", labels: tuple = ()):
        return _NULL_METRIC

    def histogram(self, name: str, help: str = "", labels: tuple = (),
                  buckets: tuple = LATENCY_BUCKETS):
        return _NULL_METRIC

    def families(self):
        return []

    def get(self, name: str):
        return None

    def reset(self) -> None:
        pass


NULL_REGISTRY = NullRegistry()


class _Timer:
    """``with timed(hist):`` — observes elapsed seconds on exit."""

    __slots__ = ("_hist", "_t0")

    def __init__(self, hist):
        self._hist = hist

    def __enter__(self):
        self._t0 = _time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe(_time.perf_counter() - self._t0)
        return False


def timed(hist):
    """Time a block into ``hist``; free when ``hist`` is the null metric."""
    if hist is _NULL_METRIC:
        return _NULL_TIMER
    return _Timer(hist)


# ---------------------------------------------------------------------------
# The active registry
# ---------------------------------------------------------------------------

_active: "MetricsRegistry | NullRegistry" = (
    MetricsRegistry()
    if os.environ.get("NBI_OBS", "").lower() in ("1", "true", "yes", "on")
    else NULL_REGISTRY
)


def get_registry() -> "MetricsRegistry | NullRegistry":
    """The registry instrumentation records into right now."""
    return _active


def enable(registry: "MetricsRegistry | None" = None) -> MetricsRegistry:
    """Switch instrumentation on; returns the active real registry.

    Idempotent: with a real registry already active (and no explicit
    ``registry``), it is kept — counters accumulated so far survive.
    """
    global _active
    if registry is not None:
        _active = registry
    elif not _active.enabled:
        _active = MetricsRegistry()
    return _active  # type: ignore[return-value]


def disable() -> None:
    """Back to the no-op registry (the default state)."""
    global _active
    _active = NULL_REGISTRY
