"""Runtime observability: metrics registry, job tracing, exporters.

Import surface:

* :mod:`repro.obs.metrics` — re-exported here; safe from any layer
  (it imports nothing from ``repro``, so even ``repro.core.events``
  can depend on it without a cycle).
* :mod:`repro.obs.trace` / :mod:`repro.obs.export` — import these
  submodules explicitly. ``trace`` imports ``repro.core.events``, so
  pulling it in eagerly here would cycle with core modules that use
  the registry.

Instrumentation is **off by default**: :func:`get_registry` returns a
no-op :class:`NullRegistry` until :func:`enable` is called (or the
process starts with ``NBI_OBS=1``). See ``docs/observability.md``.
"""

from .metrics import (
    DURATION_BUCKETS,
    LATENCY_BUCKETS,
    NULL_REGISTRY,
    MetricsRegistry,
    NullRegistry,
    disable,
    enable,
    get_registry,
    timed,
)

__all__ = [
    "DURATION_BUCKETS",
    "LATENCY_BUCKETS",
    "NULL_REGISTRY",
    "MetricsRegistry",
    "NullRegistry",
    "disable",
    "enable",
    "get_registry",
    "timed",
]
