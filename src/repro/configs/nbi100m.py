"""nbi-100m — the framework's own ~110M-parameter reference model.

Used by the end-to-end training example (examples/train_e2e.py): small
enough to train a few hundred steps on CPU, big enough to exercise every
substrate layer (data pipeline, optimizer, checkpointing, eco-preemption).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="nbi-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=2048,
        vocab_size=32768,
        head_dim=64,
        tie_embeddings=True,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="nbi100m-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attn_chunk=16,
    )
