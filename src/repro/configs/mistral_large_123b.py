"""mistral-large-123b — dense GQA flagship.

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768
[hf:mistralai/Mistral-Large-Instruct-2407; unverified].
Deep + wide → defaults to 8 gradient-accumulation microbatches so the
per-step activation footprint fits 16 GB chips (see EXPERIMENTS.md §Perf).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=32768,
        head_dim=128,
        rope_theta=1e6,
        microbatch=8,
        # §Perf hillclimb: selective remat cuts repeated TP all-reduces in
        # the recompute pass (collective 78.1→67.5 s; MFU 19.6→20.2%).
        remat="selective",
        # Capacity: AdamW state 1.23 TB → 84 GB/chip with TP-only sharding;
        # ZeRO-3 2D sharding brings it to 4.8 GB/chip (fits v5e).
        fsdp=True,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="mistral-large-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attn_chunk=16,
        microbatch=2,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
