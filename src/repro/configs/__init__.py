"""Architecture configs — one module per assigned architecture.

``get_config(name)`` returns the full published config;
``get_smoke_config(name)`` returns a reduced same-family config for CPU
smoke tests (small widths/depths/vocabs, same code paths).
"""

from __future__ import annotations

import importlib

ARCHS = [
    "deepseek_moe_16b",
    "kimi_k2_1t_a32b",
    "rwkv6_7b",
    "codeqwen15_7b",
    "minicpm3_4b",
    "mistral_large_123b",
    "starcoder2_7b",
    "recurrentgemma_2b",
    "whisper_small",
    "llava_next_mistral_7b",
    "nbi100m",  # the framework's own end-to-end example model
]

_ALIASES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "rwkv6-7b": "rwkv6_7b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "minicpm3-4b": "minicpm3_4b",
    "mistral-large-123b": "mistral_large_123b",
    "starcoder2-7b": "starcoder2_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-small": "whisper_small",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "nbi-100m": "nbi100m",
}

ASSIGNED = [a for a in ARCHS if a != "nbi100m"]


def _module(name: str):
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", ""))
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str):
    return _module(name).config()


def get_smoke_config(name: str):
    return _module(name).smoke_config()
