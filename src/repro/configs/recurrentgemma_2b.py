"""recurrentgemma-2b — Griffin: RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000
[arXiv:2402.19427; hf]. Window-2048 local attention + O(1) recurrent state
→ runs the long_500k cell. Embeddings tied (Gemma lineage).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-2b",
        family="rglru",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        head_dim=256,
        attention="local",
        window=2048,
        block_pattern=("rec", "rec", "attn"),
        lru_width=2560,
        conv_width=4,
        rope_theta=1e4,
        tie_embeddings=True,
        sub_quadratic=True,
        remat="full",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="recurrentgemma-smoke",
        n_layers=5,  # 1 super-layer + 2 tail rec pairs
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=8,
        lru_width=64,
        attn_chunk=8,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
