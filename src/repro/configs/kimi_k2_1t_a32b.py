"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 routed experts top-8.

61L d_model=7168 64H (GQA kv=8 per assignment) d_ff=2048(expert)
vocab=163840 [arXiv:2501.kimi2; unverified]. 1 shared expert, 1 leading
dense layer (DeepSeek-V3 lineage).

Memory note (see EXPERIMENTS.md): ~1.03 T params do not fit a single
256×16 GB pod with fp32 AdamW state — this config uses the block-quantized
8-bit optimizer and gradient-accumulation microbatching by default.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=18432,  # dense (layer-0) MLP width
        vocab_size=163840,
        head_dim=128,
        n_experts=384,
        n_shared_experts=1,
        top_k=8,
        moe_d_ff=2048,
        n_dense_layers=1,
        rope_theta=5e4,
        # §Perf hillclimb: capacity C∝N makes one-hot dispatch cost linear
        # in group size — 256 saves ~2.3 s/step of dispatch-einsum compute.
        moe_group_tokens=256,
        optimizer="adamw8bit",
        microbatch=8,
        remat="selective",  # §Perf: −4% collective (fewer recompute psums)
        # Capacity: adamw8bit state ≈ 4.2 TB; model-axis-only sharding is
        # 256 GB/chip. ZeRO-3 2D sharding → 16.4 GB (single pod, at the
        # edge) / 8.2 GB (2-pod production mesh) — see EXPERIMENTS §Dry-run.
        fsdp=True,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="kimi-k2-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        moe_d_ff=32,
        n_experts=8,
        n_shared_experts=1,
        top_k=2,
        n_dense_layers=1,
        vocab_size=512,
        moe_group_tokens=32,
        attn_chunk=16,
        param_dtype="float32",
        dtype="float32",
        optimizer="adamw8bit",
        microbatch=1,
        remat="none",
    )
