"""minicpm3-4b — dense with MLA (multi-head latent attention).

62L d_model=2560 40H d_ff=6400 vocab=73448 [hf:openbmb/MiniCPM3-4B; hf].
MLA ranks from the published config: q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64. Decode uses the compressed-latent cache
with absorbed matmuls (see repro/models/transformer.py).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        attention="mla",
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        rope_theta=1e4,
        remat="full",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="minicpm3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        q_lora_rank=24,
        kv_lora_rank=16,
        qk_nope_dim=8,
        qk_rope_dim=8,
        v_head_dim=8,
        attn_chunk=16,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
