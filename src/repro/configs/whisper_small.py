"""whisper-small — encoder-decoder; conv audio frontend is a stub.

12L(+12 enc) d_model=768 12H d_ff=3072 vocab=51865 [arXiv:2212.04356;
unverified]. ``input_specs()`` provides precomputed frame embeddings
(B, 1500, 768); shapes apply to the decoder side. 12 heads / 51865 vocab do
not divide the 16-way model axis → those rules auto-disable (FF shards).
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small",
        family="encdec",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        n_enc_layers=12,
        enc_len=1500,
        tie_embeddings=True,
        attn_chunk=512,  # 12 heads cannot shard on a 16-way model axis →
        remat="full",    # keep attention tiles small instead
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="whisper-smoke",
        n_layers=2,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        enc_len=24,
        attn_chunk=8,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
