"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.

28L d_model=2048 16H (GQA kv=16) vocab=102400 [arXiv:2401.06066; hf].
The assigned d_ff=1408 is the per-expert (fine-grained) width; the leading
dense layer uses the published 10944 dense intermediate size.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,  # dense (layer-0) MLP width
        vocab_size=102400,
        head_dim=128,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        n_dense_layers=1,
        rope_theta=1e4,
        moe_group_tokens=1024,
        remat="full",
        microbatch=1,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="deepseek-moe-16b-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        moe_d_ff=32,
        n_experts=8,
        n_shared_experts=2,
        top_k=2,
        n_dense_layers=1,
        vocab_size=512,
        moe_group_tokens=32,
        attn_chunk=16,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
