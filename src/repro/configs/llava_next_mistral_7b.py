"""llava-next-mistral-7b — VLM: mistral-7b backbone, anyres tiling stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. The vision tower +
anyres tiling is a STUB: ``input_specs()`` provides 1152 precomputed patch
embeddings (2 anyres tiles × 576) prepended to the text sequence.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llava-next-mistral-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        head_dim=128,
        rope_theta=1e6,
        n_patches=1152,
        remat="full",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="llava-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        n_patches=8,
        attn_chunk=8,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
