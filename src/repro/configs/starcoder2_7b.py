"""starcoder2-7b — dense GQA, RoPE.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152 [arXiv:2402.19173; hf].
36 heads do not divide the 16-way model axis → attention activations stay
replicated over `model` (heads rule auto-disabled); FF/vocab still shard.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        head_dim=128,
        rope_theta=1e5,
        remat="full",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="starcoder2-smoke",
        n_layers=2,
        d_model=64,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attn_chunk=16,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
