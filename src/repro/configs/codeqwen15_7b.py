"""codeqwen1.5-7b — dense, qwen1.5 arch (full MHA-as-GQA kv=32).

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf].
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        head_dim=128,
        rope_theta=1e6,
        remat="full",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="codeqwen-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attn_chunk=16,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
