"""rwkv6-7b — Finch: attention-free RNN with data-dependent decay.

32L d_model=4096 d_ff=14336 vocab=65536 [arXiv:2404.05892; hf].
O(1) decode state → runs the long_500k cell.
"""

from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b",
        family="rwkv6",
        n_layers=32,
        d_model=4096,
        n_heads=64,  # d_model / rwkv_head_size
        n_kv_heads=64,
        d_ff=14336,
        vocab_size=65536,
        attention="none",
        rwkv_head_size=64,
        rwkv_lora_rank=32,
        rwkv_decay_lora=64,
        sub_quadratic=True,
        remat="full",
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        name="rwkv6-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        rwkv_head_size=16,
        rwkv_lora_rank=8,
        rwkv_decay_lora=8,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
