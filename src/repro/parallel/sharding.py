"""Logical-axis sharding rules (MaxText idiom, dependency-free).

One model definition carries *logical* axis names on every parameter,
activation constraint and cache leaf; this module resolves them to mesh
axes for a concrete (arch, mesh, batch) combination:

  batch    → ("pod", "data")   data parallelism (both axes)
  heads    → "model"           tensor parallelism over attention heads
  kv_heads → "model"
  ff       → "model"           tensor parallelism over MLP hidden
  vocab    → "model"           embedding/unembedding sharding
  experts  → "model"           expert parallelism (MoE all-to-all)
  lru      → "model"           RG-LRU width sharding
  kv_seq   → "model"           decode-time KV *sequence* sharding
                               (flash-decoding split-KV)
  embed/layers → replicated

Every rule self-disables when the corresponding dimension size is not
divisible by the mesh axis (e.g. whisper's 12 heads or starcoder2's 36 on a
16-way model axis; batch=1 for long_500k) — the table is *derived*, per
(cfg, mesh, shapes), not hand-maintained per arch.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ParamDef, set_logical_rules
from repro.models.config import ArchConfig

_MODEL_RULES = ("vocab", "heads", "kv_heads", "ff", "experts", "lru", "kv_seq", "seq")


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def rules_for(
    cfg: ArchConfig,
    mesh,
    *,
    param_defs=None,
    batch_size: int | None = None,
    extra_dims: dict | None = None,
    fsdp: "bool | None" = None,
) -> dict:
    """Derive the logical→mesh table, disabling non-divisible rules.

    ``param_defs``: the model's ParamDef tree — every (dim, logical) pair is
    checked. ``extra_dims``: activation/cache dims not visible in params,
    e.g. {"kv_seq": 32768, "batch": 256, "heads": n_heads}.
    """
    sizes = mesh_axis_sizes(mesh)
    model = sizes.get("model", 1)
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= sizes[a]

    # collect all dimension sizes per logical name
    dims: dict[str, set] = {}

    def note(name, size):
        if name is not None:
            dims.setdefault(name, set()).add(int(size))

    if param_defs is not None:
        for d in jax.tree_util.tree_leaves(
            param_defs, is_leaf=lambda x: isinstance(x, ParamDef)
        ):
            for size, name in zip(d.shape, d.logical):
                note(name, size)
    note("heads", cfg.n_heads)
    note("kv_heads", cfg.n_kv_heads)
    for name, size in (extra_dims or {}).items():
        note(name, size)

    rules: dict[str, object] = {"layers": None, "embed": None}
    for name in _MODEL_RULES:
        seen = dims.get(name, set())
        ok = model > 1 and seen and all(s % model == 0 for s in seen)
        if name == "seq" and not getattr(cfg, "seq_shard", False):
            ok = False  # sequence parallelism is an explicit perf lever
        rules[name] = "model" if ok else None
    if batch_size is not None and dp and batch_size % dp_total == 0:
        rules["batch"] = dp if len(dp) > 1 else dp[0]
    else:
        rules["batch"] = None

    # FSDP / ZeRO-3: weights' (and optimizer moments') "embed" dim sharded
    # over the data axes on top of the TP axes — 2D weight sharding. GSPMD
    # all-gathers each layer's shard at use (cheap: ~params×passes wire) and
    # reduce-scatters its gradient; without this, a 123 B AdamW state is
    # ~84 GB/chip on the 16×16 mesh — 5× over a v5e's HBM. Training only.
    use_fsdp = getattr(cfg, "fsdp", False) if fsdp is None else fsdp
    if use_fsdp and dp:
        emb = dims.get("embed", set())
        if emb and all(s % dp_total == 0 for s in emb):
            rules["embed"] = dp if len(dp) > 1 else dp[0]
    return rules


def spec_for(logical: tuple, rules: dict) -> P:
    return P(*(rules.get(name) if name is not None else None for name in logical))


def named_sharding(mesh, logical: tuple, rules: dict) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical, rules))


def resolve_tree(mesh, logical_tree, rules: dict):
    """Logical tree (tuples as leaves) → NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda lg: named_sharding(mesh, lg, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


@contextlib.contextmanager
def use_rules(rules: dict, mesh):
    set_logical_rules(rules, mesh)
    try:
        yield
    finally:
        set_logical_rules(None, None)


def with_rules(fn, rules: dict, mesh):
    """Wrap a step function so logical `shard()` constraints resolve during
    tracing (jit.lower happens under the wrapper)."""

    def wrapped(*args, **kwargs):
        with use_rules(rules, mesh):
            return fn(*args, **kwargs)

    return wrapped
