from .sharding import (
    dp_axes,
    named_sharding,
    resolve_tree,
    rules_for,
    with_rules,
)

__all__ = ["dp_axes", "named_sharding", "resolve_tree", "rules_for", "with_rules"]

from .compression import (  # noqa: E402
    dequant_int8,
    ef_compressed_psum,
    init_ef_state,
    quant_int8,
    wire_bytes_per_param,
)

__all__ += [
    "dequant_int8", "ef_compressed_psum", "init_ef_state", "quant_int8",
    "wire_bytes_per_param",
]
