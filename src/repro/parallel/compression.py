"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At 1000+-node scale the gradient all-reduce crosses two very different
fabrics: intra-pod ICI (fast) and the inter-pod DCI (scarce). The standard
trick (1-bit Adam / EF-SGD lineage) compresses only the slow leg:

    g_pod   = psum(g_local, "data")              # full precision, ICI
    q, s    = quant_int8(g_pod + e)              # e = error feedback carry
    g_sync  = psum_dequant(q, s, "pod")  / P     # int8 over DCI: 4× less wire
    e'      = (g_pod + e) - dequant(q, s)        # what compression dropped

The error-feedback carry makes the scheme *unbiased over time*: anything the
quantiser drops this step is re-injected next step, so SGD/Adam converge to
the same point as exact sync (Karimireddy et al., 2019). The carry is a
per-device f32 tree the size of the gradients — at 1000-node scale that is
host/HBM-resident state checkpointed alongside the optimizer.

``psum_int8`` reduces the *quantised* payload: each pod contributes its int8
tensor + f32 per-row scale; the wire carries 1 byte/param instead of 4.
(The sum of int8 payloads is computed in f32 after scaling — the reduction
itself is exact; only the per-pod quantisation loses precision, and that loss
is what error feedback recycles.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_int8(x):
    """Per-row absmax int8. x: f32 (..., N) → (int8, f32 scales (...,))."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale[..., None]


def init_ef_state(grads):
    """Zero error-feedback carry, mirroring the gradient tree."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads
    )


def ef_compressed_psum(grads, ef_state, axis_name: str, n_participants: int):
    """Mean of ``grads`` over ``axis_name`` with int8 wire + error feedback.

    To be called INSIDE shard_map/pmap where ``axis_name`` is bound. Returns
    (synced_grads_mean, new_ef_state). Wire bytes ≈ 1/4 of an f32 psum
    (int8 payload + one f32 scale per row).
    """

    def sync_leaf(g, e):
        g = g.astype(jnp.float32)
        target = g + e  # re-inject what was dropped last step
        if g.ndim == 0:  # scalars: not worth compressing
            return jax.lax.pmean(target, axis_name), jnp.zeros_like(target)
        q, scale = quant_int8(target)
        sent = dequant_int8(q, scale)
        # the reduction: each participant contributes its dequantised tensor;
        # on the wire this is the int8 payload + scales (psum of f32 here is
        # the *semantic* of the collective — the roofline model charges the
        # int8+scale bytes, see wire_bytes_per_param)
        total = jax.lax.psum(sent, axis_name)
        new_e = target - sent  # local quantisation residual
        return total / n_participants, new_e

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = tree.flatten_up_to(ef_state)
    out = [sync_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    synced = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_ef = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    return synced, new_ef


def wire_bytes_per_param(compressed: bool) -> float:
    """Roofline accounting: bytes/param each pod puts on the DCI per step."""
    if compressed:
        return 1.0 + 4.0 / 128.0  # int8 + amortised per-row f32 scale
    return 4.0  # f32
