"""ANSI table + JSON rendering shared by the CLI tools.

No external dependency (the Perl original uses Text::ASCIITable +
Term::ANSIColor; this is the equivalent, honouring NO_COLOR and non-tty).
``emit_json`` is the one serializer behind every tool's ``--json`` flag
(lsjobs, whojobs, ecoreport), so scripted consumers see a single dialect:
two-space indent, sorted keys, ISO strings for datetimes.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys

RESET = "\x1b[0m"
COLORS = {
    "red": "\x1b[31m", "green": "\x1b[32m", "yellow": "\x1b[33m",
    "blue": "\x1b[34m", "magenta": "\x1b[35m", "cyan": "\x1b[36m",
    "grey": "\x1b[90m", "bold": "\x1b[1m", "inverse": "\x1b[7m",
}

STATE_COLORS = {
    "RUNNING": "green",
    "PENDING": "yellow",
    "SUSPENDED": "magenta",
    "COMPLETING": "cyan",
    "CONFIGURING": "cyan",
    "FAILED": "red",
    "TIMEOUT": "red",
    "NODE_FAIL": "red",
    "CANCELLED": "grey",
    "COMPLETED": "blue",
}


def _json_default(obj):
    if hasattr(obj, "to_dict"):  # curated payloads win over raw asdict
        return obj.to_dict()
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return dataclasses.asdict(obj)
    if hasattr(obj, "isoformat"):  # datetime/date
        return obj.isoformat()
    return str(obj)


def to_json(payload) -> str:
    """The CLI suite's canonical JSON dialect (stable for scripting)."""
    return json.dumps(payload, indent=2, sort_keys=True, default=_json_default)


def emit_json(payload, fh=None) -> None:
    """Serialize ``payload`` and print it — every ``--json`` flag ends here."""
    print(to_json(payload), file=fh if fh is not None else sys.stdout)


def use_color(force: bool | None = None) -> bool:
    if force is not None:
        return force
    if os.environ.get("NO_COLOR"):
        return False
    return sys.stdout.isatty()


def paint(text: str, color: str, enabled: bool = True) -> str:
    if not enabled or color not in COLORS:
        return text
    return f"{COLORS[color]}{text}{RESET}"


def state_color(state: str) -> str:
    return STATE_COLORS.get(state, "")


def render_table(
    headers: list[str],
    rows: list[list[str]],
    *,
    color_for_row=None,
    max_widths: dict | None = None,
    enabled: bool | None = None,
) -> str:
    """Fixed-width ASCII table with optional per-row colouring."""
    en = use_color(enabled)
    cols = len(headers)
    widths = [len(h) for h in headers]
    srows = [[str(c) for c in r] for r in rows]
    for r in srows:
        for i in range(cols):
            widths[i] = max(widths[i], len(r[i]) if i < len(r) else 0)
    if max_widths:
        for i, h in enumerate(headers):
            if h in max_widths:
                widths[i] = min(widths[i], max_widths[h])

    def fmt_cell(text, w):
        text = text if len(text) <= w else text[: max(0, w - 1)] + "…"
        return text.ljust(w)

    sep = "+".join("-" * (w + 2) for w in widths)
    sep = f"+{sep}+"
    out = [sep, "| " + " | ".join(fmt_cell(h, w) for h, w in zip(headers, widths)) + " |", sep]
    for r in srows:
        cells = " | ".join(
            fmt_cell(r[i] if i < len(r) else "", widths[i]) for i in range(cols)
        )
        line = f"| {cells} |"
        if color_for_row:
            c = color_for_row(r)
            if c:
                line = paint(line, c, en)
        out.append(line)
    out.append(sep)
    return "\n".join(out)
