"""nbid — the NBI-Slurm gateway daemon.

    nbid                        # serve in the foreground (^C to stop)
    nbid --status               # one-line health of the running daemon
    nbid --status --json        # full stats RPC payload
    nbid --stop                 # ask the running daemon to shut down

One nbid per host owns the QueueCache, EventBus, federation
Placer/BacklogTracker and EcoController; every CLI (lsjobs, runjob,
waitjobs, viewjobs, whojobs, nbimon) detects the socket automatically and
becomes a thin client — one backend poll serves all of them, and held eco
jobs keep being released after the submitting shells exit. See
``docs/gateway.md`` for the protocol and a systemd user-service example.
"""

from __future__ import annotations

import argparse
import signal
import sys

from repro.core.gateway import GatewayServer, default_socket_path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="nbid", description="serve the NBI-Slurm gateway daemon"
    )
    ap.add_argument("--socket", default=None, metavar="PATH",
                    help="Unix socket to serve on (default: "
                         "$NBI_GATEWAY_SOCKET or the per-user runtime path)")
    ap.add_argument("--backend", default=None, metavar="KIND",
                    help="backend kind (slurm|sim|federated; default: "
                         "$REPRO_BACKEND / auto)")
    ap.add_argument("--ttl", type=float, default=2.0,
                    help="QueueCache TTL seconds (default 2; events "
                         "invalidate sooner)")
    ap.add_argument("--poll", type=float, default=15.0,
                    help="background poll/tick cadence against real "
                         "backends (default 15s)")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="fair-share tokens/s per user (default 50)")
    ap.add_argument("--burst", type=float, default=100.0,
                    help="fair-share bucket capacity per user (default 100)")
    ap.add_argument("--no-eco", dest="eco", action="store_false",
                    help="do not own an EcoController (clients then manage "
                         "held jobs themselves)")
    ap.add_argument("--obs", action="store_true",
                    help="enable the metrics registry (NBI_OBS=1 "
                         "equivalent) so stats/nbimon scrapes carry "
                         "request-latency metrics")
    ap.add_argument("--status", action="store_true",
                    help="query the running daemon instead of serving")
    ap.add_argument("--stop", action="store_true",
                    help="shut the running daemon down")
    ap.add_argument("--json", action="store_true",
                    help="with --status: emit the full stats payload")
    args = ap.parse_args(argv)
    socket_path = args.socket or default_socket_path()

    if args.status or args.stop:
        from repro.cli.session import GatewayClient

        client = GatewayClient(socket_path)
        try:
            if args.stop:
                client.shutdown()
                print(f"gateway at {socket_path} stopping")
                return 0
            stats = client.stats()
        except ConnectionError as e:
            print(f"nbid: {e}", file=sys.stderr)
            return 1
        if args.json:
            from repro.cli.render import emit_json

            emit_json(stats)
        else:
            d = stats.get("daemon", {})
            qc = stats.get("queue_cache", {})
            eco = stats.get("eco", {})
            print(
                f"nbid pid {d.get('pid')} on {d.get('socket')} "
                f"[{d.get('backend')}] up {d.get('uptime_s', 0.0):.0f}s | "
                f"{d.get('connections', 0)} conn, "
                f"{sum(d.get('requests', {}).values())} req, "
                f"{d.get('throttled', 0)} throttled | "
                f"cache {qc.get('polls', 0)} polls / {qc.get('hits', 0)} hits"
                + (f" | eco {eco.get('held', 0)} held" if eco else "")
            )
        return 0

    if args.obs:
        from repro.obs import enable

        enable()
    backend = None
    if args.backend:
        from repro.core import get_backend

        backend = get_backend(args.backend)
    server = GatewayServer(
        backend,
        socket_path,
        ttl_s=args.ttl,
        eco=args.eco,
        rate=args.rate,
        burst=args.burst,
        poll_s=args.poll,
    )
    try:
        server.bind()
    except Exception as e:  # noqa: BLE001 — stale socket, perms, live daemon
        print(f"nbid: cannot bind {socket_path}: {e}", file=sys.stderr)
        return 1

    def _stop(signum, frame):
        server.close()

    signal.signal(signal.SIGTERM, _stop)
    signal.signal(signal.SIGINT, _stop)
    print(
        f"nbid: serving {type(server.backend).__name__} on {socket_path} "
        f"(eco={'on' if server.controller else 'off'}, "
        f"rate={args.rate:g}/s burst={args.burst:g})",
        flush=True,
    )
    try:
        server.serve_forever()
    finally:
        server.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
