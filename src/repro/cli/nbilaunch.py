"""nbilaunch — run a declarative tool wrapper (NBI::Launcher port).

    nbilaunch --list                             # discover available wrappers
    nbilaunch kraken2 reads1=r1.fq db=/dbs/k2 -- --cpus 16 --mem 200
    nbilaunch train arch=nbi-100m steps=200 --no-eco

Wrapper arguments are ``key=value`` pairs (validated against the wrapper's
declared inputs/params); flags after ``--`` adjust SLURM resources. Third-
party wrappers in ``~/.nbi/launchers/*.py`` are discovered automatically.
"""

from __future__ import annotations

import argparse

from repro.core import discover_launchers, LauncherError


def parse_kv(pairs: list[str]) -> dict:
    out = {}
    for p in pairs:
        if "=" not in p:
            raise SystemExit(f"expected key=value, got {p!r}")
        k, _, v = p.partition("=")
        # best-effort typing: int → float → str
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out[k] = v
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="nbilaunch")
    ap.add_argument("tool", nargs="?", help="wrapper name (see --list)")
    ap.add_argument("args", nargs="*", help="key=value wrapper arguments")
    ap.add_argument("--list", action="store_true", help="list available wrappers")
    ap.add_argument("--launcher-dir", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--outdir", default=".")
    ap.add_argument("--cpus", type=int, default=None)
    ap.add_argument("--mem", default=None, help="GB (bare) or 500MB/8GB")
    ap.add_argument("--time", default=None, help="hours (bare) or 2h30m")
    ap.add_argument("--queue", default=None)
    ap.add_argument("--eco", dest="eco", action="store_true", default=None)
    ap.add_argument("--no-eco", dest="eco", action="store_false")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the generated script, do not submit")
    ap.add_argument("--now", default=None, help=argparse.SUPPRESS)  # tests
    args = ap.parse_args(argv)

    found = discover_launchers(args.launcher_dir)
    if args.list or not args.tool:
        for name, cls in sorted(found.items()):
            doc = (cls.__doc__ or "").strip().splitlines()[0] if cls.__doc__ else ""
            print(f"{name:12s} {doc}")
        return 0

    if args.tool not in found:
        print(f"unknown wrapper {args.tool!r}; try --list")
        return 1

    cls = found[args.tool]
    try:
        launcher = cls(outdir=args.outdir, eco=args.eco, **parse_kv(args.args))
    except LauncherError as e:
        print(f"error: {e}")
        return 1

    # resource overrides after construction (mirror runjob's units)
    from repro.cli.runjob import memory_mb_from_cli
    from repro.core import parse_time_s

    if args.cpus is not None:
        launcher.opts.threads = args.cpus
    if args.mem is not None:
        launcher.opts.memory_mb = memory_mb_from_cli(args.mem)
    if args.time is not None:
        launcher.opts.time_s = parse_time_s(args.time)
    if args.queue is not None:
        launcher.opts.queue = args.queue

    if args.dry_run:
        print(launcher.to_job().script(), end="")
        return 0

    from datetime import datetime

    now = datetime.fromisoformat(args.now) if args.now else None
    jobid = launcher.submit(now=now)
    print(jobid)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
