"""nbimon — runtime observability surface (metrics + job-lifecycle spans).

    nbimon                         # one-shot Prometheus text dump to stdout
    nbimon --json                  # registry snapshot in the shared JSON dialect
    nbimon --snapshot f.json ...   # render a saved snapshot (e.g. the
                                   # benchmark day's results/obs_day.json)
                                   # instead of this process's registry
    nbimon --textfile out.prom     # write the node-exporter textfile
    nbimon --check-textfile f.prom # validate an exposition file (CI gate)
    nbimon --live                  # event ticker over the bus (mirrors
                                   # viewjobs --live), summary stats on exit

Metrics are per-process: a bare ``nbimon`` only sees what *this* process
recorded, which is why long runs (benchmarks, daemons) persist a snapshot
for ``--snapshot`` to render. ``--live`` enables the registry, attaches a
:class:`~repro.obs.trace.JobTracer` to the backend's event bus (the
simulator's native bus, or a :class:`~repro.core.events.
PollingEventAdapter` on real SLURM) and prints one line per job
transition, then the session's span/cache summary.
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from repro.obs import enable, get_registry
from repro.obs import export as obs_export


def _fmt_event(e) -> str:
    when = e.at.strftime("%H:%M:%S") if hasattr(e.at, "strftime") else str(e.at)
    tail = " ".join(p for p in (e.name, e.cluster and f"[{e.cluster}]") if p)
    return f"{when} {e.type:<9} {e.jobid} {tail}".rstrip()


def live_ticker(
    backend,
    *,
    ticks: "int | None" = None,
    duration_s: float = 0.0,
    poll_s: float = 2.0,
    out=print,
    sleep=time.sleep,
):
    """Stream job events from ``backend`` and return the tracer.

    On a simulator (native bus) each tick advances simulated time and the
    loop ends early once the queue drains; on real SLURM each tick is one
    adapter poll. ``ticks`` bounds the loop directly (tests);
    ``duration_s`` converts to ticks at ``poll_s`` (0 = run until drained
    / forever).
    """
    from repro.core.events import PollingEventAdapter
    from repro.obs.trace import JobTracer

    inner = getattr(backend, "inner", backend)
    bus = getattr(inner, "bus", None)
    sim_like = bus is not None and hasattr(inner, "advance")
    adapter = None
    if bus is None:
        adapter = PollingEventAdapter(backend)
        bus = adapter.bus
        adapter.poll()  # baseline snapshot yields no events
    tracer = JobTracer().attach(bus)
    token = bus.subscribe(lambda e: out(_fmt_event(e)))
    if ticks is None and duration_s:
        ticks = max(1, int(duration_s / max(poll_s, 1e-9)))
    try:
        i = 0
        while ticks is None or i < ticks:
            if sim_like:
                backend.advance(poll_s)
                if not backend.queue():
                    break  # simulated queue drained — nothing left to watch
            else:
                sleep(poll_s)
                adapter.poll()
            i += 1
    except KeyboardInterrupt:
        pass
    finally:
        bus.unsubscribe(token)
        tracer.detach()
    return tracer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="nbimon",
        description="dump, export, validate or live-stream runtime metrics",
    )
    ap.add_argument("--json", action="store_true",
                    help="emit the metrics snapshot as JSON")
    ap.add_argument("--snapshot", default=None, metavar="PATH",
                    help="render a saved snapshot JSON instead of this "
                         "process's registry")
    ap.add_argument("--textfile", default=None, metavar="OUT",
                    help="write a Prometheus textfile (node-exporter "
                         "textfile-collector format)")
    ap.add_argument("--check-textfile", default=None, metavar="PATH",
                    help="parse+validate an exposition file; exit 1 if "
                         "malformed")
    ap.add_argument("--live", action="store_true",
                    help="stream job events from the backend bus; prints "
                         "session stats on exit")
    ap.add_argument("--poll", type=float, default=2.0,
                    help="seconds between live ticks (default 2)")
    ap.add_argument("--for", dest="duration", type=float, default=0.0,
                    help="live duration in seconds (0 = until drained / "
                         "interrupted)")
    from repro.cli.session import add_gateway_args

    add_gateway_args(ap)
    args = ap.parse_args(argv)

    from repro.cli.render import emit_json

    if args.check_textfile:
        try:
            families = obs_export.parse_textfile(
                Path(args.check_textfile).read_text(encoding="utf-8")
            )
        except (OSError, ValueError) as e:
            print(f"nbimon: invalid textfile: {e}", file=sys.stderr)
            return 1
        if args.json:
            emit_json({"ok": True, "families": families})
        else:
            samples = sum(f["samples"] for f in families.values())
            print(f"ok: {len(families)} families, {samples} samples")
        return 0

    if args.live:
        enable()  # the ticker's own counters should actually record
        from repro.cli.session import GatewayClient, resolve_backend

        try:
            backend = resolve_backend(args.gateway, args.gateway_socket)
        except ConnectionError as e:
            print(f"nbimon: {e}", file=sys.stderr)
            return 1
        # --json promises machine-readable stdout: ticker lines move to
        # stderr so the final stats payload parses clean
        out = (lambda line: print(line, file=sys.stderr)) if args.json else print
        if isinstance(backend, GatewayClient):
            # daemon mode: stream the daemon's aggregated event ticker —
            # no in-process bus or polling adapter, the daemon's single
            # subscription fans out to every nbimon on the host
            count = 0
            try:
                for e in backend.events(
                    poll_s=args.poll, duration_s=args.duration
                ):
                    out(_fmt_event(e))
                    count += 1
            except KeyboardInterrupt:
                pass
            except ConnectionError as e:
                print(f"nbimon: event stream lost: {e}", file=sys.stderr)
                return 1
            try:
                payload = backend.stats()
            except ConnectionError:
                payload = {}
            payload["events_streamed"] = count
            if args.json:
                emit_json(payload)
            else:
                print(f"{count} event(s) streamed from {backend.socket_path}")
            return 0
        tracer = live_ticker(
            backend, duration_s=args.duration, poll_s=args.poll, out=out
        )
        stats = obs_export.session_stats(
            cache=backend, registry=get_registry(), tracer=tracer
        )
        if args.json:
            emit_json(stats)
        else:
            t = tracer.to_dict()
            print(
                f"{t['events_seen']} event(s), {t['spans_finished']} span(s) "
                f"finished, {t['spans_open']} open"
            )
        return 0

    if args.gateway:
        # scrape the daemon: its stats RPC carries daemon counters, queue-
        # cache numbers and (when the daemon runs with NBI_OBS=1) the full
        # metrics snapshot — rendered as Prometheus text like a local dump
        from repro.cli.session import GatewayClient

        try:
            payload = GatewayClient(args.gateway_socket).stats()
        except ConnectionError as e:
            print(f"nbimon: {e}", file=sys.stderr)
            return 1
        if args.json:
            emit_json(payload)
        elif payload.get("metrics"):
            sys.stdout.write(obs_export.prometheus_from_snapshot(
                {"metrics": payload["metrics"]}
            ))
        else:
            d = payload.get("daemon", {})
            qc = payload.get("queue_cache", {})
            print(
                f"gateway pid {d.get('pid')} up {d.get('uptime_s', 0.0):.0f}s "
                f"| {d.get('connections', 0)} connection(s), "
                f"{sum(d.get('requests', {}).values())} request(s), "
                f"{d.get('throttled', 0)} throttled "
                f"| cache: {qc.get('polls', 0)} poll(s), "
                f"{qc.get('hits', 0)} hit(s)"
            )
        return 0

    if args.snapshot:
        snap = obs_export.load_snapshot(args.snapshot)
    else:
        snap = obs_export.snapshot(get_registry())
    if args.textfile:
        obs_export.write_textfile(args.textfile, snap=snap)
        if not args.json:
            print(f"wrote {args.textfile}")
    if args.json:
        emit_json(snap)
    elif not args.textfile:
        sys.stdout.write(obs_export.prometheus_from_snapshot(snap))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
