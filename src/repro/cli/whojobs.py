"""whojobs — cluster utilisation grouped by user.

One row per user: running/pending job counts, CPUs and memory in use, and a
share bar — the at-a-glance "who is using the cluster" view.
"""

from __future__ import annotations

import argparse

from repro.core import Queue
from repro.cli.render import emit_json, render_table
from repro.cli.session import add_gateway_args, resolve_backend


def utilisation_records(q: Queue) -> list[dict]:
    """Per-user utilisation, sorted by CPUs in use (the ``--json`` payload).

    On a federation each record additionally carries ``clusters``, the
    user's running CPUs broken down per member; single-cluster payloads
    are unchanged.
    """
    per_user: dict[str, dict] = {}
    total_cpus = 0
    federated = any(j.cluster for j in q)
    for j in q:
        u = per_user.setdefault(
            j.user, {"run": 0, "pend": 0, "cpus": 0, "mem_mb": 0, "clusters": {}}
        )
        cpus = int(j.cpus or 0)
        mem = int(j.memory or 0)
        if j.state == "RUNNING":
            u["run"] += 1
            u["cpus"] += cpus
            u["mem_mb"] += mem
            total_cpus += cpus
            if j.cluster:
                u["clusters"][j.cluster] = u["clusters"].get(j.cluster, 0) + cpus
        elif j.state == "PENDING":
            u["pend"] += 1
    out = []
    for user, u in sorted(per_user.items(), key=lambda kv: -kv[1]["cpus"]):
        share = u["cpus"] / total_cpus if total_cpus else 0.0
        rec = {
            "user": user,
            "running": u["run"],
            "pending": u["pend"],
            "cpus": u["cpus"],
            "mem_mb": u["mem_mb"],
            "share": round(share, 4),
        }
        if federated:
            rec["clusters"] = dict(sorted(u["clusters"].items()))
        out.append(rec)
    return out


def utilisation_rows(q: Queue) -> list[list[str]]:
    rows = []
    records = utilisation_records(q)
    federated = any("clusters" in r for r in records)
    for r in records:
        bar = "#" * round(r["share"] * 20)
        row = [
            r["user"],
            str(r["running"]),
            str(r["pending"]),
            str(r["cpus"]),
            f"{r['mem_mb'] / 1024:.0f}",
            f"{r['share'] * 100:4.0f}% {bar}",
        ]
        if federated:
            row.append(" ".join(
                f"{name}:{cpus}" for name, cpus in r.get("clusters", {}).items()
            ))
        rows.append(row)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="whojobs")
    ap.add_argument("-q", "--queue", dest="partition", default=None)
    ap.add_argument("--json", dest="as_json", action="store_true",
                    help="emit per-user utilisation as JSON for scripting")
    ap.add_argument("--no-color", action="store_true")
    add_gateway_args(ap)
    args = ap.parse_args(argv)

    backend = resolve_backend(args.gateway, args.gateway_socket)
    # only RUNNING/PENDING rows feed the utilisation table: push the
    # state filter to the daemon so it ships two states, not the queue
    q = Queue(state=["RUNNING", "PENDING"], queue=args.partition,
              backend=backend)
    if args.as_json:
        emit_json(utilisation_records(q))
        return 0
    if not len(q):
        print("cluster is idle")
        return 0
    headers = ["User", "Running", "Pending", "CPUs", "Mem(GB)", "Share"]
    if any(j.cluster for j in q):
        headers.append("Clusters")
    print(
        render_table(
            headers,
            utilisation_rows(q),
            enabled=False if args.no_color else None,
        )
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
