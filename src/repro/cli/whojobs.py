"""whojobs — cluster utilisation grouped by user.

One row per user: running/pending job counts, CPUs and memory in use, and a
share bar — the at-a-glance "who is using the cluster" view.
"""

from __future__ import annotations

import argparse

from repro.core import Queue, get_queue_cache
from repro.cli.render import render_table


def utilisation_rows(q: Queue) -> list[list[str]]:
    per_user: dict[str, dict] = {}
    total_cpus = 0
    for j in q:
        u = per_user.setdefault(
            j.user, {"run": 0, "pend": 0, "cpus": 0, "mem_mb": 0}
        )
        cpus = int(j.cpus or 0)
        mem = int(j.memory or 0)
        if j.state == "RUNNING":
            u["run"] += 1
            u["cpus"] += cpus
            u["mem_mb"] += mem
            total_cpus += cpus
        elif j.state == "PENDING":
            u["pend"] += 1
    rows = []
    for user, u in sorted(per_user.items(), key=lambda kv: -kv[1]["cpus"]):
        share = u["cpus"] / total_cpus if total_cpus else 0.0
        bar = "#" * round(share * 20)
        rows.append(
            [
                user,
                str(u["run"]),
                str(u["pend"]),
                str(u["cpus"]),
                f"{u['mem_mb'] / 1024:.0f}",
                f"{share * 100:4.0f}% {bar}",
            ]
        )
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="whojobs")
    ap.add_argument("-q", "--queue", dest="partition", default=None)
    ap.add_argument("--no-color", action="store_true")
    args = ap.parse_args(argv)

    q = Queue(queue=args.partition, backend=get_queue_cache())
    if not len(q):
        print("cluster is idle")
        return 0
    print(
        render_table(
            ["User", "Running", "Pending", "CPUs", "Mem(GB)", "Share"],
            utilisation_rows(q),
            enabled=False if args.no_color else None,
        )
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
