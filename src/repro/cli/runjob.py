"""runjob — submit a command as a SLURM job with resource flags.

Paper examples, reproduced exactly:

  runjob -n "assembly" -c 18 -m 64 -t 12 -w ./logs/ \\
      "flye --nano-raw reads.fastq --out-dir asm"

  runjob -n "align" -c 8 -m 16 --files samples.txt \\
      "bwa mem ref.fa #FILE# > #FILE#.bam"

  runjob --eco -n "annotate" -t 6 "prokka genome.fa"

Bare ``-m`` is gigabytes and bare ``-t`` is hours (unit suffixes accepted:
``-m 500MB``, ``-t 2h30m``). Eco mode is ON by default (config key
``economy_mode``; override per-job with --eco/--no-eco): the EcoScheduler
injects ``--begin=<next eco window>`` with no change to the command.

Batch mode: ``--from-file cmds.txt`` reads one shell command per line and
submits the whole batch through the SubmitEngine; adding ``--array`` folds
the batch into a single SLURM job array (one sbatch call, ids ``base_k``).
"""

from __future__ import annotations

import argparse
import sys
from copy import deepcopy
from datetime import datetime

from repro.core import (
    EcoScheduler,
    Job,
    Opts,
    SubmitEngine,
    get_backend,
    load_config,
    parse_memory_mb,
    parse_time_s,
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="runjob", description="Submit a command as a SLURM job."
    )
    ap.add_argument("command", nargs="*", help="command to run (quote it)")
    ap.add_argument("-n", "--name", default="job")
    ap.add_argument("-c", "--cpus", type=int, default=1)
    ap.add_argument("-m", "--memory", default="1GB",
                    help="bare number = GB; accepts 500MB / 8GB / 1TB")
    ap.add_argument("-t", "--time", default="1h",
                    help="bare number = hours; accepts 2h30m / 0-12:00:00")
    ap.add_argument("-q", "--queue", default=None)
    ap.add_argument("-w", "--workdir-logs", dest="output_dir", default="",
                    help="directory for stdout/err logs")
    ap.add_argument("--files", default=None,
                    help="file list → job array; use #FILE# in the command")
    ap.add_argument("--from-file", dest="from_file", default=None,
                    help="read one command per line; submit them as a batch")
    ap.add_argument("--array", action="store_true",
                    help="coalesce the --from-file batch into one job array")
    ap.add_argument("--email", default="")
    ap.add_argument("--after", action="append", default=[],
                    help="job id this job depends on (afterok; repeatable)")
    ap.add_argument("--begin", default="", help="explicit --begin (ISO8601)")
    ap.add_argument("--eco", dest="eco", action="store_true", default=None,
                    help="defer to the next eco window (default: config)")
    ap.add_argument("--no-eco", dest="eco", action="store_false")
    ap.add_argument("--eco-hold", action="store_true",
                    help="eco v2: submit deferred jobs HELD (no --begin) and "
                         "release reactively when load drops — never later "
                         "than the static begin (see waitjobs --eco-release)")
    ap.add_argument("--cluster", default=None,
                    help="federation: pin the job to this member cluster "
                         "(default: the default cluster)")
    ap.add_argument("--anywhere", action="store_true",
                    help="federation: let the placement router pick the "
                         "cluster — greenest feasible for eco jobs, "
                         "fastest for urgent ones")
    ap.add_argument("--gres", default="")
    ap.add_argument("--sbatch", action="append", default=[],
                    help="raw #SBATCH pass-through (repeatable)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the generated script, do not submit")
    ap.add_argument("--now", default=None, help=argparse.SUPPRESS)  # tests
    from repro.cli.session import add_gateway_args

    add_gateway_args(ap)
    return ap


def memory_mb_from_cli(value) -> int:
    """Bare numbers are GB on the CLI (paper: ``-m 64`` = 64 GB)."""
    s = str(value).strip()
    if s.replace(".", "", 1).isdigit():
        return int(float(s) * 1024)
    return parse_memory_mb(s)


def read_command_file(path: str) -> list[str]:
    """One command per line; blank lines and ``#`` comments skipped
    (same list-file format as ``Job(files=...)``)."""
    return Job._load_files(path)


def _hold_controller(sched, now):
    """The release agent for jobs this invocation just submitted held.

    Against the shared simulator its tick hook keeps releasing after
    main() returns (the sim owns the reference); real SLURM has no
    in-cluster agent, so warn that an adopter must run.
    """
    from repro.core import EcoController, get_backend

    controller = EcoController(get_backend(), sched, now=now)
    if not controller.self_driving:
        print(
            "note: --eco-hold needs a release agent — keep "
            "`waitjobs --eco-release` (or a cron adoption loop) "
            "running, or the job stays held",
            file=sys.stderr,
        )
    return controller


def _submit_via_gateway(client, args, opts) -> int:
    """Submit through a live nbid daemon.

    Placement, array coalescing, eco pricing AND hold-and-release all
    happen daemon-side (the daemon owns the EcoController, so held jobs
    keep being released after this shell exits — no adoption loop
    needed). The client only ships Job payloads and prints ids.
    """
    if args.from_file:
        try:
            commands = read_command_file(args.from_file)
        except OSError as e:
            print(f"cannot read {args.from_file}: {e.strerror or e}",
                  file=sys.stderr)
            return 1
        if not commands:
            print(f"no commands in {args.from_file}", file=sys.stderr)
            return 1
        jobs = [
            Job(name=args.name if args.array else f"{args.name}-{i}",
                command=cmd, opts=deepcopy(opts))
            for i, cmd in enumerate(commands)
        ]
    else:
        jobs = [Job(name=args.name, command=" ".join(args.command),
                    opts=opts, files=args.files, workdir="")]
    if args.cluster:
        for job in jobs:
            job.cluster = args.cluster
    result = client.submit_batch(
        jobs, eco=args.eco, coalesce=bool(args.array)
    )
    if result["eco_deferred"]:
        print(
            f"eco mode: {result['eco_deferred']} submission(s) held for "
            f"favourable load (released by the gateway daemon)"
        )
    for jid in result["ids"]:
        print(jid)
    if args.array and args.from_file:
        print(
            f"# {len(result['ids'])} task(s) in "
            f"{result['sbatch_calls']} submission(s) [gateway]",
            file=sys.stderr,
        )
    return 0


def main(argv=None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    if not args.command and not args.from_file:
        ap.error("a command (or --from-file) is required")
    if args.command and args.from_file:
        ap.error("give either a command or --from-file, not both")
    if args.files and args.from_file:
        ap.error("--files (one argument per task) and --from-file "
                 "(one command per task) are mutually exclusive")
    if args.array and not args.from_file:
        ap.error("--array requires --from-file")
    if args.cluster and args.anywhere:
        ap.error("--cluster pins a member; --anywhere routes freely — "
                 "pick one")
    cfg = load_config()

    # --- daemon mode: a live nbid owns pricing/placement/holding; this
    # process stays a thin client (dry runs always render locally)
    if not args.dry_run and args.gateway is not False:
        from repro.cli.session import GatewayClient, resolve_backend

        be = resolve_backend(args.gateway, args.gateway_socket)
        if isinstance(be, GatewayClient):
            opts = Opts(
                queue=args.queue if args.queue is not None else cfg.get("queue"),
                threads=args.cpus,
                memory_mb=memory_mb_from_cli(args.memory),
                time_s=parse_time_s(args.time),
                email_address=args.email,
                email_type="END" if args.email else "NONE",
                output_dir=args.output_dir,
                gres=args.gres,
                extra=list(args.sbatch),
                tmpdir=cfg.get("tmpdir") or "",
            )
            if args.after:
                opts.dependencies = [int(a) for a in args.after]
            if args.begin:
                opts.set_begin(args.begin)
            return _submit_via_gateway(be, args, opts)

    # --- federation routing: resolve which member cluster this goes to
    backend = get_backend()
    registry = getattr(backend, "registry", None)
    route_cluster = None  # None = not federated; "" = placer decides
    if registry is not None:
        if args.cluster:
            if args.cluster not in registry:
                print(
                    f"unknown cluster {args.cluster!r} "
                    f"(configured: {', '.join(registry.names())})",
                    file=sys.stderr,
                )
                return 2
            route_cluster = args.cluster
        elif args.anywhere:
            route_cluster = ""  # decided by the Placer (at eco time below)
        else:
            # zero-surprise default: the default cluster, exactly where a
            # single-cluster setup would have run it
            route_cluster = registry.default_name
    elif args.cluster or args.anywhere:
        ap.error("--cluster/--anywhere need a federated backend — add "
                 "[cluster.<name>] stanzas to the config "
                 "(see docs/federation.md)")

    opts = Opts(
        queue=args.queue if args.queue is not None else cfg.get("queue"),
        threads=args.cpus,
        memory_mb=memory_mb_from_cli(args.memory),
        time_s=parse_time_s(args.time),
        email_address=args.email,
        email_type="END" if args.email else "NONE",
        output_dir=args.output_dir,
        gres=args.gres,
        extra=list(args.sbatch),
        tmpdir=cfg.get("tmpdir") or "",
    )
    if args.after:
        opts.dependencies = [int(a) for a in args.after]
    if args.begin:
        opts.set_begin(args.begin)

    # --- eco mode (paper: ON by default, --no-eco / economy_mode=0 disable)
    use_eco = cfg.get_bool("economy_mode") if args.eco is None else args.eco
    eco_note = ""
    eco_meta = None
    eco_decision = None
    sched = None
    predicted_s = 0
    if use_eco and not opts.begin:
        from repro.accounting import predictor_from_config

        now = datetime.fromisoformat(args.now) if args.now else datetime.now()
        predictor = predictor_from_config(cfg)
        if route_cluster == "":
            # --anywhere: route BEFORE pricing — eco jobs score
            # green-first, and the tier maths below must use the chosen
            # member's own windows and carbon trace
            route_cluster = backend.placer.place_spec(
                cpus=opts.threads, memory_mb=opts.memory_mb,
                time_s=opts.time_s, now=now, name=args.name, eco=True,
                charge=not args.dry_run,  # a dry run must not skew routing
            ).cluster
        if route_cluster:
            # price through the routed member's per-cluster scheduler (a
            # copy, so the registry's object keeps its configuration)
            from copy import copy as _copy

            sched = _copy(registry.get(route_cluster).scheduler)
            sched.predictor = predictor
        else:
            # the tier is priced from this job's historical runtime when
            # the archive knows it; with no history this is exactly
            # next_window()
            sched = EcoScheduler(cfg, predictor=predictor)
        predicted_s = sched.effective_duration(opts.time_s, args.name)
        decision = sched.decide(opts.time_s, now, name=args.name)
        eco_decision = decision
        eco_meta = {"tier": decision.tier, "deferred": decision.deferred}
        if decision.deferred:
            if args.eco_hold:
                # same decision, reactive execution: hold now, release when
                # load drops — the decision begin becomes the deadline.
                # The controller itself is built lazily at registration
                # time so dry runs leak no tick hook on the shared sim.
                from repro.core import EcoController

                opts.hold = True
                eco_meta = EcoController.hold_meta(decision, predicted_s)
                eco_note = (
                    f"eco mode: held for favourable load "
                    f"(release deadline {decision.begin_directive}, "
                    f"tier {decision.tier})"
                )
            else:
                opts.set_begin(decision.begin_directive)
                eco_note = (
                    f"eco mode: deferred to {decision.begin_directive} "
                    f"(tier {decision.tier})"
                )
            if predicted_s < opts.time_s:
                eco_note += (
                    f" [predicted {predicted_s // 60} min from history, "
                    f"limit {opts.time_s // 60} min]"
                )
        if route_cluster:
            eco_note = (eco_note + " " if eco_note else "eco mode: run now ") \
                + f"[cluster {route_cluster}]"

    if args.from_file:
        # --- batch mode: one job per command line, via the SubmitEngine
        try:
            commands = read_command_file(args.from_file)
        except OSError as e:
            print(f"cannot read {args.from_file}: {e.strerror or e}",
                  file=sys.stderr)
            return 1
        if not commands:
            print(f"no commands in {args.from_file}", file=sys.stderr)
            return 1
        jobs = [
            Job(name=f"{args.name}-{i}", command=cmd, opts=deepcopy(opts))
            for i, cmd in enumerate(commands)
        ]
        for job in jobs:
            job.eco_meta = eco_meta
            if route_cluster:
                job.cluster = route_cluster
        if args.array:
            # one array job carries the whole batch → share one name
            for job in jobs:
                job.name = args.name
        engine = SubmitEngine(backend, coalesce=args.array)
        if args.dry_run:
            if args.array:
                array_job = Job(name=args.name, opts=deepcopy(opts))
                array_job.task_commands = commands
                print(array_job.script(), end="")
            else:
                for job in jobs:
                    print(job.script(), end="")
            if eco_note:
                print(f"# {eco_note}", file=sys.stderr)
            return 0
        result = engine.submit_many(jobs)
        if eco_meta and eco_meta.get("hold"):
            controller = _hold_controller(sched, now)
            for base in result.base_ids:
                controller.register(base, eco_decision, now=now,
                                    duration_s=predicted_s)
        if eco_meta:
            from repro.accounting import log_submissions

            log_submissions([(jid, "", eco_meta) for jid in result.ids])
        if eco_note:
            print(eco_note)
        for jid in result.ids:
            print(jid)
        if args.array:
            print(
                f"# {len(result)} task(s) in {result.sbatch_calls} submission(s)",
                file=sys.stderr,
            )
        return 0

    command = " ".join(args.command)
    job = Job(
        name=args.name,
        command=command,
        opts=opts,
        files=args.files,
        workdir="",
    )
    job.eco_meta = eco_meta
    if route_cluster:
        job.cluster = route_cluster
    if args.dry_run:
        print(job.script(), end="")
        if eco_note:
            print(f"# {eco_note}", file=sys.stderr)
        return 0
    jobid = job.run(backend)
    if eco_meta and eco_meta.get("hold"):
        _hold_controller(sched, now).register(
            jobid, eco_decision, now=now, duration_s=predicted_s)
    if eco_meta:
        from repro.accounting import log_submissions

        if job.files:  # sacct reports array tasks as base_0..base_k
            log_submissions([(f"{jobid}_{t}", "", eco_meta)
                             for t in range(len(job.files))])
        else:
            log_submissions([(str(jobid), "", eco_meta)])
    if eco_note:
        print(eco_note)
    print(jobid)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
