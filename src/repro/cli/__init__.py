"""Command-line tools (ports of the paper's bin/ suite).

| tool      | purpose                                             |
|-----------|-----------------------------------------------------|
| runjob    | submit a command as a SLURM job with resource flags |
| lsjobs    | list/filter/cancel user jobs (colour table)         |
| viewjobs  | interactive terminal UI for job management          |
| waitjobs  | block until jobs matching a pattern complete        |
| whojobs   | cluster utilisation grouped by user                 |
| session   | launch an interactive SLURM session                 |
| nbilaunch | run a declarative tool wrapper (Launcher)           |
| nbimon    | runtime metrics dump / Prometheus export / ticker   |
| ecoreport | energy/carbon accounting + eco-mode savings report  |
"""
