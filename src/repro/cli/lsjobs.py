"""lsjobs — colour-coded, human-readable snapshot of the job queue.

A static-table alternative to raw ``squeue`` (the interactive companion is
``viewjobs``). Supports filtering and bulk-cancel of the filtered set.
"""

from __future__ import annotations

import argparse
import sys

from repro.core import Queue
from repro.cli.render import emit_json, render_table, state_color
from repro.cli.session import add_gateway_args, resolve_backend

HEADERS = ["JobID", "User", "Queue", "JobName", "State",
           "TimeUsed", "TimeLeft", "TimeLimit", "NodeList", "Reason"]


def queue_rows(q: Queue, *, with_cluster: bool = False) -> list[list[str]]:
    return [
        ([j.cluster] if with_cluster else [])
        + [j.jobid, j.user, j.queue, j.name, j.state,
           j.time_used, j.time_left, j.time_limit, j.nodelist, j.reason]
        for j in q
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="lsjobs")
    ap.add_argument("-u", "--user", default=None, help="filter by user")
    ap.add_argument("--all", action="store_true", help="all users")
    ap.add_argument("-s", "--state", default=None, help="PENDING/RUNNING/...")
    ap.add_argument("-n", "--name", default=None, help="job-name regex")
    ap.add_argument("-q", "--queue", dest="partition", default=None)
    ap.add_argument("--cluster", default=None,
                    help="filter to one federation member cluster")
    ap.add_argument("--cancel", action="store_true",
                    help="cancel every job matching the filters")
    ap.add_argument("--yes", action="store_true", help="skip confirmation")
    ap.add_argument("--json", dest="as_json", action="store_true",
                    help="emit the (filtered) queue as JSON for scripting")
    ap.add_argument("--no-color", action="store_true")
    add_gateway_args(ap)
    args = ap.parse_args(argv)

    # nbid daemon when present (one poll serves every client on the host),
    # else the classic shared TTL cache over squeue
    backend = resolve_backend(args.gateway, args.gateway_socket)
    user = None if args.all else args.user
    if user is None and not args.all:
        import getpass

        try:
            user = getpass.getuser()
        except Exception:
            user = None
    q = Queue(user=user, state=args.state, name=args.name,
              queue=args.partition, cluster=args.cluster, backend=backend)

    if args.cancel:
        ids = q.ids()
        if not ids:
            print("no matching jobs")
            return 0
        if not args.yes:
            print(f"about to cancel {len(ids)} job(s): {' '.join(ids)}")
            reply = input("proceed? [y/N] ").strip().lower()
            if reply != "y":
                print("aborted")
                return 1
        q.cancel()
        print(f"cancelled {len(ids)} job(s)")
        return 0

    if args.as_json:
        emit_json([j for j in q])  # QueuedJob dataclasses → shared serializer
        return 0
    if not len(q):
        print("no jobs in queue")
        return 0
    # federation: lead with a Cluster column (absent on a plain backend,
    # so single-cluster output is unchanged)
    federated = any(j.cluster for j in q)
    headers = (["Cluster"] + HEADERS) if federated else HEADERS
    state_col = 5 if federated else 4
    print(
        render_table(
            headers,
            queue_rows(q, with_cluster=federated),
            color_for_row=lambda r: state_color(r[state_col]),
            enabled=False if args.no_color else None,
        )
    )
    print(f"{len(q)} job(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
