"""session — interactive SLURM sessions + the gateway thin client.

    session                 # 1 CPU, 4 GB, 2 h on the default partition
    session -c 8 -m 16 -t 4 # 8 CPUs, 16 GB, 4 hours
    session --print         # show the srun command without executing

Runs ``srun --pty bash`` with the requested resources. With ``--print`` (or
when srun is unavailable — e.g. under the simulator backend) the fully
formed command line is printed instead, which is also what the tests assert.

This module is also where every CLI acquires **daemon mode**:
:class:`GatewayClient` speaks the :mod:`repro.core.gateway` protocol over
the per-host Unix socket and implements the Backend protocol, so any tool
can treat the daemon exactly like a local backend. :func:`resolve_backend`
is the one seam the CLIs call — it probes the socket and **transparently
falls back to the in-process path** (``get_queue_cache()``) when no daemon
is running, which keeps every existing invocation byte-identical while a
running ``nbid`` silently collapses N processes' polling into one.
"""

from __future__ import annotations

import argparse
import getpass
import os
import shutil
import socket

from repro.cli.runjob import memory_mb_from_cli
from repro.core import format_slurm_time, load_config, parse_time_s
from repro.core.gateway import (
    EMPTY_FILTER_KEY,
    GatewayConnectionLost,
    GatewayError,
    canonical_filter_key,
    default_socket_path,
    event_from_wire,
    job_to_wire,
    recv_frame,
    row_filter,
    send_frame,
)

#: materialized queue views kept per client (distinct filter sets)
_VIEW_CAP = 32


class _QueueView:
    """Client-side materialized queue snapshot for one filter set.

    Holds the last full snapshot the daemon sent (generation-tagged) and
    applies per-job add/update/remove deltas to it, so a steady-state
    watcher pays O(changes) wire bytes per poll instead of O(jobs).
    """

    __slots__ = ("generation", "by_id", "order")

    def __init__(self, generation: int, rows: list):
        self.generation = generation
        self.by_id = {str(r.get("jobid", "")): r for r in rows}
        self.order = list(self.by_id)

    def rows(self) -> list:
        return [self.by_id[i] for i in self.order]

    def apply(self, delta: dict, order: "list | None") -> None:
        """Apply a server delta; raises KeyError on any inconsistency
        (the caller then falls back to a full snapshot)."""
        removed = set()
        for jid in delta.get("remove") or []:
            jid = str(jid)
            removed.add(jid)
            self.by_id.pop(jid, None)
        for row in delta.get("update") or []:
            jid = str(row.get("jobid", ""))
            if jid not in self.by_id:
                raise KeyError(f"update for unknown job {jid}")
            self.by_id[jid] = row
        added = []
        for row in delta.get("add") or []:
            jid = str(row.get("jobid", ""))
            self.by_id[jid] = row
            added.append(jid)
        if order is not None:
            new_order = [str(i) for i in order]
            if len(new_order) != len(self.by_id) or any(
                i not in self.by_id for i in new_order
            ):
                raise KeyError("delta order does not match row set")
            self.order = new_order
        else:
            # the server's append rule: survivors keep their order, adds
            # go to the back (it ships an explicit order otherwise)
            self.order = [i for i in self.order if i not in removed] + added


# ---------------------------------------------------------------------------
# GatewayClient — the Backend-protocol thin client
# ---------------------------------------------------------------------------


class GatewayClient:
    """Backend-protocol client for a running :class:`GatewayServer`.

    One short-lived connection per RPC (``wait`` and ``events`` hold
    theirs open for the stream) — no shared socket state, so a client
    object is safe to use from argparse-driven CLI code without lifecycle
    ceremony. All errors surface as :class:`GatewayError` (daemon said
    no) or :class:`GatewayConnectionLost` (daemon went away), the latter
    a ``ConnectionError`` so existing retry/except paths compose.
    """

    def __init__(self, socket_path: str | None = None, *,
                 user: str | None = None, timeout_s: float = 30.0):
        self.socket_path = socket_path or default_socket_path()
        if user is None:
            try:
                user = getpass.getuser()
            except Exception:  # noqa: BLE001 — no passwd entry in containers
                user = os.environ.get("USER", "anonymous")
        self.user = user
        self.timeout_s = timeout_s
        self._next_id = 1
        #: filter key → _QueueView (LRU, capped at _VIEW_CAP)
        self._views: "dict[tuple, _QueueView]" = {}
        #: set False after a plain-list reply (v1 daemon): stop sending
        #: since/filters it would ignore anyway
        self._server_v2 = True

    # -- plumbing -------------------------------------------------------------

    def _connect(self, timeout_s: "float | None") -> socket.socket:
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(timeout_s)
        try:
            sock.connect(self.socket_path)
        except OSError as e:
            sock.close()
            raise GatewayConnectionLost(
                f"no gateway at {self.socket_path}: {e}"
            ) from e
        return sock

    def _call(self, method: str, *, _timeout_s: "float | None" = -1, **params):
        timeout = self.timeout_s if _timeout_s == -1 else _timeout_s
        params.setdefault("user", self.user)
        rid = self._next_id
        self._next_id += 1
        sock = self._connect(timeout)
        try:
            try:
                send_frame(sock, {"id": rid, "method": method, "params": params})
                resp = recv_frame(sock)
            except (OSError, ConnectionError) as e:
                if isinstance(e, GatewayConnectionLost):
                    raise
                raise GatewayConnectionLost(
                    f"gateway connection lost during {method}: {e}"
                ) from e
            if resp is None:
                raise GatewayConnectionLost(
                    f"gateway closed the connection during {method}"
                )
            if not resp.get("ok"):
                raise GatewayError(str(resp.get("error", "unknown error")))
            return resp.get("result")
        finally:
            sock.close()

    # -- Backend protocol -----------------------------------------------------

    def queue(self) -> list[dict]:
        return self.queue_filtered()

    def queue_filtered(self, *, user=None, states=None, cluster=None,
                       ids=None) -> list[dict]:
        """Queue snapshot with **server-side filter pushdown** and the
        **delta protocol** (protocol v2).

        The daemon ships only matching rows, and — once this client holds
        a snapshot for the same filter set — only what changed since the
        generation it last saw (or ``{"unchanged": true}``). Against a v1
        daemon the reply is a plain full row list; filters are then
        applied locally, so results are identical either way.
        """
        filters: dict = {}
        if user:
            filters["user"] = str(user)
        if cluster is not None:
            filters["cluster"] = str(cluster)
        if ids:
            filters["ids"] = [str(i) for i in ids]
        if states:
            filters["states"] = [str(s).upper() for s in states]
        key = canonical_filter_key(filters)
        if not self._server_v2:
            resp = self._call("queue")
        else:
            view = self._views.get(key)
            params: dict = {"v": 2}
            if filters:
                params["filters"] = filters
            if view is not None:
                params["since"] = view.generation
            resp = self._call("queue", **params)
        return self._materialize(key, resp, filters)

    def _materialize(self, key: tuple, resp, filters: dict) -> list:
        if isinstance(resp, list):
            # v1 daemon: a plain full snapshot; filter locally
            self._server_v2 = False
            self._views.pop(key, None)
            if key == EMPTY_FILTER_KEY:
                return resp
            pred = row_filter(key)
            return [r for r in resp if pred(r)]
        if not isinstance(resp, dict):
            raise GatewayError(
                f"bad queue response type: {type(resp).__name__}"
            )
        gen = resp.get("generation")
        view = self._views.get(key)
        if resp.get("unchanged"):
            if view is None or view.generation != gen:
                return self._refetch_full(key, filters)
            return view.rows()
        delta = resp.get("delta")
        if delta is not None:
            if view is None or view.generation != resp.get("since"):
                return self._refetch_full(key, filters)
            try:
                view.apply(delta, resp.get("order"))
            except KeyError:
                return self._refetch_full(key, filters)
            view.generation = gen
            return view.rows()
        jobs = resp.get("jobs")
        if jobs is None:
            raise GatewayError("queue response carries neither jobs nor delta")
        view = _QueueView(int(gen), jobs)
        if key not in self._views and len(self._views) >= _VIEW_CAP:
            self._views.pop(next(iter(self._views)))
        self._views[key] = view
        return view.rows()

    def _refetch_full(self, key: tuple, filters: dict) -> list:
        """Defensive resync: drop the stale view, ask for a fresh full
        snapshot (no ``since`` → the daemon cannot answer with a delta)."""
        self._views.pop(key, None)
        params: dict = {"v": 2}
        if filters:
            params["filters"] = filters
        resp = self._call("queue", **params)
        if isinstance(resp, dict) and (
            resp.get("delta") is not None or resp.get("unchanged")
        ):
            # no ``since`` went out, so a delta back is a protocol breach
            raise GatewayError("daemon answered a full-snapshot request "
                               "with a delta")
        return self._materialize(key, resp, filters)

    def nodes_info(self) -> list[dict]:
        return self._call("nodes_info")

    def cancel(self, jobids: list) -> None:
        self._call("cancel", ids=[str(j) for j in jobids])

    def release(self, jobids: list) -> None:
        self._call("release", ids=[str(j) for j in jobids])

    def submit(self, job):
        result = self.submit_batch([job])
        base = result["base_ids"][0]
        job.jobid = base
        return base

    def submit_many(self, jobs: list) -> list:
        return self.submit_batch(jobs)["base_ids"]

    # -- daemon-side services --------------------------------------------------

    def submit_batch(self, jobs: list, *, eco: "bool | None" = None,
                     coalesce: bool = True) -> dict:
        """Submit through the daemon's SubmitEngine (placement, array
        coalescing and eco hold-and-release all happen daemon-side — the
        daemon keeps releasing held jobs after this process exits)."""
        return self._call(
            "submit_batch",
            jobs=[job_to_wire(j) for j in jobs],
            eco=eco, coalesce=coalesce,
            _timeout_s=max(self.timeout_s, 300.0),
        )

    def wait(self, *, ids=None, user=None, name=None,
             poll_s: float = 15.0, timeout_s: float = 0.0) -> dict:
        """Server-side wait: blocks until the watch set drains."""
        return self._call(
            "wait",
            ids=[str(i) for i in ids] if ids else None,
            watch_user=user, name=name,
            poll_s=poll_s, timeout_s=timeout_s,
            _timeout_s=None,  # the daemon owns the deadline
        )

    def events(self, *, poll_s: float = 2.0, duration_s: float = 0.0,
               max_events: int = 0):
        """Generator over the daemon's aggregated event ticker
        (:class:`~repro.core.events.JobEvent` objects)."""
        rid = self._next_id
        self._next_id += 1
        sock = self._connect(None)
        try:
            send_frame(sock, {
                "id": rid, "method": "events_subscribe",
                "params": {"user": self.user, "poll_s": poll_s,
                           "duration_s": duration_s,
                           "max_events": max_events},
            })
            first = recv_frame(sock)
            if first is None or not first.get("ok"):
                raise GatewayError(
                    str((first or {}).get("error", "subscribe failed"))
                )
            while True:
                frame = recv_frame(sock)
                if frame is None or frame.get("end"):
                    return
                if "event" in frame:
                    yield event_from_wire(frame["event"])
        except (OSError, ConnectionError) as e:
            if isinstance(e, GatewayConnectionLost):
                raise
            raise GatewayConnectionLost(f"event stream lost: {e}") from e
        finally:
            sock.close()

    def stats(self) -> dict:
        return self._call("stats")

    def ping(self) -> dict:
        return self._call("ping", _timeout_s=2.0)

    def advance(self, seconds: float) -> dict:
        """Advance the daemon's simulated clock (sim backends only)."""
        return self._call("advance", seconds=float(seconds), _timeout_s=None)

    def shutdown(self) -> dict:
        return self._call("shutdown")


# ---------------------------------------------------------------------------
# The CLI seam: --gateway/--no-gateway + transparent fallback
# ---------------------------------------------------------------------------


def add_gateway_args(ap: argparse.ArgumentParser) -> None:
    """The shared ``--gateway`` / ``--no-gateway`` / ``--gateway-socket``
    flags (default: auto-detect the socket, fall back in-process)."""
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--gateway", dest="gateway", action="store_true",
                   default=None,
                   help="require the nbid daemon (error when absent)")
    g.add_argument("--no-gateway", dest="gateway", action="store_false",
                   help="force the in-process path even with a daemon up")
    ap.add_argument("--gateway-socket", default=None, metavar="PATH",
                    help="daemon socket (default: $NBI_GATEWAY_SOCKET or "
                         "the per-user runtime path)")


def resolve_backend(gateway: "bool | None" = None,
                    socket_path: str | None = None):
    """The backend a CLI should talk to.

    ``gateway=True`` requires a live daemon (raises
    :class:`GatewayConnectionLost` otherwise); ``False`` forces the
    classic in-process shared cache; ``None`` (the default) probes the
    socket once and silently falls back — with no daemon running the
    returned object IS ``get_queue_cache()``, byte-identical behaviour.
    """
    if gateway is None and os.environ.get("NBI_NO_GATEWAY", ""):
        gateway = False
    if gateway is False:
        from repro.core import get_queue_cache

        return get_queue_cache()
    client = GatewayClient(socket_path)
    if gateway:
        client.ping()  # raises GatewayConnectionLost when absent
        return client
    try:
        client.ping()
        return client
    except (ConnectionError, GatewayError, OSError):
        from repro.core import get_queue_cache

        return get_queue_cache()


def srun_command(
    *, cpus: int, memory_mb: int, time_s: int, queue: str = "", gres: str = ""
) -> list[str]:
    cmd = [
        "srun",
        f"--cpus-per-task={cpus}",
        f"--mem={memory_mb}",
        f"--time={format_slurm_time(time_s)}",
        "--job-name=interactive",
    ]
    if queue:
        cmd.append(f"--partition={queue}")
    if gres:
        cmd.append(f"--gres={gres}")
    cmd += ["--pty", "bash", "-l"]
    return cmd


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="session")
    ap.add_argument("-c", "--cpus", type=int, default=1)
    ap.add_argument("-m", "--memory", default="4GB", help="bare number = GB")
    ap.add_argument("-t", "--time", default="2h", help="bare number = hours")
    ap.add_argument("-q", "--queue", default=None)
    ap.add_argument("--gres", default="")
    ap.add_argument("--print", dest="print_only", action="store_true")
    args = ap.parse_args(argv)

    cfg = load_config()
    cmd = srun_command(
        cpus=args.cpus,
        memory_mb=memory_mb_from_cli(args.memory),
        time_s=parse_time_s(args.time),
        queue=args.queue if args.queue is not None else cfg.get("queue"),
        gres=args.gres,
    )
    if args.print_only or not shutil.which("srun"):
        print(" ".join(cmd))
        return 0
    os.execvp("srun", cmd)  # replaces the process; no return on success


if __name__ == "__main__":
    raise SystemExit(main())
