"""session — launch an interactive SLURM session.

    session                 # 1 CPU, 4 GB, 2 h on the default partition
    session -c 8 -m 16 -t 4 # 8 CPUs, 16 GB, 4 hours
    session --print         # show the srun command without executing

Runs ``srun --pty bash`` with the requested resources. With ``--print`` (or
when srun is unavailable — e.g. under the simulator backend) the fully
formed command line is printed instead, which is also what the tests assert.
"""

from __future__ import annotations

import argparse
import os
import shutil

from repro.core import load_config, parse_time_s, format_slurm_time
from repro.cli.runjob import memory_mb_from_cli


def srun_command(
    *, cpus: int, memory_mb: int, time_s: int, queue: str = "", gres: str = ""
) -> list[str]:
    cmd = [
        "srun",
        f"--cpus-per-task={cpus}",
        f"--mem={memory_mb}",
        f"--time={format_slurm_time(time_s)}",
        "--job-name=interactive",
    ]
    if queue:
        cmd.append(f"--partition={queue}")
    if gres:
        cmd.append(f"--gres={gres}")
    cmd += ["--pty", "bash", "-l"]
    return cmd


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="session")
    ap.add_argument("-c", "--cpus", type=int, default=1)
    ap.add_argument("-m", "--memory", default="4GB", help="bare number = GB")
    ap.add_argument("-t", "--time", default="2h", help="bare number = hours")
    ap.add_argument("-q", "--queue", default=None)
    ap.add_argument("--gres", default="")
    ap.add_argument("--print", dest="print_only", action="store_true")
    args = ap.parse_args(argv)

    cfg = load_config()
    cmd = srun_command(
        cpus=args.cpus,
        memory_mb=memory_mb_from_cli(args.memory),
        time_s=parse_time_s(args.time),
        queue=args.queue if args.queue is not None else cfg.get("queue"),
        gres=args.gres,
    )
    if args.print_only or not shutil.which("srun"):
        print(" ".join(cmd))
        return 0
    os.execvp("srun", cmd)  # replaces the process; no return on success


if __name__ == "__main__":
    raise SystemExit(main())
