"""viewjobs — interactive terminal UI for job management (paper Figure 1).

Browse the live queue without leaving the terminal: scroll with arrow or Vim
keys, sort columns, inspect per-job details, toggle column visibility and
adjust column widths interactively. Select jobs with Space and cancel the
selection in bulk with a single keypress — no copy-pasting ids into scancel.

Architecture: all interaction logic lives in :class:`ViewModel`, a pure
state machine ``(state, key) → state`` that renders to a list of strings —
fully unit-testable without a terminal. The curses driver at the bottom is a
thin I/O shell around it (and the only part that needs a tty).

Live mode (``--live``): instead of blindly re-reading the queue every
refresh tick, the ViewModel subscribes to :class:`~repro.core.events.
JobEvent` s — the simulator's native bus, or a
:class:`~repro.core.events.PollingEventAdapter` diffing snapshots on real
SLURM — and refreshes only when something actually changed, showing the
latest transition in an event ticker on the status line.
"""

from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, field

from repro.core import Queue, QueuedJob
from repro.cli.render import COLORS, RESET, STATE_COLORS

COLUMNS = [  # (key, header, default width, default visible)
    ("jobid", "JobID", 10, True),
    # hidden on a plain backend; auto-shown once federated rows appear
    ("cluster", "Cluster", 9, False),
    ("user", "User", 9, True),
    ("queue", "Queue", 13, True),
    ("name", "JobName", 16, True),
    ("state", "State", 10, True),
    ("time_used", "TimeUsed", 11, False),
    ("time_left", "TimeLeft", 11, True),
    ("time_limit", "TimeLimit", 11, True),
    ("nodelist", "NodeList", 10, True),
    ("reason", "Reason", 12, False),
]

HELP_LINE = (
    "q:quit Enter:details f:filter s:sort-col o:asc/desc Space:select "
    "C:cancel-selected j/k:scroll h/l:column </>:width v:visibility r:refresh"
)


@dataclass
class ViewState:
    rows: list = field(default_factory=list)  # QueuedJob
    cursor: int = 0
    col_cursor: int = 0
    scroll: int = 0
    height: int = 20  # visible body rows
    sort_key: str = "jobid"
    sort_desc: bool = False
    selected: set = field(default_factory=set)  # jobids
    visible: dict = field(default_factory=dict)  # col key → bool
    widths: dict = field(default_factory=dict)  # col key → int
    filter_text: str = ""
    mode: str = "list"  # list | details | filter | confirm
    status: str = ""
    pending_cancel: list = field(default_factory=list)
    quit: bool = False


class ViewModel:
    """The TUI's engine: feed key events, read rendered lines."""

    def __init__(self, queue_source, canceller=None):
        """``queue_source()`` → list[QueuedJob]; ``canceller(ids)`` cancels."""
        self._source = queue_source
        self._cancel = canceller or (lambda ids: None)
        # live mode: recent events for the ticker + a dirty flag so the
        # driver refreshes only when the cluster actually changed
        self.live = False
        self.events: deque = deque(maxlen=50)
        self._dirty = False
        self._bus_token: "tuple | None" = None
        s = ViewState()
        for key, _, width, vis in COLUMNS:
            s.visible[key] = vis
            s.widths[key] = width
        self.state = s
        self.refresh()

    # -- live mode (event bus) -------------------------------------------------

    def bind_bus(self, bus) -> None:
        """Subscribe to a :class:`~repro.core.events.EventBus`; every event
        marks the view dirty and feeds the status-line ticker."""
        if self._bus_token is not None:
            old_bus, token = self._bus_token
            old_bus.unsubscribe(token)
        self._bus_token = (bus, bus.subscribe(self.note_event))
        self.live = True

    def note_event(self, event) -> None:
        self.events.append(event)
        self._dirty = True

    def maybe_refresh(self) -> bool:
        """Refresh iff an event arrived since the last render; True if so."""
        if not self._dirty:
            return False
        self._dirty = False
        self.refresh()
        return True

    # -- data ------------------------------------------------------------------

    def refresh(self) -> None:
        s = self.state
        jobs = list(self._source())
        if s.filter_text:
            needle = s.filter_text.lower()
            jobs = [
                j
                for j in jobs
                if needle in j.name.lower()
                or needle in j.user.lower()
                or needle in j.state.lower()
                or needle in j.queue.lower()
                or needle in j.cluster.lower()
                or needle in j.jobid
            ]
        key = s.sort_key

        def sort_val(j: QueuedJob):
            if key == "jobid":
                return (j.jobid_num, j.jobid)
            return getattr(j, key, "")

        jobs.sort(key=sort_val, reverse=s.sort_desc)
        s.rows = jobs
        if not s.visible["cluster"] and any(j.cluster for j in jobs):
            s.visible["cluster"] = True  # federation detected: show the column
        live = {j.jobid for j in jobs}
        s.selected &= live
        s.cursor = min(s.cursor, max(0, len(jobs) - 1))
        self._clamp_scroll()

    # -- key handling -----------------------------------------------------------

    def key(self, k: str) -> None:
        """One key event. Multi-char names: 'UP','DOWN','LEFT','RIGHT','ENTER','ESC','BACKSPACE'."""
        s = self.state
        if s.mode == "filter":
            self._key_filter(k)
            return
        if s.mode == "confirm":
            self._key_confirm(k)
            return
        if s.mode == "details":
            if k in ("q", "ESC", "ENTER"):
                s.mode = "list"
            return
        self._key_list(k)

    def keys(self, seq: str) -> None:
        for ch in seq:
            self.key(ch)

    def _visible_cols(self) -> list:
        return [c for c in COLUMNS if self.state.visible[c[0]]]

    def _key_list(self, k: str) -> None:
        s = self.state
        n = len(s.rows)
        cols = self._visible_cols()
        if k == "q":
            s.quit = True
        elif k in ("j", "DOWN"):
            s.cursor = min(n - 1, s.cursor + 1) if n else 0
        elif k in ("k", "UP"):
            s.cursor = max(0, s.cursor - 1)
        elif k == "g":
            s.cursor = 0
        elif k == "G":
            s.cursor = max(0, n - 1)
        elif k in ("h", "LEFT"):
            s.col_cursor = max(0, s.col_cursor - 1)
        elif k in ("l", "RIGHT"):
            s.col_cursor = min(len(cols) - 1, s.col_cursor + 1)
        elif k == "s":  # sort by the column under the cursor
            ckey = cols[s.col_cursor][0]
            if s.sort_key == ckey:
                s.sort_desc = not s.sort_desc
            else:
                s.sort_key, s.sort_desc = ckey, False
            self.refresh()
        elif k == "o":
            s.sort_desc = not s.sort_desc
            self.refresh()
        elif k == "<":
            ckey = cols[s.col_cursor][0]
            s.widths[ckey] = max(4, s.widths[ckey] - 2)
        elif k == ">":
            ckey = cols[s.col_cursor][0]
            s.widths[ckey] = min(60, s.widths[ckey] + 2)
        elif k == "v":  # toggle visibility of the column under the cursor
            ckey = cols[s.col_cursor][0]
            shown = [c for c in COLUMNS if s.visible[c[0]]]
            if len(shown) > 1:
                s.visible[ckey] = False
                s.col_cursor = min(s.col_cursor, len(self._visible_cols()) - 1)
        elif k == "V":  # show all columns
            for ckey, *_ in COLUMNS:
                s.visible[ckey] = True
        elif k == " ":
            if n:
                jid = s.rows[s.cursor].jobid
                if jid in s.selected:
                    s.selected.discard(jid)
                else:
                    s.selected.add(jid)
                s.cursor = min(n - 1, s.cursor + 1)
        elif k == "a":  # select all (filtered) rows
            s.selected = {j.jobid for j in s.rows}
        elif k == "u":
            s.selected.clear()
        elif k == "C":
            targets = sorted(s.selected) or ([s.rows[s.cursor].jobid] if n else [])
            if targets:
                s.pending_cancel = targets
                s.mode = "confirm"
        elif k == "f":
            s.mode = "filter"
        elif k == "F":
            s.filter_text = ""
            self.refresh()
        elif k == "ENTER":
            if n:
                s.mode = "details"
        elif k == "r":
            self.refresh()
            s.status = "refreshed"
        self._clamp_scroll()

    def _key_filter(self, k: str) -> None:
        s = self.state
        if k == "ENTER":
            s.mode = "list"
            self.refresh()
        elif k == "ESC":
            s.filter_text = ""
            s.mode = "list"
            self.refresh()
        elif k == "BACKSPACE":
            s.filter_text = s.filter_text[:-1]
        elif len(k) == 1 and k.isprintable():
            s.filter_text += k

    def _key_confirm(self, k: str) -> None:
        s = self.state
        if k in ("y", "Y"):
            ids = list(s.pending_cancel)
            self._cancel(ids)
            s.status = f"cancelled {len(ids)} job(s)"
            s.selected.clear()
            s.pending_cancel = []
            s.mode = "list"
            self.refresh()
        elif k in ("n", "N", "ESC", "q"):
            s.pending_cancel = []
            s.mode = "list"
            s.status = "cancel aborted"

    def _clamp_scroll(self) -> None:
        s = self.state
        if s.cursor < s.scroll:
            s.scroll = s.cursor
        if s.cursor >= s.scroll + s.height:
            s.scroll = s.cursor - s.height + 1

    # -- rendering -----------------------------------------------------------------

    def render(self, *, color: bool = False) -> list[str]:
        s = self.state
        if s.mode == "details":
            return self._render_details()
        cols = self._visible_cols()
        out = []
        hdr_cells = []
        for i, (key, header, _, _) in enumerate(cols):
            w = s.widths[key]
            mark = ""
            if key == s.sort_key:
                mark = "▼" if s.sort_desc else "▲"
            text = _fit(header + mark, w)
            if i == s.col_cursor:
                text = f"[{text[: max(0, w - 2)].strip():<{max(0, w - 2)}}]"
                text = _fit(text, w)
            hdr_cells.append(text)
        out.append("  " + " ".join(hdr_cells))
        body = s.rows[s.scroll : s.scroll + s.height]
        for i, j in enumerate(body):
            ridx = s.scroll + i
            sel = "*" if j.jobid in s.selected else " "
            cur = ">" if ridx == s.cursor else " "
            cells = [_fit(getattr(j, key, ""), s.widths[key]) for key, *_ in cols]
            line = f"{cur}{sel}" + " ".join(cells)
            if color:
                cname = STATE_COLORS.get(j.state, "")
                if ridx == s.cursor:
                    line = f"{COLORS['inverse']}{line}{RESET}"
                elif cname:
                    line = f"{COLORS[cname]}{line}{RESET}"
            out.append(line)
        if s.mode == "filter":
            out.append(f"filter: {s.filter_text}_")
        elif s.mode == "confirm":
            out.append(
                f"cancel {len(s.pending_cancel)} job(s) "
                f"[{' '.join(s.pending_cancel[:8])}{'…' if len(s.pending_cancel) > 8 else ''}]? y/N"
            )
        else:
            nsel = len(s.selected)
            parts = [f"{len(s.rows)} job(s)"]
            if nsel:
                parts.append(f"{nsel} selected")
            if s.filter_text:
                parts.append(f"filter={s.filter_text!r}")
            if s.status:
                parts.append(s.status)
            if self.live:
                parts.append(self._ticker_text())
            out.append(" | ".join(parts))
        out.append(HELP_LINE)
        return out

    def _ticker_text(self) -> str:
        if not self.events:
            return "live: no events yet"
        e = self.events[-1]
        when = e.at.strftime("%H:%M:%S") if hasattr(e.at, "strftime") else e.at
        return f"live: {when} {e.type} {e.jobid} ({len(self.events)} ev)"

    def _render_details(self) -> list[str]:
        s = self.state
        j = s.rows[s.cursor]
        fields = [
            ("JobID", j.jobid),
            *([("Cluster", j.cluster)] if j.cluster else []),
            ("User", j.user), ("Partition", j.queue),
            ("Name", j.name), ("State", j.state), ("TimeUsed", j.time_used),
            ("TimeLeft", j.time_left), ("TimeLimit", j.time_limit),
            ("Nodes", j.nodelist), ("Reason", j.reason),
            ("CPUs", j.cpus), ("Memory(MB)", j.memory),
        ]
        width = max(len(k) for k, _ in fields)
        lines = [f"─── job {j.jobid} ───"]
        lines += [f"{k:>{width}} : {v}" for k, v in fields]
        lines.append("(Enter/q to close)")
        return lines


def _fit(text: str, w: int) -> str:
    text = str(text)
    if len(text) > w:
        text = text[: max(0, w - 1)] + "…"
    return text.ljust(w)


# ---------------------------------------------------------------------------
# curses driver (thin shell; everything above is testable without a tty)
# ---------------------------------------------------------------------------


def _curses_main(stdscr, vm: ViewModel, refresh_s: float, adapter=None):
    import curses

    curses.curs_set(0)
    stdscr.timeout(int(refresh_s * 1000))
    keymap = {
        curses.KEY_UP: "UP", curses.KEY_DOWN: "DOWN",
        curses.KEY_LEFT: "LEFT", curses.KEY_RIGHT: "RIGHT",
        10: "ENTER", 13: "ENTER", 27: "ESC",
        curses.KEY_BACKSPACE: "BACKSPACE", 127: "BACKSPACE",
    }
    while not vm.state.quit:
        h, w = stdscr.getmaxyx()
        vm.state.height = max(3, h - 3)
        stdscr.erase()
        for y, line in enumerate(vm.render()[: h - 1]):
            stdscr.addnstr(y, 0, line, w - 1)
        stdscr.refresh()
        c = stdscr.getch()
        if c == -1:  # timeout tick
            if vm.live:
                if adapter is not None:
                    adapter.poll()  # one snapshot → events → dirty flag
                vm.maybe_refresh()  # redraw only when something changed
            else:
                vm.refresh()
            continue
        vm.key(keymap.get(c, chr(c) if 0 < c < 256 else ""))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="viewjobs")
    ap.add_argument("-u", "--user", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--refresh", type=float, default=2.0, help="seconds")
    ap.add_argument("--live", action="store_true",
                    help="event-driven refresh: redraw on job transitions "
                         "instead of every tick; shows an event ticker")
    ap.add_argument("--once", action="store_true",
                    help="render one frame to stdout (no tty needed)")
    ap.add_argument("--stats", action="store_true",
                    help="print this session's observability snapshot "
                         "(cache hit rate, polls saved) as JSON on exit")
    from repro.cli.session import add_gateway_args, resolve_backend

    add_gateway_args(ap)
    args = ap.parse_args(argv)

    if args.stats:
        from repro.obs import enable

        enable()  # record this session's counters, not no-ops
    # daemon when present (one poll serves every viewer), else the
    # shared TTL cache: refresh ticks dedupe either way
    backend = resolve_backend(args.gateway, args.gateway_socket)
    user = None
    if not args.all:
        user = args.user
        if user is None:
            import getpass

            try:
                user = getpass.getuser()
            except Exception:
                user = None

    def source():
        return list(Queue(user=user, backend=backend))

    vm = ViewModel(source, canceller=backend.cancel)
    adapter = None
    if args.live:
        bus = getattr(getattr(backend, "inner", backend), "bus", None)
        if bus is None:  # real SLURM: synthesise events from snapshot diffs
            from repro.core import PollingEventAdapter

            adapter = PollingEventAdapter(backend)
            bus = adapter.bus
            adapter.poll()  # baseline
        vm.bind_bus(bus)
    def print_stats() -> None:
        if not args.stats:
            return
        from repro.cli.render import emit_json
        from repro.obs.export import session_stats

        emit_json(session_stats(cache=backend))

    if args.once:
        print("\n".join(vm.render()))
        print_stats()
        return 0
    import curses

    curses.wrapper(_curses_main, vm, args.refresh, adapter)
    print_stats()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
