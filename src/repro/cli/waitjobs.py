"""waitjobs — block until jobs matching a pattern complete.

    waitjobs                     # wait for all of my jobs
    waitjobs -n 'align.*'        # wait for jobs whose name matches
    waitjobs 123456 123457       # wait for specific ids
    waitjobs --timeout 3600      # give up after an hour (exit 2)
    waitjobs --json              # machine-readable per-job final states
    waitjobs --eco-release       # also release held eco jobs while waiting

Exit status: 0 when every watched job COMPLETED, 1 when any ended
FAILED / TIMEOUT / NODE_FAIL (or otherwise short of COMPLETED, e.g.
CANCELLED), 2 on timeout, 3 when the backend/daemon connection was lost
mid-wait (the jobs may still be running — distinct from a timeout, which
means the jobs were observed but too slow).

Event-driven: instead of re-polling squeue until the watch set drains
(one snapshot per poll tick), waitjobs takes ONE snapshot to resolve the
watch set and then blocks on terminal :class:`~repro.core.events.JobEvent`s
— delivered natively by the simulator's bus, or synthesised by a
:class:`~repro.core.events.PollingEventAdapter` for real SLURM (where each
adapter poll is still one snapshot, but terminal *states* now arrive with
the event instead of being inferred from absence). Against the simulator
the wait loop advances simulated time, so integration tests run instantly.
"""

from __future__ import annotations

import argparse
import sys
import time
from dataclasses import dataclass, field

from repro.core import Queue
from repro.core.events import TERMINAL_EVENTS, PollingEventAdapter
from repro.core.simcluster import SimCluster

#: terminal states the exit code treats as hard failures
BAD_STATES = ("FAILED", "TIMEOUT", "NODE_FAIL", "OUT_OF_MEMORY")


@dataclass
class WaitResult:
    """Outcome of one wait: per-job final states + bookkeeping."""

    ok: bool  # the watch set drained before the timeout
    states: dict = field(default_factory=dict)  # jobid → final state
    snapshots: int = 0  # queue() snapshots taken end to end
    #: the backend/daemon went away mid-wait: the watched jobs may well
    #: still be running — must NOT read as a timeout (exit 3, not 2)
    connection_lost: bool = False

    @property
    def failed_ids(self) -> list:
        return [j for j, s in self.states.items() if s in BAD_STATES]

    @property
    def all_completed(self) -> bool:
        return all(s == "COMPLETED" for s in self.states.values())

    @property
    def exit_code(self) -> int:
        if self.connection_lost:
            return 3
        if not self.ok:
            return 2
        return 0 if self.all_completed else 1

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "timed_out": not self.ok and not self.connection_lost,
            "connection_lost": self.connection_lost,
            "exit_code": self.exit_code,
            "jobs": dict(sorted(self.states.items())),
            "failed": sorted(self.failed_ids),
            "snapshots": self.snapshots,
        }


def matching_ids(backend, *, user=None, name=None, ids=None) -> list[str]:
    # ids travel with the Queue so a gateway backend ships the handful of
    # watched rows, not the whole snapshot (filters re-applied locally)
    q = Queue(user=user, name=name, jobids=list(ids) if ids else None,
              backend=backend)
    return q.ids()


def wait_for_events(
    backend,
    *,
    user=None,
    name=None,
    ids=None,
    poll_s: float = 15.0,
    timeout_s: float = 0.0,
    progress=None,
    controller=None,
) -> WaitResult:
    """Block on terminal events until the watch set drains.

    ``controller`` (an :class:`~repro.core.ecocontroller.EcoController`)
    is ticked on every poll against real backends; against the simulator
    its tick hook already rides ``advance()``.
    """
    inner = getattr(backend, "inner", backend)
    watched = set(matching_ids(backend, user=user, name=name, ids=ids))
    result = WaitResult(ok=True, snapshots=1)
    if ids:
        # explicit ids with no active queue row already left the queue:
        # resolve their terminal state NOW — they must appear in the
        # result (and drive the exit code) even while other ids still run
        gone = [
            req for req in {str(i) for i in ids}
            if not any(_id_matches(w, req) for w in watched)
        ]
        result.states.update(_final_states(inner, gone))
    if not watched:
        return result
    remaining = set(watched)
    start = time.monotonic()

    def on_event(event):
        if event.jobid not in remaining:
            return
        result.states[event.jobid] = _norm_state(event.state) or event.type
        remaining.discard(event.jobid)

    bus = getattr(inner, "bus", None)
    native = isinstance(inner, SimCluster) or (
        # a federation of simulators pushes member events (cluster-tagged,
        # ids namespaced) through its aggregated bus — same zero-snapshot
        # wait loop, now spanning every member cluster at once
        getattr(inner, "all_sim", False) and hasattr(backend, "advance")
    )
    if native and bus is not None:
        # native events: zero snapshots while waiting — each advance()
        # delivers every transition in order at its simulated instant
        token = bus.subscribe(on_event, types=TERMINAL_EVENTS)
        try:
            while remaining:
                if progress:
                    progress(len(remaining))
                if timeout_s and time.monotonic() - start > timeout_s:
                    result.ok = False
                    return result
                backend.advance(poll_s)
        except ConnectionError:
            result.ok = False
            result.connection_lost = True
            return result
        finally:
            bus.unsubscribe(token)
    else:
        adapter = PollingEventAdapter(backend)
        adapter.bus.subscribe(on_event, types=TERMINAL_EVENTS)
        try:
            adapter.poll()  # baseline snapshot (no events by definition)
            baseline = set(adapter._prev or {})
            result.snapshots += 1
            # a watched job can finish between the matching_ids snapshot
            # and the baseline poll; it will never produce a vanish event,
            # so resolve it here instead of blocking on it forever
            raced = [jid for jid in remaining if jid not in baseline]
            result.states.update(_final_states(inner, raced))
            remaining -= set(raced)
            while remaining:
                if progress:
                    progress(len(remaining))
                if timeout_s and time.monotonic() - start > timeout_s:
                    result.ok = False
                    return result
                time.sleep(poll_s)
                if controller is not None:
                    from datetime import datetime

                    controller.tick(datetime.now())
                adapter.poll()
                result.snapshots += 1
        except ConnectionError:
            # the backend (a gateway daemon, a broken pipe to squeue's
            # host) went away mid-wait: the jobs may still be running
            result.ok = False
            result.connection_lost = True
            return result
    result.states.update(_final_states(inner, watched - set(result.states)))
    return result


def _id_matches(watched_id: str, requested: str) -> bool:
    """Back-compat alias: the one shared matcher lives in
    :func:`repro.core.federation.id_covers` (also used by the gateway's
    server-side ``ids`` filter pushdown)."""
    from repro.core.federation import id_covers

    return id_covers(watched_id, requested)


def _norm_state(state: str) -> str:
    """Normalise a raw queue/sacct state for exit-code matching
    (``CANCELLED by 123`` → ``CANCELLED``, ``OUT_OF_ME+`` → OOM)."""
    state = (state or "").split(" ")[0]
    if state.startswith("OUT_OF_ME"):
        return "OUT_OF_MEMORY"
    if state.startswith("CANCELLED"):
        return "CANCELLED"
    return state


def _final_states(inner, jids) -> dict:
    """Best-effort terminal states for jobs that left the queue unseen.

    Simulator-shaped backends answer exactly via ``get()``; on real SLURM
    one ``sacct`` call resolves the whole batch (a FAILED job that left
    the queue before we looked must still drive exit code 1). Jobs with
    no record keep the classic convention: gone means COMPLETED.
    """
    jids = [str(j) for j in jids]
    out: dict = {}
    unresolved = []
    get = getattr(inner, "get", None)
    for jid in jids:
        state = ""
        if get is not None:
            job = get(jid)
            state = getattr(job, "state", "") if job is not None else ""
        if state:
            out[jid] = _norm_state(state)
        else:
            unresolved.append(jid)
    if unresolved:
        rows: dict = {}
        accounting = getattr(inner, "accounting", None)
        if accounting is not None and get is None:  # sacct-shaped backend
            try:
                rows = {
                    str(r.get("jobid", "")): str(r.get("state", ""))
                    for r in accounting()
                    if isinstance(r, dict)
                }
            except Exception:  # noqa: BLE001 — sacct may be unavailable
                rows = {}
        for jid in unresolved:
            out[jid] = _norm_state(rows.get(jid, "")) or "COMPLETED"
    return out


def wait_for(
    backend,
    *,
    user=None,
    name=None,
    ids=None,
    poll_s: float = 15.0,
    timeout_s: float = 0.0,
    progress=None,
) -> bool:
    """Back-compat wrapper: True when the watch set drained in time."""
    return wait_for_events(
        backend, user=user, name=name, ids=ids,
        poll_s=poll_s, timeout_s=timeout_s, progress=progress,
    ).ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="waitjobs")
    ap.add_argument("ids", nargs="*", help="specific job ids to wait for")
    ap.add_argument("-u", "--user", default=None)
    ap.add_argument("-n", "--name", default=None, help="job-name regex")
    ap.add_argument("--poll", type=float, default=15.0, help="seconds between polls")
    ap.add_argument("--timeout", type=float, default=0.0, help="0 = forever")
    ap.add_argument("--json", action="store_true",
                    help="emit per-job final states as JSON")
    ap.add_argument("--eco-release", action="store_true",
                    help="adopt held eco jobs (runjob --eco-hold) and "
                         "release them reactively while waiting")
    ap.add_argument("--stats", action="store_true",
                    help="print this session's observability snapshot on "
                         "exit (queue polls saved, cache hit rate) as JSON")
    ap.add_argument("--quiet", action="store_true")
    from repro.cli.session import add_gateway_args

    add_gateway_args(ap)
    args = ap.parse_args(argv)

    if args.stats:
        from repro.obs import enable

        enable()  # record this session's counters, not no-ops
    from repro.cli.session import GatewayClient, resolve_backend

    try:
        backend = resolve_backend(args.gateway, args.gateway_socket)
    except ConnectionError as e:
        print(f"gateway connection failed: {e}", file=sys.stderr)
        return 3
    user = args.user
    if user is None and not args.ids and not args.name:
        import getpass

        try:
            user = getpass.getuser()
        except Exception:
            user = None

    if isinstance(backend, GatewayClient):
        # server-side wait: the daemon subscribes once on its own bus and
        # blocks this RPC until the watch set drains (its EcoController
        # keeps releasing held jobs — --eco-release is implicit)
        if args.eco_release and not args.quiet and not args.json:
            print("eco: held-job release is owned by the gateway daemon")
        try:
            r = backend.wait(
                ids=args.ids or None, user=user, name=args.name,
                poll_s=args.poll, timeout_s=args.timeout,
            )
            result = WaitResult(
                ok=bool(r.get("ok")),
                states=dict(r.get("states", {})),
                snapshots=int(r.get("snapshots", 0)),
            )
        except ConnectionError:
            result = WaitResult(ok=False, connection_lost=True)
    else:
        controller = None
        if args.eco_release:
            from repro.core import EcoController

            controller = EcoController.adopt(backend)
            if not args.quiet and controller.held:
                print(f"eco: managing {len(controller.held)} held job(s)")

        def progress(n):
            if not args.quiet and not args.json:
                print(f"waiting on {n} job(s)...", flush=True)

        result = wait_for_events(
            backend,
            user=user,
            name=args.name,
            ids=args.ids or None,
            poll_s=args.poll,
            timeout_s=args.timeout,
            progress=progress,
            controller=controller,
        )
    if args.json:
        from repro.cli.render import emit_json

        payload = result.to_dict()
        if args.stats:
            from repro.obs.export import session_stats

            payload["stats"] = session_stats(cache=backend)
        emit_json(payload)
        return result.exit_code
    if result.connection_lost:
        print("connection lost", file=sys.stderr)
    elif not result.ok:
        print("timeout")
    elif result.failed_ids:
        print(f"{len(result.failed_ids)} job(s) failed: "
              + " ".join(sorted(result.failed_ids)))
    elif not args.quiet:
        print("all jobs finished")
    if args.stats:
        from repro.cli.render import emit_json
        from repro.obs.export import session_stats

        emit_json(session_stats(cache=backend))
    return result.exit_code


if __name__ == "__main__":
    raise SystemExit(main())
