"""waitjobs — block until jobs matching a pattern complete.

    waitjobs                     # wait for all of my jobs
    waitjobs -n 'align.*'        # wait for jobs whose name matches
    waitjobs 123456 123457       # wait for specific ids
    waitjobs --timeout 3600      # give up after an hour (exit 2)

Exit status: 0 when every watched job left the queue, 2 on timeout.
Against the simulator backend the poll loop advances simulated time, so
integration tests run instantly.
"""

from __future__ import annotations

import argparse
import time

from repro.core import Queue, get_queue_cache
from repro.core.simcluster import SimCluster


def matching_ids(backend, *, user=None, name=None, ids=None) -> list[str]:
    q = Queue(user=user, name=name, backend=backend)
    if ids:
        want = {str(i) for i in ids}
        return [j.jobid for j in q if j.jobid in want or str(j.jobid_num) in want]
    return q.ids()


def wait_for(
    backend,
    *,
    user=None,
    name=None,
    ids=None,
    poll_s: float = 15.0,
    timeout_s: float = 0.0,
    progress=None,
) -> bool:
    """Poll until no watched job is active. Returns True on success."""
    watched = set(matching_ids(backend, user=user, name=name, ids=ids))
    if ids and not watched:
        # ids given but already gone from the queue → done
        return True
    start = time.monotonic()
    while True:
        q = Queue(user=user, backend=backend)
        active = {j.jobid for j in q if j.is_active()}
        left = watched & active if watched else active
        if not left:
            return True
        if progress:
            progress(len(left))
        if timeout_s and time.monotonic() - start > timeout_s:
            return False
        # a QueueCache wrapper delegates advance() and invalidates on it
        if isinstance(getattr(backend, "inner", backend), SimCluster):
            backend.advance(poll_s)  # simulated clock: tests run instantly
        else:
            time.sleep(poll_s)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="waitjobs")
    ap.add_argument("ids", nargs="*", help="specific job ids to wait for")
    ap.add_argument("-u", "--user", default=None)
    ap.add_argument("-n", "--name", default=None, help="job-name regex")
    ap.add_argument("--poll", type=float, default=15.0, help="seconds between polls")
    ap.add_argument("--timeout", type=float, default=0.0, help="0 = forever")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    backend = get_queue_cache()  # dedupes squeue across the poll loop
    user = args.user
    if user is None and not args.ids and not args.name:
        import getpass

        try:
            user = getpass.getuser()
        except Exception:
            user = None

    def progress(n):
        if not args.quiet:
            print(f"waiting on {n} job(s)...", flush=True)

    ok = wait_for(
        backend,
        user=user,
        name=args.name,
        ids=args.ids or None,
        poll_s=args.poll,
        timeout_s=args.timeout,
        progress=progress,
    )
    if not ok:
        print("timeout")
        return 2
    if not args.quiet:
        print("all jobs finished")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
