"""ecoreport — energy, carbon, and eco-mode savings from the job archive.

Aggregates the :class:`~repro.accounting.store.HistoryStore` into
per-user (or per-tool) totals: jobs, cpu-hours, energy, carbon, and the
headline number — **carbon saved by eco mode**, computed as the
difference between each job's actual emissions and the counterfactual
emissions had it started at submission time instead of its deferred
eco window.

    ecoreport                      # per-user table from the archive
    ecoreport --by tool            # group by tool / job-name stem
    ecoreport --by-cluster         # federation: per-member totals and
                                   # carbon saved by placement routing
    ecoreport --collect            # harvest backend accounting first
    ecoreport --json               # machine-readable (shared dialect)
    ecoreport --user alice --since 2026-01-01

Energy figures prefer measured sacct ``ConsumedEnergy``; jobs without a
reading (and everything from the simulator) use the deterministic
cpu × time × TDP model (config key ``energy_cpu_watts``). Carbon uses the
configured ``carbon_trace`` or, absent one, a synthetic reference curve —
relative savings are then indicative, not metered.
"""

from __future__ import annotations

import argparse
import sys
from datetime import datetime

from repro.accounting import (
    EnergyModel,
    HistoryStore,
    collect,
    render_report,
    report_dict,
)
from repro.cli.render import emit_json


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="ecoreport",
        description="Energy, carbon, and eco-mode savings report.",
    )
    ap.add_argument("--history", default=None,
                    help="job archive path (default: $NBI_HISTORY / config)")
    ap.add_argument("--by", choices=["user", "tool", "cluster", "none"],
                    default="user",
                    help="grouping for the table (default: user)")
    ap.add_argument("--by-cluster", dest="by", action="store_const",
                    const="cluster",
                    help="shorthand for --by cluster (federation: per-member "
                         "totals incl. placement savings)")
    ap.add_argument("-u", "--user", default=None, help="filter to one user")
    ap.add_argument("--tool", default=None, help="filter to one tool/name stem")
    ap.add_argument("--cluster", default=None,
                    help="filter to one federation member cluster")
    ap.add_argument("--state", default=None, help="filter by final state")
    ap.add_argument("--since", default=None,
                    help="only jobs started on/after this ISO date(time); "
                         "with --collect, the same instant also widens the "
                         "sacct harvest window (--starttime)")
    ap.add_argument("--collect", action="store_true",
                    help="harvest the backend's accounting into the archive first")
    ap.add_argument("--json", dest="as_json", action="store_true",
                    help="emit the report as JSON")
    ap.add_argument("--no-color", action="store_true")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    store = HistoryStore(args.history)

    # validate --since up front: nothing may mutate the archive before a
    # bad value errors out
    since = None
    if args.since:
        try:
            since = datetime.fromisoformat(args.since)
        except ValueError:
            print(f"cannot parse --since {args.since!r} (want ISO 8601)",
                  file=sys.stderr)
            return 2

    if args.collect:
        from repro.core import get_backend

        n = collect(get_backend(), store, EnergyModel.from_config(),
                    since=since.isoformat() if since else "")
        if not args.as_json:
            print(f"collected {n} new record(s) into {store.path}")

    records = store.records(
        user=args.user, tool=args.tool, state=args.state, since=since,
        cluster=args.cluster,
    )

    if args.as_json:
        emit_json(report_dict(records, by=args.by))
        return 0
    if not records:
        print(f"no archived jobs in {store.path} "
              "(run with --collect, or submit some jobs first)")
        return 0
    print(render_report(records, by=args.by,
                        color=False if args.no_color else None))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
