from .steps import (
    abstract_train_state,
    init_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    train_state_logical,
)

__all__ = [
    "abstract_train_state", "init_train_state",
    "make_prefill_step", "make_serve_step", "make_train_step",
    "train_state_logical",
]
