"""Train / prefill / decode step builders.

``make_train_step`` assembles: loss → (optionally microbatched, gradient-
accumulated) grad → gradient clip → optimizer update. Data parallelism,
tensor parallelism and expert parallelism all come from the logical-axis
rules installed around tracing (repro.parallel.sharding); the returned
function is pure and jit-ready.

Gradient accumulation reshapes the global batch (B, ...) into
(MB, B/MB, ...) and ``lax.scan``s — peak activation memory drops by ~MB×
while arithmetic is unchanged (the §Perf lever for the 123 B dense model).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim import Optimizer
from repro.parallel.sharding import with_rules


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------


def init_train_state(model: Model, optimizer: Optimizer, rng):
    params = model.init(rng)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_train_state(model: Model, optimizer: Optimizer):
    """ShapeDtypeStruct train state — dry-run lowers against this."""
    params = model.abstract_params()
    opt = jax.eval_shape(optimizer.init, params)
    return {
        "params": params,
        "opt": opt,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def train_state_logical(model: Model, optimizer: Optimizer):
    plog = model.param_logical()
    return {
        "params": plog,
        "opt": optimizer.state_logical(plog),
        "step": (),
    }


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(model: Model, optimizer: Optimizer, rules: dict, mesh):
    cfg = model.cfg
    mb = max(1, cfg.microbatch)

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def train_step(state, batch):
        params = state["params"]
        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch
            )

            def acc(carry, mbatch):
                gsum, lsum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + l), m

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), ms = jax.lax.scan(acc, (gzero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
            loss = lsum / mb
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)

        new_params, new_opt = optimizer.update(grads, state["opt"], params)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
        )
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm})
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        return new_state, metrics

    return with_rules(train_step, rules, mesh)


def make_prefill_step(model: Model, rules: dict, mesh):
    def prefill_step(params, batch):
        return model.prefill_fn(params, batch)

    return with_rules(prefill_step, rules, mesh)


def make_serve_step(model: Model, rules: dict, mesh):
    """One decode step: (params, cache, tokens, pos) → (logits, new cache)."""

    def serve_step(params, cache, tokens, pos):
        return model.decode_fn(params, cache, tokens, pos)

    return with_rules(serve_step, rules, mesh)
