"""Pure-DP train step with explicit collectives (shard_map) and optional
error-feedback int8 compression on the cross-pod gradient leg.

The GSPMD path (repro.training.steps) lets the partitioner place every
collective — right for TP/EP-sharded giants. For models that fit one chip
(the nbi-100m class and most <8B configs at serving precision), fleets run
pure data parallelism, where the gradient all-reduce IS the communication
bill, and its inter-pod leg crosses the slow DCI. This module is the
manual-collectives twin of make_train_step:

    shard_map over ("pod", "data"):
        per-device grads                       (local batch shard)
        → psum over "data"                     (f32, fast ICI)
        → ef_compressed_psum over "pod"        (int8 + error feedback, DCI)
        → identical optimizer update on every device

The error-feedback carry rides in the train state (checkpointed like
optimizer moments). With ``compress=False`` the pod leg is a plain f32
pmean — the exactness baseline the tests compare against.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.registry import Model
from repro.optim import Optimizer
from repro.parallel.compression import ef_compressed_psum, init_ef_state


def init_dp_state(model: Model, optimizer: Optimizer, rng, *, compress: bool):
    params = model.init(rng)
    state = {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if compress:
        state["ef"] = init_ef_state(params)
    return state


def make_dp_train_step(model: Model, optimizer: Optimizer, mesh, *,
                       compress: bool = True):
    """Returns a jit-ready ``(state, batch) -> (state, metrics)``.

    ``mesh`` must expose a "data" axis and may expose a "pod" axis; the
    global batch is sharded over all of them, params are replicated.
    """
    axes = mesh.axis_names
    pod = "pod" if "pod" in axes else None
    n_pod = dict(zip(axes, mesh.devices.shape)).get("pod", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)

    def local_step(state, batch):
        # batch here is this device's shard; params/opt replicated
        def loss_fn(p):
            return model.loss_fn(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        # fast leg: exact mean over the intra-pod data axis
        grads = jax.lax.pmean(grads, "data")
        loss = jax.lax.pmean(loss, "data")
        metrics = jax.lax.pmean(metrics, "data")
        new_state = dict(state)
        if pod is not None:
            loss = jax.lax.pmean(loss, pod)
            metrics = jax.lax.pmean(metrics, pod)
            if compress:
                grads, new_ef = ef_compressed_psum(grads, state["ef"], pod, n_pod)
                new_state["ef"] = new_ef
            else:
                grads = jax.lax.pmean(grads, pod)
        new_params, new_opt = optimizer.update(grads, state["opt"], state["params"])
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree_util.tree_leaves(grads)))
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm})
        new_state.update(
            params=new_params, opt=new_opt, step=state["step"] + 1
        )
        return new_state, metrics

    state_specs = jax.tree_util.tree_map(lambda _: P(), {"params": 0, "opt": 0, "step": 0})
    # full-tree specs are built per-call by shard_map from these prototypes
    in_state_spec = P()  # replicated
    batch_spec = P(batch_axes)

    wrapped = jax.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(in_state_spec, batch_spec),
        out_specs=(in_state_spec, in_state_spec),
        check_vma=False,
    )
    return wrapped
