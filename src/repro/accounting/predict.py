"""``RuntimePredictor`` — history-driven duration estimates for eco mode.

The EcoScheduler picks its tier from the job's *requested* time limit, and
users pad limits defensively — a job asking for 12 h that historically
finishes in 50 min gets priced as a 12 h job and lands in tier 2 instead
of completing inside a 6 h night window at tier 1. The predictor closes
that gap: estimate the duration from the job's own completion history
(per user + tool/name-stem percentile, with safety margin), never above
the requested limit, and fall back to the limit whenever the history is
too thin.

Hard invariant (pinned property-style in ``tests/test_eco_properties.py``):
**no history ⇒ the prediction IS the request limit**, so every eco
decision is bit-identical to the predictor-free scheduler. The predictor
can only ever move a job to an equal-or-better tier, never change
behaviour for workloads it has not seen.
"""

from __future__ import annotations

import math

from .store import HistoryStore, name_stem  # noqa: F401  (re-exported key rule)

#: at least this many completed runs before we trust a key's history
DEFAULT_MIN_SAMPLES = 3
#: percentile of past runtimes used as the estimate
DEFAULT_PERCENTILE = 90.0
#: multiplicative safety margin on top of the percentile
DEFAULT_MARGIN = 1.25
#: never predict below this (scheduler granularity)
MIN_PREDICT_S = 60


class RuntimePredictor:
    """Percentile-of-history duration estimator.

    The index is built lazily on first use from one store scan and keyed
    twice: ``(user, key)`` then ``key`` alone, where key is the tool name
    (for Launcher wrappers) or the job-name stem (for plain jobs). Only
    ``COMPLETED`` runs count — a TIMEOUT runtime is censored at the limit
    and says nothing about the true duration.
    """

    def __init__(
        self,
        store: HistoryStore,
        *,
        percentile: float = DEFAULT_PERCENTILE,
        min_samples: int = DEFAULT_MIN_SAMPLES,
        margin: float = DEFAULT_MARGIN,
    ):
        self.store = store
        self.percentile = float(percentile)
        self.min_samples = max(1, int(min_samples))
        self.margin = float(margin)
        self._index: dict | None = None
        #: per-(user, key) memo of indexed lookups, cleared by refresh()
        self._key_cache: dict = {}

    # -- public API ----------------------------------------------------------

    def predict(
        self, default_s: int, *, name: str = "", user: str = "", tool: str = ""
    ) -> int:
        """Estimated duration, clamped to ``[MIN_PREDICT_S, default_s]``.

        ``default_s`` is the requested time limit and is returned verbatim
        whenever there is no usable history for this job's key.
        """
        key = tool or (name_stem(name) if name else "")
        if not key:
            return default_s
        runtimes = self._lookup(user, key)
        if len(runtimes) < self.min_samples:
            return default_s
        est = _percentile(runtimes, self.percentile) * self.margin
        est = int(math.ceil(est / 60.0)) * 60  # round up to whole minutes
        # the limit clamp is applied LAST: the floor must never push the
        # estimate above a sub-minute request limit
        return min(default_s, max(MIN_PREDICT_S, est))

    def sample_count(self, *, name: str = "", user: str = "", tool: str = "") -> int:
        key = tool or (name_stem(name) if name else "")
        return len(self._lookup(user, key)) if key else 0

    def refresh(self) -> None:
        """Drop the cached index; the next predict() rescans the store."""
        self._index = None
        self._key_cache = {}

    # -- internals -----------------------------------------------------------

    def _lookup(self, user: str, key: str) -> list:
        if self._index is None:
            # prefer the store's sidecar index: one O(key) query instead of
            # a full-archive scan, memoized per (user, key) until refresh()
            memo = self._key_cache.get((user, key))
            if memo is not None:
                return memo
            runtimes_for = getattr(self.store, "runtimes_for", None)
            if runtimes_for is not None:
                rts = runtimes_for(key, user)
                if rts is not None:
                    self._key_cache[(user, key)] = rts
                    return rts
        idx = self._build()
        if user and (user, key) in idx:
            return idx[(user, key)]
        return idx.get(key, [])

    def _build(self) -> dict:
        if self._index is not None:
            return self._index
        idx: dict = {}
        for r in self.store.scan():
            if not r.completed or r.runtime_s <= 0:
                continue
            key = r.tool or name_stem(r.name)
            if not key:
                continue
            idx.setdefault(key, []).append(r.runtime_s)
            if r.user:
                idx.setdefault((r.user, key), []).append(r.runtime_s)
        for v in idx.values():
            v.sort()
        self._index = idx
        return idx


def _percentile(sorted_vals: list, p: float) -> float:
    """Nearest-rank-interpolated percentile of an ascending list."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return float(sorted_vals[0])
    rank = (p / 100.0) * (len(sorted_vals) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = rank - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


def predictor_from_config(cfg=None) -> "RuntimePredictor | None":
    """The predictor the submission paths use, or None.

    None when prediction is disabled (``eco_prediction = 0``) or the
    history file does not exist yet — both give today's exact behaviour.
    """
    if cfg is None:
        from repro.core.config import load_config

        cfg = load_config()
    if not cfg.get_bool("eco_prediction"):
        return None
    from .store import history_path

    path = history_path(cfg.get("history_file") or None)
    if not path.is_file():
        return None
    return RuntimePredictor(HistoryStore(path))
