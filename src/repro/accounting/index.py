"""``HistoryIndex`` — SQLite sidecar index over the JSONL job archive.

The JSONL file stays the source of truth (append-only, crash-tolerant,
human-greppable — see :mod:`repro.accounting.store`). This module keeps a
disposable SQLite database next to it (``<archive>.idx``) so the read
paths — ``ids()`` for collector dedup, ``records()`` for ``ecoreport``
filters, per-key runtime lists for the :class:`RuntimePredictor` — are
O(query) instead of O(archive).

Design rules:

* **JSONL is truth, the index is a cache.** The index ingests the archive
  incrementally by byte offset; any read starts with a cheap ``refresh()``
  that only parses bytes appended since the last one. If the file shrank
  or its head bytes changed (rotated, rewritten, migrated), the index is
  rebuilt from scratch — a rebuild is just one full scan, i.e. exactly
  what every read used to cost.
* **Bit-equal answers.** Every query reproduces the scan-and-filter
  semantics of :class:`HistoryStore` exactly, including skipping torn or
  corrupt lines and honouring a parseable unterminated final line (kept
  out of the database, overlaid at query time, because a later append
  would merge with it into one corrupt line — which is also what a plain
  scan would then see).
* **Fail open.** Any sqlite error propagates to the caller
  (:class:`HistoryStore`), which falls back to the plain scan and stops
  using the index for that store instance. Deleting ``<archive>.idx`` is
  always safe.
"""

from __future__ import annotations

import hashlib
import json
import sqlite3
import threading
from pathlib import Path

from datetime import datetime, timezone

SCHEMA_VERSION = 1

#: bytes of the archive head fingerprinted to detect in-place rewrites
_HEAD_BYTES = 4096

_SCHEMA = """
CREATE TABLE IF NOT EXISTS meta (
    key   TEXT PRIMARY KEY,
    value TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS records (
    seq       INTEGER PRIMARY KEY,
    jobid     TEXT NOT NULL,
    user      TEXT NOT NULL,
    state     TEXT NOT NULL,
    cluster   TEXT NOT NULL,
    tkey      TEXT NOT NULL,
    sortts    TEXT NOT NULL,
    completed INTEGER NOT NULL,
    runtime_s INTEGER NOT NULL,
    payload   TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS ix_records_jobid ON records (jobid);
CREATE INDEX IF NOT EXISTS ix_records_user ON records (user);
CREATE INDEX IF NOT EXISTS ix_records_tkey ON records (tkey, completed, runtime_s);
CREATE INDEX IF NOT EXISTS ix_records_sortts ON records (sortts);
"""


def _ts_key(t: "datetime | None") -> str:
    """Normalise a datetime to a fixed-width, lexicographically ordered key.

    Naive datetimes (everything the simulator and ``datetime.now()``
    produce) format as ``YYYY-MM-DDTHH:MM:SS.ffffff`` — fixed width, so
    string order is chronological order. Aware datetimes are converted to
    UTC and stripped, which keeps aware-vs-aware comparisons exact.
    """
    if t is None:
        return ""
    if t.tzinfo is not None:
        t = t.astimezone(timezone.utc).replace(tzinfo=None)
    return t.isoformat(sep="T", timespec="microseconds")


class HistoryIndex:
    """Incremental SQLite index over one JSONL archive file."""

    def __init__(self, archive_path: "str | Path"):
        self.path = Path(archive_path)
        self.db_path = self.path.with_name(self.path.name + ".idx")
        self._lock = threading.Lock()
        self._conn: "sqlite3.Connection | None" = None
        #: parseable-but-unterminated final line, overlaid on query results
        self._tail: "dict | None" = None
        # observability
        self.rebuilds = 0
        self.ingested = 0

    # -- connection & schema -------------------------------------------------

    def _connect(self) -> sqlite3.Connection:
        if self._conn is not None:
            return self._conn
        self.db_path.parent.mkdir(parents=True, exist_ok=True)
        conn = sqlite3.connect(
            str(self.db_path), timeout=5.0, check_same_thread=False
        )
        try:
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA synchronous=NORMAL")
            conn.executescript(_SCHEMA)
            ver = self._meta_get(conn, "version")
            if ver != str(SCHEMA_VERSION):
                if ver is not None:
                    # older/newer schema: drop and rebuild from the JSONL
                    conn.executescript(
                        "DROP TABLE IF EXISTS records; DROP TABLE IF EXISTS meta;"
                    )
                    conn.executescript(_SCHEMA)
                with conn:
                    self._meta_set(conn, "version", str(SCHEMA_VERSION))
        except sqlite3.DatabaseError:
            # corrupt sidecar: it is only a cache — remove and start over
            conn.close()
            self.db_path.unlink(missing_ok=True)
            conn = sqlite3.connect(
                str(self.db_path), timeout=5.0, check_same_thread=False
            )
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA synchronous=NORMAL")
            conn.executescript(_SCHEMA)
            with conn:
                self._meta_set(conn, "version", str(SCHEMA_VERSION))
        self._conn = conn
        return conn

    def close(self) -> None:
        with self._lock:
            if self._conn is not None:
                self._conn.close()
                self._conn = None

    @staticmethod
    def _meta_get(conn: sqlite3.Connection, key: str) -> "str | None":
        row = conn.execute(
            "SELECT value FROM meta WHERE key = ?", (key,)
        ).fetchone()
        return row[0] if row else None

    @staticmethod
    def _meta_set(conn: sqlite3.Connection, key: str, value: str) -> None:
        conn.execute(
            "INSERT INTO meta (key, value) VALUES (?, ?) "
            "ON CONFLICT(key) DO UPDATE SET value = excluded.value",
            (key, value),
        )

    # -- ingest --------------------------------------------------------------

    def refresh(self) -> None:
        """Bring the index up to date with the archive file.

        Cheap when nothing changed (one stat + one head-hash check);
        otherwise parses only the appended bytes. Called by every query.
        """
        with self._lock:
            self._refresh_locked()

    def _refresh_locked(self) -> None:
        conn = self._connect()
        try:
            size = self.path.stat().st_size
        except OSError:
            size = 0
        offset = int(self._meta_get(conn, "offset") or 0)
        head_len = int(self._meta_get(conn, "head_len") or 0)
        head_hash = self._meta_get(conn, "head_hash") or ""

        if size < offset or not self._head_matches(head_len, head_hash):
            # archive truncated, rotated, or rewritten in place: rebuild
            with conn:
                conn.execute("DELETE FROM records")
                self._meta_set(conn, "offset", "0")
                self._meta_set(conn, "head_len", "0")
                self._meta_set(conn, "head_hash", "")
            offset = 0
            self.rebuilds += 1
            from repro.obs.metrics import get_registry

            get_registry().counter(
                "nbi_history_index_rebuilds_total",
                "full index rebuilds (archive truncated/rotated/rewritten)",
            ).inc()

        self._tail = None
        if size <= offset:
            return
        from repro.obs.metrics import get_registry, timed

        reg = get_registry()
        with timed(reg.histogram(
            "nbi_history_index_ingest_seconds",
            "incremental ingest of appended archive bytes",
        )):
            self._ingest_locked(conn, offset, size)

    def _ingest_locked(self, conn, offset: int, size: int) -> None:
        with self.path.open("rb") as fh:
            fh.seek(offset)
            data = fh.read(size - offset)
        nl = data.rfind(b"\n")
        chunk, tail = (data[: nl + 1], data[nl + 1:]) if nl >= 0 else (b"", data)

        rows = []
        seq0 = offset  # byte offset of each line start doubles as a stable,
        pos = 0        # strictly increasing seq → file order == seq order
        for raw in chunk.splitlines(keepends=True):
            start = seq0 + pos
            pos += len(raw)
            row = _row_from_line(raw, start)
            if row is not None:
                rows.append(row)
        if tail:
            self._tail = _parse_line(tail)

        new_offset = offset + len(chunk)
        new_head_len = min(new_offset, _HEAD_BYTES)
        with conn:  # one transaction per refresh: crash-safe, serialized
            if rows:
                conn.executemany(
                    "INSERT OR REPLACE INTO records "
                    "(seq, jobid, user, state, cluster, tkey, sortts, "
                    " completed, runtime_s, payload) "
                    "VALUES (?, ?, ?, ?, ?, ?, ?, ?, ?, ?)",
                    rows,
                )
            self._meta_set(conn, "offset", str(new_offset))
            self._meta_set(conn, "head_len", str(new_head_len))
            self._meta_set(conn, "head_hash", self._hash_head(new_head_len))
        self.ingested += len(rows)
        if rows:
            from repro.obs.metrics import get_registry

            get_registry().counter(
                "nbi_history_index_ingested_total",
                "archive records ingested incrementally",
            ).inc(len(rows))

    def _head_matches(self, head_len: int, head_hash: str) -> bool:
        if head_len <= 0:
            return True  # nothing fingerprinted yet
        return self._hash_head(head_len) == head_hash

    def _hash_head(self, head_len: int) -> str:
        if head_len <= 0:
            return ""
        try:
            with self.path.open("rb") as fh:
                return hashlib.sha256(fh.read(head_len)).hexdigest()
        except OSError:
            return ""

    # -- queries -------------------------------------------------------------

    def ids(self) -> set:
        self.refresh()
        with self._lock:
            conn = self._connect()
            out = {row[0] for row in conn.execute("SELECT DISTINCT jobid FROM records")}
        tail = self._tail_record()
        if tail is not None:
            out.add(tail.jobid)
        return out

    def count(self) -> int:
        self.refresh()
        with self._lock:
            conn = self._connect()
            (n,) = conn.execute("SELECT COUNT(*) FROM records").fetchone()
        return int(n) + (1 if self._tail_record() is not None else 0)

    def records(
        self,
        *,
        user: "str | None" = None,
        tool: "str | None" = None,
        state: "str | None" = None,
        since: "datetime | None" = None,
        cluster: "str | None" = None,
    ) -> list:
        """Same result, same order, as the store's scan-and-filter path."""
        from .store import JobRecord

        self.refresh()
        where, params = [], []
        if user is not None:
            where.append("user = ?")
            params.append(user)
        if cluster is not None:
            where.append("cluster = ?")
            params.append(cluster)
        if tool is not None:
            where.append("tkey = ?")
            params.append(tool)
        if state is not None:
            where.append("state = ?")
            params.append(state)
        if since is not None:
            # records with no usable timestamp have sortts = '' and are
            # excluded, exactly as the scan path excludes t-is-None rows
            where.append("sortts >= ?")
            params.append(_ts_key(since))
        # no ORDER BY: it would bias the planner toward walking the whole
        # table in primary-key order instead of using the filter indexes;
        # file order is restored by the (trivial) seq sort in Python
        sql = "SELECT seq, payload FROM records"
        if where:
            sql += " WHERE " + " AND ".join(where)
        with self._lock:
            conn = self._connect()
            rows = conn.execute(sql, params).fetchall()
        rows.sort(key=lambda r: r[0])
        out = [JobRecord.from_dict(json.loads(p)) for _, p in rows]
        tail = self._tail_record()
        if tail is not None and _passes_filters(
            tail, user=user, tool=tool, state=state, since=since, cluster=cluster
        ):
            out.append(tail)
        return out

    def runtimes_for(self, key: str, user: str = "") -> list:
        """Ascending COMPLETED runtimes for a predictor key.

        Mirrors :meth:`RuntimePredictor._lookup`: the ``(user, key)`` list
        when the user has any history under this key, else the key-wide
        list (which may be empty).
        """
        from .store import name_stem

        self.refresh()
        tail = self._tail_record()
        tail_rt: "int | None" = None
        tail_user = ""
        if (
            tail is not None
            and tail.completed
            and tail.runtime_s > 0
            and (tail.tool or name_stem(tail.name)) == key
        ):
            tail_rt, tail_user = int(tail.runtime_s), tail.user
        base = (
            "SELECT runtime_s FROM records "
            "WHERE tkey = ? AND completed = 1 AND runtime_s > 0"
        )
        with self._lock:
            conn = self._connect()
            if user:
                rts = [
                    r[0]
                    for r in conn.execute(
                        base + " AND user = ? ORDER BY runtime_s", (key, user)
                    )
                ]
                if tail_rt is not None and tail_user == user:
                    return sorted(rts + [tail_rt])
                if rts:
                    # the (user, key) list exists; the tail (different user)
                    # could only extend the key-wide list, which is unused
                    return rts
            rts = [r[0] for r in conn.execute(base + " ORDER BY runtime_s", (key,))]
        if tail_rt is not None:
            rts = sorted(rts + [tail_rt])
        return rts

    # -- internals -----------------------------------------------------------

    def _tail_record(self):
        from .store import JobRecord

        if self._tail is None:
            return None
        try:
            return JobRecord.from_dict(self._tail)
        except TypeError:
            return None


def _parse_line(raw: bytes) -> "dict | None":
    try:
        line = raw.decode("utf-8").strip()
    except UnicodeDecodeError:
        return None
    if not line:
        return None
    try:
        d = json.loads(line)
    except json.JSONDecodeError:
        return None
    return d if isinstance(d, dict) else None


def _row_from_line(raw: bytes, seq: int) -> "tuple | None":
    from .store import JobRecord, name_stem

    d = _parse_line(raw)
    if d is None:
        return None
    try:
        rec = JobRecord.from_dict(d)
    except TypeError:
        return None
    sortts = _ts_key(rec.started_dt() or rec.requested_dt())
    return (
        seq,
        str(rec.jobid),
        str(rec.user),
        str(rec.state),
        str(rec.cluster),
        str(rec.tool or name_stem(rec.name)),
        sortts,
        1 if rec.completed else 0,
        int(rec.runtime_s or 0),
        json.dumps(d, separators=(",", ":"), sort_keys=True),
    )


def _passes_filters(r, *, user, tool, state, since, cluster) -> bool:
    """The scan path's filter predicate, verbatim (for the tail overlay)."""
    from .store import name_stem

    if user is not None and r.user != user:
        return False
    if cluster is not None and r.cluster != cluster:
        return False
    if tool is not None and (r.tool or name_stem(r.name)) != tool:
        return False
    if state is not None and r.state != state:
        return False
    if since is not None:
        t = r.started_dt() or r.requested_dt()
        if t is None or t < since:
            return False
    return True
