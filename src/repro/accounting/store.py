"""``HistoryStore`` — append-only on-disk archive of completed jobs.

Jobs vanish from ``squeue`` the moment they leave the queue; the store is
where they land afterwards, one JSON record per line. The format is
deliberately boring — JSONL, one :class:`JobRecord` per line — so it is

* **append-only**: writers hold a lock and issue one ``write()`` per
  record, so concurrent appenders interleave whole lines, never bytes;
* **crash-tolerant**: a torn final line is skipped on scan, not fatal;
* **forward-compatible**: unknown keys in old/new records are ignored,
  missing keys take the dataclass default.

Everything downstream — :mod:`repro.accounting.report` aggregation,
:class:`repro.accounting.predict.RuntimePredictor`, the ``ecoreport``
CLI — is a pure function of a scan over this file.
"""

from __future__ import annotations

import json
import os
import re
import threading
from dataclasses import asdict, dataclass, fields
from datetime import datetime
from pathlib import Path

#: default archive location; override with $NBI_HISTORY or the
#: ``history_file`` config key (see repro.core.config).
DEFAULT_HISTORY_PATH = "~/.nbi/history.jsonl"

_TERMINAL = (
    "COMPLETED", "FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL", "OUT_OF_MEMORY",
)


@dataclass
class JobRecord:
    """One completed job, as the accounting layer remembers it.

    Times are ISO-8601 strings (empty when unknown). ``runtime_s`` is the
    *actual* elapsed runtime; ``time_limit_s`` is what was requested — the
    gap between the two is exactly what the RuntimePredictor learns.
    ``carbon_nodefer_gco2`` is the counterfactual: the carbon this job
    would have emitted had it started at ``requested_start`` (submission
    time) instead of when eco mode actually ran it.
    """

    jobid: str = ""
    name: str = ""
    user: str = ""
    partition: str = ""
    cluster: str = ""  # federation member; "" on a single-cluster stack
    tool: str = ""  # wrapper/tool name; "" for plain runjob commands
    state: str = ""
    cpus: int = 1
    memory_mb: int = 0
    time_limit_s: int = 0
    runtime_s: int = 0
    submitted_at: str = ""
    started_at: str = ""
    finished_at: str = ""
    node: str = ""
    restarts: int = 0
    # eco decision, as made at submission time
    eco_deferred: bool = False
    eco_tier: int = 0
    requested_start: str = ""  # counterfactual no-eco start (submission time)
    # energy & carbon, filled in by the EnergyModel at collection time
    energy_kwh: float = 0.0
    carbon_gco2: float = 0.0
    carbon_nodefer_gco2: float = 0.0
    #: placement counterfactual (federation): the carbon this job would
    #: have emitted had it run on the DEFAULT cluster's grid instead of
    #: where the placer routed it; equals carbon_gco2 off-federation
    carbon_default_cluster_gco2: float = 0.0

    # -- serialisation -------------------------------------------------------

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "JobRecord":
        known = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    # -- derived -------------------------------------------------------------

    @property
    def is_terminal(self) -> bool:
        return self.state in _TERMINAL

    @property
    def completed(self) -> bool:
        return self.state == "COMPLETED"

    @property
    def cpu_hours(self) -> float:
        return self.cpus * self.runtime_s / 3600.0

    @property
    def carbon_saved_gco2(self) -> float:
        """Counterfactual minus actual (positive = eco mode saved carbon)."""
        return self.carbon_nodefer_gco2 - self.carbon_gco2

    @property
    def placement_saved_gco2(self) -> float:
        """Default-cluster counterfactual minus actual (positive = routing
        this job away from the default cluster saved carbon). Records
        archived before federation lack the counterfactual (0.0) and read
        as no saving, not a penalty."""
        if self.carbon_default_cluster_gco2 <= 0.0:
            return 0.0
        return self.carbon_default_cluster_gco2 - self.carbon_gco2

    def started_dt(self) -> datetime | None:
        return _parse_iso(self.started_at)

    def requested_dt(self) -> datetime | None:
        return _parse_iso(self.requested_start) or _parse_iso(self.submitted_at)


_SWEEP_SUFFIX = re.compile(r"[-_.]\d+$")


def name_stem(name: str) -> str:
    """Group ``align-0``/``align-1``/… sweeps under one key.

    Only a *separator + digits* suffix is stripped (repeatedly, to a fixed
    point), so the function is idempotent and a bare digit-ending name like
    ``kraken2`` keys as itself — records archived as ``kraken2-0`` and a
    lookup for ``kraken2`` land on the same key.
    """
    while True:
        stripped = _SWEEP_SUFFIX.sub("", name)
        if stripped == name or not stripped:
            return name
        name = stripped


def _parse_iso(s: str) -> datetime | None:
    if not s:
        return None
    try:
        return datetime.fromisoformat(s)
    except ValueError:
        return None


def history_path(path: str | None = None) -> Path:
    """Resolve the archive path: arg > $NBI_HISTORY > config > default."""
    if path:
        return Path(path).expanduser()
    env = os.environ.get("NBI_HISTORY")
    if env:
        return Path(env).expanduser()
    from repro.core.config import load_config

    cfg_path = load_config().get("history_file")
    return Path(cfg_path or DEFAULT_HISTORY_PATH).expanduser()


def log_submission(jobid, *, tool: str = "", eco_meta: "dict | None" = None) -> None:
    """Journal submission-time facts for the configured archive.

    Called by the submission paths (runjob / Launcher / SubmitEngine) so
    that ``collect()`` over *real* SLURM accounting can restore the tool
    and eco decision — the simulator carries them natively. No-op when
    there is nothing to journal.
    """
    log_submissions([(jobid, tool, eco_meta)])


def log_submissions(entries) -> None:
    """Batched :func:`log_submission`: ``entries`` is an iterable of
    ``(jobid, tool, eco_meta)``. Resolves the archive path and opens the
    journal once for the whole batch."""
    entries = [(j, t, m) for j, t, m in entries if t or m]
    if not entries:
        return
    HistoryStore().submit_log().log_many(entries)


class HistoryStore:
    """Append-only JSONL store of :class:`JobRecord` entries."""

    def __init__(self, path: "str | Path | None" = None):
        self.path = history_path(str(path) if path is not None else None)
        self._lock = threading.Lock()

    # -- writing -------------------------------------------------------------

    def append(self, record: JobRecord) -> None:
        self.append_many([record])

    def append_many(self, records: "list[JobRecord]") -> None:
        if not records:
            return
        payload = "".join(
            json.dumps(r.to_dict(), separators=(",", ":"), sort_keys=True) + "\n"
            for r in records
        )
        with self._lock:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self.path.open("a", encoding="utf-8") as fh:
                fh.write(payload)

    # -- reading -------------------------------------------------------------

    def scan(self):
        """Yield every parseable record in file order (torn lines skipped)."""
        if not self.path.is_file():
            return
        with self.path.open("r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield JobRecord.from_dict(json.loads(line))
                except (json.JSONDecodeError, TypeError):
                    continue  # torn/corrupt line — skip, keep scanning

    def __iter__(self):
        return self.scan()

    def __len__(self) -> int:
        return sum(1 for _ in self.scan())

    def ids(self) -> set:
        """Job ids already archived (collectors dedup against this)."""
        return {r.jobid for r in self.scan()}

    # -- submission-side companion --------------------------------------------

    def submit_log(self) -> "SubmitLog":
        """The sidecar recording submission-time facts for this archive."""
        return SubmitLog(self.path.with_name(self.path.name + ".submits"))

    def records(
        self,
        *,
        user: str | None = None,
        tool: str | None = None,
        state: str | None = None,
        since: datetime | None = None,
        cluster: str | None = None,
    ) -> "list[JobRecord]":
        out = []
        for r in self.scan():
            if user is not None and r.user != user:
                continue
            if cluster is not None and r.cluster != cluster:
                continue
            # same key the report prints for --by tool, so a user can
            # filter by exactly what the table showed
            if tool is not None and (r.tool or name_stem(r.name)) != tool:
                continue
            if state is not None and r.state != state:
                continue
            if since is not None:
                t = r.started_dt() or r.requested_dt()
                if t is None or t < since:
                    continue
            out.append(r)
        return out


class SubmitLog:
    """Submission-time facts sacct can never report (tool, eco decision).

    The simulator carries these on the :class:`SimJob` itself, but real
    SLURM forgets them the moment ``sbatch`` returns — so the submission
    paths journal ``jobid → {tool, eco_tier, eco_deferred}`` here (same
    JSONL discipline as the main archive) and ``collect()`` merges the
    journal into sacct-derived records. Missing/unjournaled jobids simply
    keep the field defaults.
    """

    def __init__(self, path: "str | Path"):
        self.path = Path(path).expanduser()
        self._lock = threading.Lock()

    def log(self, jobid, *, tool: str = "", eco_meta: "dict | None" = None) -> None:
        if not tool and not eco_meta:
            return  # nothing sacct doesn't already know
        self.log_many([(jobid, tool, eco_meta)])

    def log_many(self, entries) -> None:
        """One locked write for a whole batch of ``(jobid, tool, eco_meta)``."""
        lines = []
        for jobid, tool, eco_meta in entries:
            entry = {"jobid": str(jobid), "tool": tool or ""}
            if eco_meta:
                entry["eco_tier"] = int(eco_meta.get("tier", 0) or 0)
                entry["eco_deferred"] = bool(eco_meta.get("deferred", False))
                if eco_meta.get("hold"):
                    # hold-and-release: the deadline lets another process
                    # (EcoController.adopt) take over releasing this job
                    entry["eco_hold"] = True
                    entry["eco_deadline"] = str(eco_meta.get("deadline", ""))
                    entry["eco_duration_s"] = int(eco_meta.get("duration_s", 0) or 0)
            lines.append(json.dumps(entry, separators=(",", ":"), sort_keys=True))
        if not lines:
            return
        payload = "\n".join(lines) + "\n"
        with self._lock:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self.path.open("a", encoding="utf-8") as fh:
                fh.write(payload)

    def load(self) -> "dict[str, dict]":
        """jobid → journal entry (later entries win)."""
        out: dict[str, dict] = {}
        if not self.path.is_file():
            return out
        with self.path.open("r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    entry = json.loads(line)
                except json.JSONDecodeError:
                    continue
                jid = str(entry.get("jobid", ""))
                if jid:
                    out[jid] = entry
        return out
