"""``HistoryStore`` — append-only on-disk archive of completed jobs.

Jobs vanish from ``squeue`` the moment they leave the queue; the store is
where they land afterwards, one JSON record per line. The format is
deliberately boring — JSONL, one :class:`JobRecord` per line — so it is

* **append-only**: writers hold a lock and issue one ``write()`` per
  record, so concurrent appenders interleave whole lines, never bytes;
* **crash-tolerant**: a torn final line is skipped on scan, not fatal;
* **forward-compatible**: unknown keys in old/new records are ignored,
  missing keys take the dataclass default.

Everything downstream — :mod:`repro.accounting.report` aggregation,
:class:`repro.accounting.predict.RuntimePredictor`, the ``ecoreport``
CLI — is a pure function of a scan over this file.
"""

from __future__ import annotations

import json
import os
import re
import threading
from dataclasses import asdict, dataclass, fields
from datetime import datetime
from pathlib import Path

#: default archive location; override with $NBI_HISTORY or the
#: ``history_file`` config key (see repro.core.config).
DEFAULT_HISTORY_PATH = "~/.nbi/history.jsonl"

_TERMINAL = (
    "COMPLETED", "FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL", "OUT_OF_MEMORY",
)


@dataclass
class JobRecord:
    """One completed job, as the accounting layer remembers it.

    Times are ISO-8601 strings (empty when unknown). ``runtime_s`` is the
    *actual* elapsed runtime; ``time_limit_s`` is what was requested — the
    gap between the two is exactly what the RuntimePredictor learns.
    ``carbon_nodefer_gco2`` is the counterfactual: the carbon this job
    would have emitted had it started at ``requested_start`` (submission
    time) instead of when eco mode actually ran it.
    """

    jobid: str = ""
    name: str = ""
    user: str = ""
    partition: str = ""
    cluster: str = ""  # federation member; "" on a single-cluster stack
    tool: str = ""  # wrapper/tool name; "" for plain runjob commands
    state: str = ""
    cpus: int = 1
    memory_mb: int = 0
    time_limit_s: int = 0
    runtime_s: int = 0
    submitted_at: str = ""
    started_at: str = ""
    finished_at: str = ""
    node: str = ""
    restarts: int = 0
    # eco decision, as made at submission time
    eco_deferred: bool = False
    eco_tier: int = 0
    requested_start: str = ""  # counterfactual no-eco start (submission time)
    # energy & carbon, filled in by the EnergyModel at collection time
    energy_kwh: float = 0.0
    carbon_gco2: float = 0.0
    carbon_nodefer_gco2: float = 0.0
    #: placement counterfactual (federation): the carbon this job would
    #: have emitted had it run on the DEFAULT cluster's grid instead of
    #: where the placer routed it; equals carbon_gco2 off-federation
    carbon_default_cluster_gco2: float = 0.0

    # -- serialisation -------------------------------------------------------

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "JobRecord":
        known = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    # -- derived -------------------------------------------------------------

    @property
    def is_terminal(self) -> bool:
        return self.state in _TERMINAL

    @property
    def completed(self) -> bool:
        return self.state == "COMPLETED"

    @property
    def cpu_hours(self) -> float:
        return self.cpus * self.runtime_s / 3600.0

    @property
    def carbon_saved_gco2(self) -> float:
        """Counterfactual minus actual (positive = eco mode saved carbon)."""
        return self.carbon_nodefer_gco2 - self.carbon_gco2

    @property
    def placement_saved_gco2(self) -> float:
        """Default-cluster counterfactual minus actual (positive = routing
        this job away from the default cluster saved carbon). Records
        archived before federation lack the counterfactual (0.0) and read
        as no saving, not a penalty."""
        if self.carbon_default_cluster_gco2 <= 0.0:
            return 0.0
        return self.carbon_default_cluster_gco2 - self.carbon_gco2

    def started_dt(self) -> datetime | None:
        return _parse_iso(self.started_at)

    def requested_dt(self) -> datetime | None:
        return _parse_iso(self.requested_start) or _parse_iso(self.submitted_at)


_SWEEP_SUFFIX = re.compile(r"[-_.]\d+$")


def name_stem(name: str) -> str:
    """Group ``align-0``/``align-1``/… sweeps under one key.

    Only a *separator + digits* suffix is stripped (repeatedly, to a fixed
    point), so the function is idempotent and a bare digit-ending name like
    ``kraken2`` keys as itself — records archived as ``kraken2-0`` and a
    lookup for ``kraken2`` land on the same key.
    """
    while True:
        stripped = _SWEEP_SUFFIX.sub("", name)
        if stripped == name or not stripped:
            return name
        name = stripped


def _parse_iso(s: str) -> datetime | None:
    if not s:
        return None
    try:
        return datetime.fromisoformat(s)
    except ValueError:
        return None


def history_path(path: str | None = None) -> Path:
    """Resolve the archive path: arg > $NBI_HISTORY > config > default."""
    if path:
        return Path(path).expanduser()
    env = os.environ.get("NBI_HISTORY")
    if env:
        return Path(env).expanduser()
    from repro.core.config import load_config

    cfg_path = load_config().get("history_file")
    return Path(cfg_path or DEFAULT_HISTORY_PATH).expanduser()


def log_submission(jobid, *, tool: str = "", eco_meta: "dict | None" = None) -> None:
    """Journal submission-time facts for the configured archive.

    Called by the submission paths (runjob / Launcher / SubmitEngine) so
    that ``collect()`` over *real* SLURM accounting can restore the tool
    and eco decision — the simulator carries them natively. No-op when
    there is nothing to journal.
    """
    log_submissions([(jobid, tool, eco_meta)])


def log_submissions(entries) -> None:
    """Batched :func:`log_submission`: ``entries`` is an iterable of
    ``(jobid, tool, eco_meta)``. Resolves the archive path and opens the
    journal once for the whole batch."""
    entries = [(j, t, m) for j, t, m in entries if t or m]
    if not entries:
        return
    HistoryStore().submit_log().log_many(entries)


class HistoryStore:
    """Append-only JSONL store of :class:`JobRecord` entries.

    Reads go through a SQLite sidecar index (``<archive>.idx``, see
    :mod:`repro.accounting.index`) when available, so ``ids()``,
    ``records()`` filters and predictor lookups cost O(query) instead of
    O(archive). The JSONL file remains the source of truth: the index is
    rebuilt from it whenever it disagrees, any index error falls back to
    the plain scan, and ``NBI_HISTORY_INDEX=0`` disables it outright.
    """

    def __init__(self, path: "str | Path | None" = None):
        self.path = history_path(str(path) if path is not None else None)
        self._lock = threading.Lock()
        self._index_obj = None
        self._index_broken = False
        self._submit_log: "SubmitLog | None" = None
        # ids() cache, valid while the file size matches what we last saw —
        # collectors call ids() per collect(), appends keep it warm
        self._ids_cache: "set | None" = None
        self._ids_cache_size = -1

    # -- writing -------------------------------------------------------------

    def append(self, record: JobRecord) -> None:
        self.append_many([record])

    def append_many(self, records: "list[JobRecord]") -> None:
        if not records:
            return
        payload = "".join(
            json.dumps(r.to_dict(), separators=(",", ":"), sort_keys=True) + "\n"
            for r in records
        )
        with self._lock:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            try:
                size0 = self.path.stat().st_size
            except OSError:
                size0 = 0
            with self.path.open("a", encoding="utf-8") as fh:
                fh.write(payload)
            if self._ids_cache is not None:
                if self._ids_cache_size == size0:
                    self._ids_cache.update(str(r.jobid) for r in records)
                    self._ids_cache_size = size0 + len(payload.encode("utf-8"))
                else:
                    self._ids_cache = None  # file changed under us: drop

    # -- reading -------------------------------------------------------------

    def scan(self):
        """Yield every parseable record in file order (torn lines skipped)."""
        if not self.path.is_file():
            return
        with self.path.open("r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield JobRecord.from_dict(json.loads(line))
                except (json.JSONDecodeError, TypeError):
                    continue  # torn/corrupt line — skip, keep scanning

    def __iter__(self):
        return self.scan()

    def __len__(self) -> int:
        idx = self._idx()
        if idx is not None:
            try:
                return idx.count()
            except Exception:
                self._fail_open()
        return sum(1 for _ in self.scan())

    def ids(self) -> set:
        """Job ids already archived (collectors dedup against this).

        Cached between calls and kept warm by :meth:`append_many`, so a
        collect() loop pays the archive read once, not once per cycle.
        Always returns a fresh set — callers mutate it for local dedup.
        """
        try:
            size = self.path.stat().st_size
        except OSError:
            size = 0
        with self._lock:
            if self._ids_cache is not None and self._ids_cache_size == size:
                return set(self._ids_cache)
        out: "set | None" = None
        idx = self._idx()
        if idx is not None:
            try:
                out = idx.ids()
            except Exception:
                self._fail_open()
        if out is None:
            out = {r.jobid for r in self.scan()}
        with self._lock:
            self._ids_cache = set(out)
            self._ids_cache_size = size
        return out

    # -- submission-side companion --------------------------------------------

    def submit_log(self) -> "SubmitLog":
        """The sidecar recording submission-time facts for this archive."""
        if self._submit_log is None:
            self._submit_log = SubmitLog(
                self.path.with_name(self.path.name + ".submits")
            )
        return self._submit_log

    def records(
        self,
        *,
        user: str | None = None,
        tool: str | None = None,
        state: str | None = None,
        since: datetime | None = None,
        cluster: str | None = None,
    ) -> "list[JobRecord]":
        idx = self._idx()
        if idx is not None:
            try:
                return idx.records(
                    user=user, tool=tool, state=state, since=since,
                    cluster=cluster,
                )
            except Exception:
                self._fail_open()
        return self._records_scan(
            user=user, tool=tool, state=state, since=since, cluster=cluster
        )

    def _records_scan(
        self,
        *,
        user: str | None = None,
        tool: str | None = None,
        state: str | None = None,
        since: datetime | None = None,
        cluster: str | None = None,
    ) -> "list[JobRecord]":
        """The scan-and-filter reference path (index bypassed)."""
        out = []
        for r in self.scan():
            if user is not None and r.user != user:
                continue
            if cluster is not None and r.cluster != cluster:
                continue
            # same key the report prints for --by tool, so a user can
            # filter by exactly what the table showed
            if tool is not None and (r.tool or name_stem(r.name)) != tool:
                continue
            if state is not None and r.state != state:
                continue
            if since is not None:
                t = r.started_dt() or r.requested_dt()
                if t is None or t < since:
                    continue
            out.append(r)
        return out

    def runtimes_for(self, key: str, user: str = "") -> "list[int] | None":
        """Ascending COMPLETED runtimes for a predictor key via the index,
        or None when no index is available (caller falls back to a scan)."""
        idx = self._idx()
        if idx is None:
            return None
        try:
            return idx.runtimes_for(key, user)
        except Exception:
            self._fail_open()
            return None

    # -- index plumbing -------------------------------------------------------

    def _fail_open(self) -> None:
        """Stop using the index for this store: every later read takes the
        plain JSONL scan (truth). Counted so operators can see a fleet
        silently degrading to O(archive) reads."""
        self._index_broken = True
        from repro.obs.metrics import get_registry

        get_registry().counter(
            "nbi_history_fail_open_total",
            "index errors that dropped a store to plain-scan reads",
        ).inc()

    def _idx(self):
        """The sidecar index, or None (disabled via env, or broken)."""
        if self._index_broken:
            return None
        if os.environ.get("NBI_HISTORY_INDEX", "1").lower() in ("0", "false", "no"):
            return None
        if self._index_obj is None:
            try:
                from .index import HistoryIndex

                self._index_obj = HistoryIndex(self.path)
            except Exception:
                self._fail_open()
                return None
        return self._index_obj


class SubmitLog:
    """Submission-time facts sacct can never report (tool, eco decision).

    The simulator carries these on the :class:`SimJob` itself, but real
    SLURM forgets them the moment ``sbatch`` returns — so the submission
    paths journal ``jobid → {tool, eco_tier, eco_deferred}`` here (same
    JSONL discipline as the main archive) and ``collect()`` merges the
    journal into sacct-derived records. Missing/unjournaled jobids simply
    keep the field defaults.
    """

    def __init__(self, path: "str | Path"):
        self.path = Path(path).expanduser()
        self._lock = threading.Lock()

    def log(self, jobid, *, tool: str = "", eco_meta: "dict | None" = None) -> None:
        if not tool and not eco_meta:
            return  # nothing sacct doesn't already know
        self.log_many([(jobid, tool, eco_meta)])

    def log_many(self, entries) -> None:
        """One locked write for a whole batch of ``(jobid, tool, eco_meta)``."""
        lines = []
        for jobid, tool, eco_meta in entries:
            entry = {"jobid": str(jobid), "tool": tool or ""}
            if eco_meta:
                entry["eco_tier"] = int(eco_meta.get("tier", 0) or 0)
                entry["eco_deferred"] = bool(eco_meta.get("deferred", False))
                if eco_meta.get("hold"):
                    # hold-and-release: the deadline lets another process
                    # (EcoController.adopt) take over releasing this job
                    entry["eco_hold"] = True
                    entry["eco_deadline"] = str(eco_meta.get("deadline", ""))
                    entry["eco_duration_s"] = int(eco_meta.get("duration_s", 0) or 0)
            lines.append(json.dumps(entry, separators=(",", ":"), sort_keys=True))
        if not lines:
            return
        payload = "\n".join(lines) + "\n"
        with self._lock:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self.path.open("a", encoding="utf-8") as fh:
                fh.write(payload)

    def load(self) -> "dict[str, dict]":
        """jobid → journal entry (later entries win).

        Incremental: a process-wide cache remembers how many bytes of each
        journal have been parsed, so repeated loads (one per ``collect()``
        cycle) only read what was appended since. Returns fresh dicts —
        callers merge and overwrite freely without corrupting the cache.
        """
        try:
            size = self.path.stat().st_size
        except OSError:
            with _JOURNAL_CACHE_LOCK:
                _JOURNAL_CACHE.pop(self.path, None)
            return {}
        with _JOURNAL_CACHE_LOCK:
            offset, entries = _JOURNAL_CACHE.get(self.path, (0, {}))
            if size < offset:  # truncated/replaced: start over
                offset, entries = 0, {}
            tail = b""
            if size > offset:
                with self.path.open("rb") as fh:
                    fh.seek(offset)
                    data = fh.read(size - offset)
                nl = data.rfind(b"\n")
                chunk, tail = (
                    (data[: nl + 1], data[nl + 1:]) if nl >= 0 else (b"", data)
                )
                if chunk:
                    entries = dict(entries)
                    for raw in chunk.splitlines():
                        entry = _parse_journal_line(raw)
                        if entry is not None:
                            entries[str(entry["jobid"])] = entry
                    offset += len(chunk)
                # the unterminated tail is NOT cached: a later append merges
                # with it into one (likely corrupt) line, exactly as a full
                # rescan would then see — so it is only overlaid per-call
                _JOURNAL_CACHE[self.path] = (offset, entries)
            out = {k: dict(v) for k, v in entries.items()}
        tail_entry = _parse_journal_line(tail)
        if tail_entry is not None:
            out[str(tail_entry["jobid"])] = tail_entry
        return out


def _parse_journal_line(raw: bytes) -> "dict | None":
    try:
        line = raw.decode("utf-8").strip()
    except UnicodeDecodeError:
        return None
    if not line:
        return None
    try:
        entry = json.loads(line)
    except json.JSONDecodeError:
        return None
    if not isinstance(entry, dict) or not str(entry.get("jobid", "")):
        return None
    return entry


#: journal read cache: path → (bytes parsed, jobid → entry)
_JOURNAL_CACHE: "dict[Path, tuple[int, dict]]" = {}
_JOURNAL_CACHE_LOCK = threading.Lock()
