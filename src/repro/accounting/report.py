"""Aggregation of the job archive into eco/energy reports.

Pure functions over a list of :class:`JobRecord`: group by user or tool,
sum energy, carbon, cpu-hours and the deferred-vs-counterfactual carbon
saving, and render either an ANSI table (via the shared
:mod:`repro.cli.render` machinery) or JSON. The ``ecoreport`` CLI is a
thin argument parser around this module.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .store import JobRecord


@dataclass
class GroupStats:
    """Aggregate over one group of records (a user, a tool, or everything)."""

    key: str = ""
    jobs: int = 0
    completed: int = 0
    failed: int = 0
    cpu_hours: float = 0.0
    energy_kwh: float = 0.0
    carbon_gco2: float = 0.0
    carbon_nodefer_gco2: float = 0.0
    carbon_default_cluster_gco2: float = 0.0
    eco_deferred: int = 0
    runtime_s_total: int = 0
    time_limit_s_total: int = 0
    tiers: dict = field(default_factory=lambda: {0: 0, 1: 0, 2: 0, 3: 0})

    def add(self, r: JobRecord) -> None:
        self.jobs += 1
        if r.completed:
            self.completed += 1
        elif r.state in ("FAILED", "TIMEOUT", "NODE_FAIL", "OUT_OF_MEMORY"):
            self.failed += 1
        self.cpu_hours += r.cpu_hours
        self.energy_kwh += r.energy_kwh
        self.carbon_gco2 += r.carbon_gco2
        self.carbon_nodefer_gco2 += r.carbon_nodefer_gco2
        # pre-federation records lack the placement counterfactual (0.0):
        # count them at actual carbon so they read as no saving, never a
        # penalty
        self.carbon_default_cluster_gco2 += (
            r.carbon_default_cluster_gco2 or r.carbon_gco2
        )
        if r.eco_deferred:
            self.eco_deferred += 1
        self.runtime_s_total += r.runtime_s
        self.time_limit_s_total += r.time_limit_s
        self.tiers[r.eco_tier if r.eco_tier in self.tiers else 0] += 1

    @property
    def carbon_saved_gco2(self) -> float:
        return self.carbon_nodefer_gco2 - self.carbon_gco2

    @property
    def placement_saved_gco2(self) -> float:
        """Carbon saved by routing jobs off the default cluster (federation)."""
        return self.carbon_default_cluster_gco2 - self.carbon_gco2

    @property
    def mean_runtime_s(self) -> float:
        return self.runtime_s_total / self.jobs if self.jobs else 0.0

    @property
    def limit_utilisation(self) -> float:
        """runtime / requested limit — how padded the requests are."""
        if not self.time_limit_s_total:
            return 0.0
        return self.runtime_s_total / self.time_limit_s_total

    def to_dict(self) -> dict:
        return {
            "key": self.key,
            "jobs": self.jobs,
            "completed": self.completed,
            "failed": self.failed,
            "cpu_hours": round(self.cpu_hours, 3),
            "energy_kwh": round(self.energy_kwh, 6),
            "carbon_gco2": round(self.carbon_gco2, 3),
            "carbon_nodefer_gco2": round(self.carbon_nodefer_gco2, 3),
            "carbon_saved_gco2": round(self.carbon_saved_gco2, 3),
            "carbon_default_cluster_gco2": round(self.carbon_default_cluster_gco2, 3),
            "placement_saved_gco2": round(self.placement_saved_gco2, 3),
            "eco_deferred": self.eco_deferred,
            "mean_runtime_s": round(self.mean_runtime_s, 1),
            "limit_utilisation": round(self.limit_utilisation, 4),
            "tiers": dict(self.tiers),
        }


def group_key(r: JobRecord, by: str) -> str:
    if by == "user":
        return r.user or "(unknown)"
    if by == "tool":
        from .predict import name_stem

        return r.tool or name_stem(r.name) or "(unnamed)"
    if by == "cluster":
        return r.cluster or "(default)"
    return "all"


def aggregate(records: "list[JobRecord]", by: str = "user") -> "dict[str, GroupStats]":
    """Group records and accumulate stats; keys sorted by energy, descending."""
    groups: dict[str, GroupStats] = {}
    for r in records:
        k = group_key(r, by)
        groups.setdefault(k, GroupStats(key=k)).add(r)
    return dict(
        sorted(groups.items(), key=lambda kv: (-kv[1].energy_kwh, kv[0]))
    )


def totals(records: "list[JobRecord]") -> GroupStats:
    t = GroupStats(key="total")
    for r in records:
        t.add(r)
    return t


def report_dict(records: "list[JobRecord]", by: str = "user") -> dict:
    """The full report payload (what ``ecoreport --json`` emits)."""
    return {
        "by": by,
        "groups": [g.to_dict() for g in aggregate(records, by).values()],
        "total": totals(records).to_dict(),
    }


REPORT_HEADERS = [
    "Key", "Jobs", "Done", "Defer", "CPUh",
    "Energy(kWh)", "CO2(g)", "NoEco CO2(g)", "Saved(g)", "Saved(%)",
]


def report_rows(groups: "dict[str, GroupStats]") -> "list[list[str]]":
    rows = []
    for g in groups.values():
        saved_pct = (
            100.0 * g.carbon_saved_gco2 / g.carbon_nodefer_gco2
            if g.carbon_nodefer_gco2 > 0
            else 0.0
        )
        rows.append(
            [
                g.key,
                str(g.jobs),
                str(g.completed),
                str(g.eco_deferred),
                f"{g.cpu_hours:.1f}",
                f"{g.energy_kwh:.3f}",
                f"{g.carbon_gco2:.1f}",
                f"{g.carbon_nodefer_gco2:.1f}",
                f"{g.carbon_saved_gco2:+.1f}",
                f"{saved_pct:+.1f}",
            ]
        )
    return rows


def render_report(records: "list[JobRecord]", by: str = "user",
                  *, color: "bool | None" = None) -> str:
    """Human-readable report: per-group table + a totals line."""
    from repro.cli.render import render_table

    groups = aggregate(records, by)
    t = totals(records)
    table = render_table(REPORT_HEADERS, report_rows(groups), enabled=color)
    saved_pct = (
        100.0 * t.carbon_saved_gco2 / t.carbon_nodefer_gco2
        if t.carbon_nodefer_gco2 > 0
        else 0.0
    )
    summary = (
        f"{t.jobs} job(s), {t.eco_deferred} eco-deferred | "
        f"{t.energy_kwh:.3f} kWh, {t.carbon_gco2:.1f} gCO2 "
        f"(no-eco counterfactual {t.carbon_nodefer_gco2:.1f} g → "
        f"saved {t.carbon_saved_gco2:+.1f} g, {saved_pct:+.1f}%)"
    )
    if abs(t.placement_saved_gco2) > 1e-9:  # federation-routed records only
        summary += (
            f"\nplacement: default-cluster counterfactual "
            f"{t.carbon_default_cluster_gco2:.1f} g → routing saved "
            f"{t.placement_saved_gco2:+.1f} g"
        )
    return table + "\n" + summary
