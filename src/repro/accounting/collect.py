"""Harvesters: backend accounting → :class:`JobRecord` → HistoryStore.

One entry point, :func:`collect`, closes the submit → run → account loop
for both backends:

* ``SimCluster.accounting()`` returns :class:`SimJob` objects carrying
  the simulator's deterministic ``energy_j`` and the eco metadata stamped
  at submission;
* ``SlurmBackend.accounting()`` returns sacct row dicts with measured
  ``ConsumedEnergy`` where the cluster reports it.

Records are deduplicated against ids already archived, so ``collect`` is
safe to run repeatedly (cron, post-advance in tests, ``ecoreport
--collect``).
"""

from __future__ import annotations

from datetime import datetime

from .energy import EnergyModel, parse_consumed_energy
from .store import HistoryStore, JobRecord


def collect(
    backend,
    store: HistoryStore,
    model: EnergyModel | None = None,
    *,
    since: str = "",
) -> int:
    """Archive every terminal job the backend knows that the store lacks.

    ``since`` (sacct ``--starttime`` syntax) widens the harvest window on
    the real backend — without it sacct only reports jobs from midnight
    today. Backends whose ``accounting()`` takes no arguments (the
    simulator) ignore it. Returns the number of records appended.
    """
    accounting = getattr(backend, "accounting", None)
    if accounting is None:
        return 0
    model = model or EnergyModel()
    seen = store.ids()
    # submission-time tool/eco facts: the target archive's sidecar, backed
    # by the default archive's — the submission paths always journal to
    # the configured default, which a custom --history must still see
    journal = _load_journal(store)
    fresh: list[JobRecord] = []
    rows = (
        accounting(since=since)
        if since and _accepts_since(accounting)
        else accounting()
    )
    for row in rows:
        rec = (
            record_from_sacct(row, model, journal=journal)
            if isinstance(row, dict)
            else record_from_sim(row, model)
        )
        if rec is None or rec.jobid in seen:
            continue
        seen.add(rec.jobid)
        fresh.append(rec)
    store.append_many(fresh)
    return len(fresh)


def _load_journal(store: HistoryStore) -> dict:
    journal = store.submit_log().load()
    default_log = HistoryStore().submit_log()
    if default_log.path != store.submit_log().path:
        merged = default_log.load()
        merged.update(journal)  # the target archive's own entries win
        journal = merged
    return journal


def _accepts_since(accounting) -> bool:
    """True when the backend's accounting() has a ``since`` parameter —
    checked by signature, not try/except, so a genuine TypeError raised
    *inside* a backend is never masked (or its sacct call re-run)."""
    import inspect

    try:
        params = inspect.signature(accounting).parameters
    except (TypeError, ValueError):
        return False
    return "since" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )


# ---------------------------------------------------------------------------
# SimCluster
# ---------------------------------------------------------------------------


def record_from_sim(j, model: EnergyModel) -> JobRecord | None:
    """SimJob → JobRecord (terminal jobs only)."""
    from repro.core.simcluster import _TERMINAL

    if j.state not in _TERMINAL:
        return None
    runtime = 0
    if j.started_at and j.finished_at:
        runtime = int((j.finished_at - j.started_at).total_seconds())
    rec = JobRecord(
        jobid=j.jobid,
        name=j.name,
        user=j.user,
        partition=j.partition,
        tool=getattr(j, "tool", "") or "",
        state=j.state,
        cpus=j.cpus,
        memory_mb=j.memory_mb,
        time_limit_s=j.time_limit_s,
        runtime_s=runtime,
        submitted_at=_iso(j.submitted_at),
        started_at=_iso(j.started_at),
        finished_at=_iso(j.finished_at),
        node=j.node or "",
        restarts=j.restarts,
        eco_deferred=bool(getattr(j, "eco_deferred", False)),
        eco_tier=int(getattr(j, "eco_tier", 0) or 0),
        requested_start=_iso(j.submitted_at),
        energy_kwh=model.energy_from_joules(getattr(j, "energy_j", 0.0)),
    )
    model.annotate(rec)
    return rec


# ---------------------------------------------------------------------------
# sacct (real SLURM)
# ---------------------------------------------------------------------------

_SACCT_TERMINAL_PREFIXES = (
    "COMPLETED", "FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL", "OUT_OF_ME",
)


def record_from_sacct(
    row: dict, model: EnergyModel, journal: "dict | None" = None
) -> JobRecord | None:
    """One parsed sacct row (see ``SlurmBackend.accounting``) → JobRecord.

    ``journal`` (jobid → :class:`~repro.accounting.store.SubmitLog` entry)
    restores what sacct cannot know: the originating tool and the eco
    decision made at submission — without it every real-SLURM record
    reads as never-deferred and the savings column stays 0.
    """
    state = (row.get("state") or "").split()[0] if row.get("state") else ""
    if not any(state.startswith(p) for p in _SACCT_TERMINAL_PREFIXES):
        return None
    if state.startswith("CANCELLED"):
        state = "CANCELLED"  # sacct reports "CANCELLED by <uid>"
    elif state.startswith("OUT_OF_ME"):
        state = "OUT_OF_MEMORY"  # may arrive truncated (OUT_OF_ME+)
    runtime = int(float(row.get("elapsed_s") or 0))
    rec = JobRecord(
        jobid=str(row.get("jobid", "")),
        name=row.get("name", ""),
        user=row.get("user", ""),
        partition=row.get("partition", ""),
        state=state,
        cpus=int(float(row.get("cpus") or 1)),
        memory_mb=int(float(row.get("memory_mb") or 0)),
        time_limit_s=int(float(row.get("time_limit_s") or 0)),
        runtime_s=runtime,
        submitted_at=row.get("submitted_at", ""),
        started_at=row.get("started_at", ""),
        finished_at=row.get("finished_at", ""),
        node=row.get("node", ""),
        requested_start=row.get("submitted_at", ""),
        energy_kwh=model.energy_from_joules(
            parse_consumed_energy(str(row.get("consumed_energy", "")))
        ),
    )
    entry = (journal or {}).get(rec.jobid)
    if entry:
        rec.tool = entry.get("tool", "") or rec.tool
        rec.eco_tier = int(entry.get("eco_tier", 0) or 0)
        rec.eco_deferred = bool(entry.get("eco_deferred", False))
    model.annotate(rec)
    return rec


def _iso(t: datetime | None) -> str:
    return t.isoformat(sep="T", timespec="seconds") if t else ""
