"""Harvesters: backend accounting → :class:`JobRecord` → HistoryStore.

One entry point, :func:`collect`, closes the submit → run → account loop
for both backends:

* ``SimCluster.accounting()`` returns :class:`SimJob` objects carrying
  the simulator's deterministic ``energy_j`` and the eco metadata stamped
  at submission;
* ``SlurmBackend.accounting()`` returns sacct row dicts with measured
  ``ConsumedEnergy`` where the cluster reports it.

Records are deduplicated against ids already archived, so ``collect`` is
safe to run repeatedly (cron, post-advance in tests, ``ecoreport
--collect``).

:class:`EventCollector` is the event-driven alternative: subscribed to a
backend's :class:`~repro.core.events.EventBus`, it archives each job *at
its terminal event* — the archive is scanned once at attach time and never
again, where repeated ``collect()`` calls re-read the whole accounting
table and the whole archive every time.
"""

from __future__ import annotations

from datetime import datetime

from repro.core.events import TERMINAL_EVENTS

from .energy import EnergyModel, parse_consumed_energy
from .store import HistoryStore, JobRecord


def collect(
    backend,
    store: HistoryStore,
    model: EnergyModel | None = None,
    *,
    since: str = "",
) -> int:
    """Archive every terminal job the backend knows that the store lacks.

    ``since`` (sacct ``--starttime`` syntax) widens the harvest window on
    the real backend — without it sacct only reports jobs from midnight
    today. Backends whose ``accounting()`` takes no arguments (the
    simulator) ignore it. Returns the number of records appended.
    """
    accounting = getattr(backend, "accounting", None)
    if accounting is None:
        return 0
    model = model or EnergyModel()
    seen = store.ids()
    # submission-time tool/eco facts: the target archive's sidecar, backed
    # by the default archive's — the submission paths always journal to
    # the configured default, which a custom --history must still see
    journal = _load_journal(store)
    fresh: list[JobRecord] = []
    rows = (
        accounting(since=since)
        if since and _accepts_since(accounting)
        else accounting()
    )
    for row in rows:
        rec = (
            record_from_sacct(row, model, journal=journal)
            if isinstance(row, dict)
            else record_from_sim(row, model)
        )
        if rec is None or rec.jobid in seen:
            continue
        seen.add(rec.jobid)
        fresh.append(rec)
    store.append_many(fresh)
    return len(fresh)


class EventCollector:
    """Archive jobs as their terminal :class:`JobEvent` s arrive.

    Where :func:`collect` is a batch rescan — every call re-reads the
    backend's full accounting table *and* the full archive to dedupe —
    the event collector pays the archive scan once (``store.ids()`` at
    construction) and then appends exactly one record per terminal event,
    buffered in batches of ``flush_every`` appends.

    Usage::

        coll = EventCollector(sim, store).attach(sim.bus)
        sim.advance(...)          # records accumulate as jobs finish
        coll.flush()              # drain the buffer (also on detach())

    The backend must resolve ``get(jobid)`` to a SimJob-shaped object
    (the simulator, possibly behind a QueueCache). Real SLURM keeps using
    :func:`collect` — sacct only learns a job's energy after the fact, so
    there is nothing to harvest at event time.
    """

    def __init__(self, backend, store: HistoryStore,
                 model: EnergyModel | None = None, *, flush_every: int = 32):
        self.backend = backend
        self.store = store
        self.model = model or EnergyModel()
        self.flush_every = max(1, int(flush_every))
        self._seen = store.ids()  # the one and only archive scan
        self._buffer: list[JobRecord] = []
        self._bus_token: "tuple | None" = None
        self.collected = 0

    def attach(self, bus) -> "EventCollector":
        """Subscribe to ``bus`` (terminal events only); returns self."""
        self.detach()
        self._bus_token = (bus, bus.subscribe(self.on_event, types=TERMINAL_EVENTS))
        return self

    def detach(self) -> None:
        """Unsubscribe and drain the buffer."""
        if self._bus_token is not None:
            bus, token = self._bus_token
            bus.unsubscribe(token)
            self._bus_token = None
        self.flush()

    def on_event(self, event) -> None:
        if event.jobid in self._seen:
            return
        job = self.backend.get(event.jobid)
        if job is None:
            return
        rec = record_from_sim(job, self.model)
        if rec is None:
            return
        self._seen.add(rec.jobid)
        self._buffer.append(rec)
        self.collected += 1
        if len(self._buffer) >= self.flush_every:
            self.flush()

    def flush(self) -> int:
        """Write buffered records; returns how many were written."""
        n = len(self._buffer)
        if n:
            self.store.append_many(self._buffer)
            self._buffer = []
        return n


def _load_journal(store: HistoryStore) -> dict:
    journal = store.submit_log().load()
    default_log = HistoryStore().submit_log()
    if default_log.path != store.submit_log().path:
        merged = default_log.load()
        merged.update(journal)  # the target archive's own entries win
        journal = merged
    return journal


def _accepts_since(accounting) -> bool:
    """True when the backend's accounting() has a ``since`` parameter —
    checked by signature, not try/except, so a genuine TypeError raised
    *inside* a backend is never masked (or its sacct call re-run)."""
    import inspect

    try:
        params = inspect.signature(accounting).parameters
    except (TypeError, ValueError):
        return False
    return "since" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )


# ---------------------------------------------------------------------------
# SimCluster
# ---------------------------------------------------------------------------


def record_from_sim(j, model: EnergyModel) -> JobRecord | None:
    """SimJob → JobRecord (terminal jobs only)."""
    from repro.core.simcluster import _TERMINAL

    if j.state not in _TERMINAL:
        return None
    runtime = 0
    if j.started_at and j.finished_at:
        runtime = int((j.finished_at - j.started_at).total_seconds())
    rec = JobRecord(
        jobid=j.jobid,
        name=j.name,
        user=j.user,
        partition=j.partition,
        cluster=getattr(j, "cluster", "") or "",
        tool=getattr(j, "tool", "") or "",
        state=j.state,
        cpus=j.cpus,
        memory_mb=j.memory_mb,
        time_limit_s=j.time_limit_s,
        runtime_s=runtime,
        submitted_at=_iso(j.submitted_at),
        started_at=_iso(j.started_at),
        finished_at=_iso(j.finished_at),
        node=j.node or "",
        restarts=j.restarts,
        eco_deferred=bool(getattr(j, "eco_deferred", False)),
        eco_tier=int(getattr(j, "eco_tier", 0) or 0),
        requested_start=_iso(j.submitted_at),
        energy_kwh=model.energy_from_joules(getattr(j, "energy_j", 0.0)),
    )
    model.annotate(rec)
    return rec


# ---------------------------------------------------------------------------
# sacct (real SLURM)
# ---------------------------------------------------------------------------

_SACCT_TERMINAL_PREFIXES = (
    "COMPLETED", "FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL", "OUT_OF_ME",
)


def record_from_sacct(
    row: dict, model: EnergyModel, journal: "dict | None" = None
) -> JobRecord | None:
    """One parsed sacct row (see ``SlurmBackend.accounting``) → JobRecord.

    ``journal`` (jobid → :class:`~repro.accounting.store.SubmitLog` entry)
    restores what sacct cannot know: the originating tool and the eco
    decision made at submission — without it every real-SLURM record
    reads as never-deferred and the savings column stays 0.
    """
    state = (row.get("state") or "").split()[0] if row.get("state") else ""
    if not any(state.startswith(p) for p in _SACCT_TERMINAL_PREFIXES):
        return None
    if state.startswith("CANCELLED"):
        state = "CANCELLED"  # sacct reports "CANCELLED by <uid>"
    elif state.startswith("OUT_OF_ME"):
        state = "OUT_OF_MEMORY"  # may arrive truncated (OUT_OF_ME+)
    runtime = int(float(row.get("elapsed_s") or 0))
    rec = JobRecord(
        jobid=str(row.get("jobid", "")),
        name=row.get("name", ""),
        user=row.get("user", ""),
        partition=row.get("partition", ""),
        cluster=str(row.get("cluster", "")),
        state=state,
        cpus=int(float(row.get("cpus") or 1)),
        memory_mb=int(float(row.get("memory_mb") or 0)),
        time_limit_s=int(float(row.get("time_limit_s") or 0)),
        runtime_s=runtime,
        submitted_at=row.get("submitted_at", ""),
        started_at=row.get("started_at", ""),
        finished_at=row.get("finished_at", ""),
        node=row.get("node", ""),
        requested_start=row.get("submitted_at", ""),
        energy_kwh=model.energy_from_joules(
            parse_consumed_energy(str(row.get("consumed_energy", "")))
        ),
    )
    entry = (journal or {}).get(rec.jobid)
    if entry:
        rec.tool = entry.get("tool", "") or rec.tool
        rec.eco_tier = int(entry.get("eco_tier", 0) or 0)
        rec.eco_deferred = bool(entry.get("eco_deferred", False))
    model.annotate(rec)
    return rec


def _iso(t: datetime | None) -> str:
    return t.isoformat(sep="T", timespec="seconds") if t else ""
