"""Accounting & energy telemetry — the submit → run → account → learn loop.

The queue forgets a job the moment it finishes; this package remembers it:

* :class:`HistoryStore` — append-only JSONL archive of completed jobs
  (runtime, resources requested vs used, exit state, eco decision, energy);
* :class:`EnergyModel` — per-job energy/carbon, from measured sacct
  ``ConsumedEnergy`` on real SLURM or the simulator's deterministic
  cpu × time × TDP model;
* :func:`collect` — harvest a backend's accounting into the store (idempotent);
* :class:`RuntimePredictor` — history-driven duration estimates that feed
  the EcoScheduler so habitually short jobs land in tier-1 windows
  (hard invariant: no history ⇒ decisions bit-identical to today);
* :mod:`~repro.accounting.report` — per-user/per-tool energy, carbon and
  "carbon saved by eco mode" aggregation behind the ``ecoreport`` CLI.
"""

from .collect import EventCollector, collect, record_from_sacct, record_from_sim
from .energy import (
    DEFAULT_WATTS_PER_CPU,
    EnergyModel,
    parse_consumed_energy,
    synthetic_trace,
)
from .predict import RuntimePredictor, name_stem, predictor_from_config
from .report import GroupStats, aggregate, render_report, report_dict, totals
from .store import (
    DEFAULT_HISTORY_PATH,
    HistoryStore,
    JobRecord,
    SubmitLog,
    history_path,
    log_submission,
    log_submissions,
)

__all__ = [
    "DEFAULT_HISTORY_PATH", "DEFAULT_WATTS_PER_CPU",
    "EnergyModel", "EventCollector", "GroupStats", "HistoryStore", "JobRecord",
    "RuntimePredictor", "SubmitLog",
    "aggregate", "collect", "history_path",
    "log_submission", "log_submissions", "name_stem",
    "parse_consumed_energy", "predictor_from_config",
    "record_from_sacct", "record_from_sim",
    "render_report", "report_dict", "synthetic_trace", "totals",
]
