"""``EnergyModel`` — per-job energy and carbon derivation.

Two sources, one output:

* **Measured** — real SLURM reports ``ConsumedEnergy`` via sacct (RAPL /
  IPMI, in joules, sometimes with K/M/G suffixes). When a row carries a
  nonzero reading we trust it.
* **Modelled** — everywhere else (the simulator, clusters without energy
  plugins) we fall back to a deterministic cpu × time × TDP model:
  ``(baseline_w + cpus · watts_per_cpu) · runtime``. Deliberately simple:
  the point is a *consistent, reproducible* figure the eco-mode
  counterfactual can difference against, not a watt-accurate meter.

Carbon is energy × grid intensity at the time the job ran. With a
measured :class:`~repro.core.eco.CarbonTrace` configured we use it;
otherwise :func:`synthetic_trace` supplies a deterministic hour-of-week
reference curve (night < day < evening peak, weekends lower) so that
deferral arithmetic — actual vs "had it run at submission" — is nonzero
and reproducible out of the box.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from datetime import datetime

from repro.core.eco import CarbonTrace

#: default busy-core power draw. ~250 W TDP across 20 cores plus a share
#: of fans/DRAM lands in the low tens of watts per allocated core.
DEFAULT_WATTS_PER_CPU = 12.0

#: flat fallback intensity (gCO2/kWh) when even the synthetic curve is off
DEFAULT_INTENSITY = 300.0

_J_PER_KWH = 3.6e6

_SUFFIX = {"K": 1e3, "M": 1e6, "G": 1e9, "T": 1e12}


def parse_consumed_energy(s: str) -> float:
    """sacct ``ConsumedEnergy`` → joules. Handles '', '0', '1234', '2.43K'."""
    s = (s or "").strip()
    if not s:
        return 0.0
    mult = 1.0
    if s[-1].upper() in _SUFFIX:
        mult = _SUFFIX[s[-1].upper()]
        s = s[:-1]
    try:
        return float(s) * mult
    except ValueError:
        return 0.0


def synthetic_trace() -> CarbonTrace:
    """Deterministic 168-hour reference intensity curve (gCO2/kWh).

    Shape, not measurement: overnight base ~210, a working-hours plateau,
    an evening peak ~430 (17:00-20:00 — the default ``peak_hours``), and
    ~12% lower weekends. Replace with a real trace (config key
    ``carbon_trace``) for actual grid figures.
    """
    hourly: list[float] = []
    for dow in range(7):
        weekend = dow >= 5
        for hour in range(24):
            v = 210.0
            if 7 <= hour < 17:
                v += 90.0  # daytime demand plateau
            if 17 <= hour < 20:
                v += 220.0  # evening peak
            elif 20 <= hour < 23:
                v += 60.0  # shoulder
            if weekend:
                v *= 0.88
            hourly.append(round(v, 1))
    return CarbonTrace(hourly)


@dataclass
class EnergyModel:
    """Derive (energy_kwh, carbon_gco2) for one job.

    On a federation each member can sit on a different grid:
    ``cluster_traces`` maps member name → its :class:`CarbonTrace`, and
    ``default_cluster`` names the member whose grid anchors the placement
    counterfactual ("what if this job had run on the default cluster").
    Both default empty, which reproduces single-cluster behaviour exactly.
    """

    watts_per_cpu: float = DEFAULT_WATTS_PER_CPU
    baseline_w: float = 0.0
    trace: CarbonTrace | None = field(default_factory=synthetic_trace)
    flat_intensity: float = DEFAULT_INTENSITY
    cluster_traces: dict = field(default_factory=dict)
    default_cluster: str = ""

    @classmethod
    def from_config(cls, cfg=None) -> "EnergyModel":
        """Build from ``~/.nbislurm.config`` (watts + optional real trace,
        plus per-cluster traces from any ``[cluster.<name>]`` stanzas)."""
        if cfg is None:
            from repro.core.config import load_config

            cfg = load_config()
        watts = float(cfg.get("energy_cpu_watts", str(DEFAULT_WATTS_PER_CPU))
                      or DEFAULT_WATTS_PER_CPU)
        trace_path = cfg.get("carbon_trace")
        trace = CarbonTrace.from_csv(trace_path) if trace_path else synthetic_trace()
        cluster_traces: dict = {}
        default_cluster = ""
        names = cfg.cluster_names()
        if names:
            for name in names:
                path = cfg.cluster_section(name).get("carbon_trace", "").strip()
                if path:
                    cluster_traces[name] = CarbonTrace.from_csv(path)
            default_cluster = cfg.get("default_cluster", "").strip() or names[0]
        return cls(watts_per_cpu=watts, trace=trace,
                   cluster_traces=cluster_traces, default_cluster=default_cluster)

    # -- energy --------------------------------------------------------------

    def energy_kwh(self, cpus: int, runtime_s: float) -> float:
        """Modelled energy: (baseline + cpus × per-core watts) × runtime."""
        watts = self.baseline_w + max(0, cpus) * self.watts_per_cpu
        return watts * max(0.0, runtime_s) / _J_PER_KWH

    def energy_from_joules(self, joules: float) -> float:
        return max(0.0, joules) / _J_PER_KWH

    # -- carbon --------------------------------------------------------------

    def intensity(
        self, start: datetime | None, runtime_s: float, *, cluster: str = ""
    ) -> float:
        """Mean gCO2/kWh over the job span (flat fallback without a clock).

        ``cluster`` selects that member's grid trace when one is
        configured; unknown/empty names fall back to the global trace.
        """
        trace = self.cluster_traces.get(cluster, self.trace) if cluster else self.trace
        if start is None or trace is None:
            return self.flat_intensity
        return trace.mean_over(start, max(1, int(runtime_s)))

    def carbon_gco2(
        self, energy_kwh: float, start: datetime | None, runtime_s: float,
        *, cluster: str = "",
    ) -> float:
        return energy_kwh * self.intensity(start, runtime_s, cluster=cluster)

    # -- one-stop record annotation -----------------------------------------

    def annotate(self, record) -> None:
        """Fill a :class:`~repro.accounting.store.JobRecord`'s energy/carbon
        fields in place (keeps a measured ``energy_kwh`` if already set).

        The no-eco counterfactual is only differenced for jobs eco mode
        actually deferred; for everything else it equals the actual carbon,
        so ordinary queue-wait drift never masquerades as an eco saving
        (or penalty). The placement counterfactual is likewise only
        differenced for jobs that actually ran OFF the default cluster."""
        if record.energy_kwh <= 0.0:
            record.energy_kwh = self.energy_kwh(record.cpus, record.runtime_s)
        started = record.started_dt()
        record.carbon_gco2 = self.carbon_gco2(
            record.energy_kwh, started, record.runtime_s,
            cluster=record.cluster,
        )
        if record.eco_deferred:
            requested = record.requested_dt() or started
            record.carbon_nodefer_gco2 = self.carbon_gco2(
                record.energy_kwh, requested, record.runtime_s,
                cluster=record.cluster,
            )
        else:
            record.carbon_nodefer_gco2 = record.carbon_gco2
        if (
            record.cluster
            and self.default_cluster
            and record.cluster != self.default_cluster
        ):
            record.carbon_default_cluster_gco2 = self.carbon_gco2(
                record.energy_kwh, started, record.runtime_s,
                cluster=self.default_cluster,
            )
        else:
            record.carbon_default_cluster_gco2 = record.carbon_gco2
