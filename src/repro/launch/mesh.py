"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256-chip v5e pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1×1 mesh over the local device — smoke tests and the e2e example."""
    return jax.make_mesh((1, 1), ("data", "model"))
