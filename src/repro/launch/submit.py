"""TPU-era launchers: the paper's Kraken2 wrapper pattern, applied to
training/serving jobs.

``Kraken2.build()`` measures the database size at submission time and
inflates the memory request (1.4× + 100 GB) so the job is unlikely to be
OOM-killed. :class:`TrainLauncher` does the same from the *model config*:

    params  = cfg.param_count()
    hbm     ≈ params × (2 bytes weights + 4 bytes grads-fp32 + opt bytes)
    chips   = ceil(hbm × HEADROOM / HBM_PER_CHIP)   (+ host RAM similarly)

so a user types ``nbilaunch train arch=mistral-large-123b`` and the wrapper
derives chip count, host memory and a wall-time estimate — no manual
calculation, exactly the paper's point. Eco mode then defers the whole pod
job to the next low-energy window (checkpoint/restart makes long runs safe
to split across windows — see ``--eco-preempt`` in repro.launch.train).
"""

from __future__ import annotations

import math

from repro.core.engine import BatchResult, SubmitEngine
from repro.core.launcher import InputSpec, Launcher
from repro.core.resources import Opts

HBM_PER_CHIP = 16e9  # TPU v5e
CHIPS_PER_HOST = 4
HOST_RAM_PER_CHIP_GB = 48
HEADROOM = 1.4  # the paper's 40%
FIXED_OVERHEAD_GB = 100  # the paper's fixed overhead, host-side

_OPT_BYTES = {"adamw": 8, "adamw8bit": 4, "lion": 4}


def submit_batch(
    items: list,
    *,
    backend=None,
    coalesce: bool = True,
    eco: bool = False,
    now=None,
) -> BatchResult:
    """Submit a mixed list of ``Job`` / ``Launcher`` items at scale.

    Launchers are materialised via ``to_job()`` (manifests are written with
    their real submitted ids afterwards); everything is routed through one
    :class:`~repro.core.engine.SubmitEngine` call. Plain homogeneous Jobs —
    e.g. a parameter sweep sharing one resource shape — collapse into a
    single SLURM job array; launcher jobs carry per-job manifest preludes
    and instead ride the backend's pipelined ``submit_many``.
    """
    jobs = [it.to_job() if isinstance(it, Launcher) else it for it in items]
    engine = SubmitEngine(backend, coalesce=coalesce, eco=eco, now=now)
    result = engine.submit_many(jobs)
    for job, jid in zip(jobs, result.ids):
        manifest = getattr(job, "_manifest", None)
        if manifest is not None:
            manifest.record["resources"]["begin"] = job.opts.begin
            manifest.write_submitted(jid)
    return result


def train_memory_model(param_count: int, optimizer: str = "adamw") -> dict:
    """Analytic per-run memory & chip sizing (the inflation heuristic)."""
    bytes_per_param = 2 + 4 + _OPT_BYTES.get(optimizer, 8)  # bf16 w + f32 g + opt
    hbm_needed = param_count * bytes_per_param * HEADROOM
    chips = max(1, math.ceil(hbm_needed / HBM_PER_CHIP))
    # round up to a whole pod slice (powers of two look like real slices)
    chips = 1 << max(0, math.ceil(math.log2(chips)))
    hosts = max(1, math.ceil(chips / CHIPS_PER_HOST))
    host_mem_gb = HOST_RAM_PER_CHIP_GB * CHIPS_PER_HOST + FIXED_OVERHEAD_GB
    return {
        "bytes_per_param": bytes_per_param,
        "hbm_needed": hbm_needed,
        "chips": chips,
        "hosts": hosts,
        "host_mem_gb": host_mem_gb,
    }


class TrainLauncher(Launcher):
    """Submit ``python -m repro.launch.train`` with derived resources."""

    tool_name = "train"
    tool_version = "0.1.0"
    activation = ("none", "")
    inputs_spec = [
        InputSpec("arch", required=True, kind="str", help="architecture id"),
    ]
    params_spec = [
        InputSpec("steps", required=False, kind="int", default=100),
        InputSpec("global_batch", required=False, kind="int", default=32),
        InputSpec("seq", required=False, kind="int", default=1024),
        InputSpec("ckpt_dir", required=False, kind="str", default="ckpt"),
        InputSpec("smoke", required=False, kind="int", default=0,
                  help="1 = reduced smoke config"),
    ]

    def default_opts(self) -> Opts:
        return Opts.new(threads=8, memory="32GB", time="12h", gres="")

    def build(self) -> None:
        from repro.configs import get_config

        cfg = get_config(self.inputs["arch"])
        sizing = train_memory_model(cfg.param_count(), cfg.optimizer)
        self.sizing = sizing
        self.opts.memory_mb = max(
            self.opts.memory_mb, int(sizing["host_mem_gb"] * 1024)
        )
        self.opts.nodes = sizing["hosts"]
        self.opts.gres = f"tpu:v5e:{min(CHIPS_PER_HOST, sizing['chips'])}"
        # naive wall-time estimate: 6·N·D at 40% MFU across the derived slice
        steps = int(self.params.get("steps", 100))
        tokens = steps * self.params["global_batch"] * self.params["seq"]
        flops = 6 * cfg.active_param_count() * tokens
        secs = flops / (sizing["chips"] * 197e12 * 0.4)
        self.opts.time_s = max(self.opts.time_s, int(secs * 2) + 600)

    def outputs(self) -> dict:
        return {"checkpoints": f"{self.outdir}/{self.params['ckpt_dir']}"}

    def make_command(self) -> str:
        p = self.params
        cmd = (
            f"python -m repro.launch.train --arch {self.inputs['arch']} "
            f"--steps {p['steps']} --global-batch {p['global_batch']} "
            f"--seq {p['seq']} --ckpt-dir {self.outdir}/{p['ckpt_dir']}"
        )
        if p.get("smoke"):
            cmd += " --smoke"
        if self.sizing["hosts"] > 1:
            # every host runs the same command under srun; topology comes
            # from SLURM env via repro.launch.distributed
            cmd = f"srun --kill-on-bad-exit=1 {cmd}"
        return cmd

    def sbatch_script(self) -> str:
        """Standalone multi-node sbatch (the deploy artifact for big runs)."""
        from repro.launch.distributed import multinode_sbatch

        return multinode_sbatch(
            job_name=f"train-{self.inputs['arch']}",
            hosts=self.sizing["hosts"],
            command=self.make_command().removeprefix("srun --kill-on-bad-exit=1 "),
            time=self.opts.slurm_time,
            partition=self.opts.queue,
            gres=self.opts.gres,
            mem_mb=self.opts.memory_mb,
        )


class ServeLauncher(Launcher):
    """Submit ``python -m repro.launch.serve`` (batched decode service)."""

    tool_name = "serve"
    tool_version = "0.1.0"
    inputs_spec = [
        InputSpec("arch", required=True, kind="str"),
    ]
    params_spec = [
        InputSpec("batch", required=False, kind="int", default=8),
        InputSpec("prompt_len", required=False, kind="int", default=128),
        InputSpec("gen_len", required=False, kind="int", default=64),
        InputSpec("smoke", required=False, kind="int", default=0),
    ]

    def default_opts(self) -> Opts:
        return Opts.new(threads=8, memory="32GB", time="4h")

    def build(self) -> None:
        from repro.configs import get_config

        cfg = get_config(self.inputs["arch"])
        # weights-only inflation (serving: bf16 weights + KV cache + headroom)
        hbm = cfg.param_count() * 2 * HEADROOM
        chips = 1 << max(0, math.ceil(math.log2(max(1, hbm / HBM_PER_CHIP))))
        self.opts.nodes = max(1, math.ceil(chips / CHIPS_PER_HOST))
        self.opts.gres = f"tpu:v5e:{min(CHIPS_PER_HOST, chips)}"
        self.opts.memory_mb = max(
            self.opts.memory_mb,
            int((HOST_RAM_PER_CHIP_GB * CHIPS_PER_HOST + FIXED_OVERHEAD_GB) * 1024),
        )

    def make_command(self) -> str:
        p = self.params
        cmd = (
            f"python -m repro.launch.serve --arch {self.inputs['arch']} "
            f"--batch {p['batch']} --prompt-len {p['prompt_len']} "
            f"--gen-len {p['gen_len']}"
        )
        if p.get("smoke"):
            cmd += " --smoke"
        return cmd
