import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell against ShapeDtypeStruct inputs (no allocation), record
``memory_analysis()`` / ``cost_analysis()`` and the trip-count-aware HLO
stats (FLOPs / HBM bytes / collective wire bytes) for the roofline.

Usage:
  python -m repro.launch.dryrun                        # all cells, both meshes
  python -m repro.launch.dryrun --arch rwkv6-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --out results/dryrun   # JSON per cell

Cells are persisted incrementally; rerunning skips completed cells unless
--force. Exit code is non-zero if any attempted cell fails.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis.hlo import analyze_hlo
from repro.configs import ASSIGNED, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model
from repro.optim import make_optimizer
from repro.parallel.sharding import resolve_tree, rules_for
from repro.training.steps import (
    abstract_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    train_state_logical,
)

# (kind, seq_len, global_batch)
SHAPES = {
    "train_4k": ("train", 4_096, 256),
    "prefill_32k": ("prefill", 32_768, 32),
    "decode_32k": ("decode", 32_768, 128),
    "long_500k": ("decode", 524_288, 1),
}

MESHES = {"single": False, "multi": True}


def plan_cells(archs=None, shapes=None):
    """All (arch, shape) cells incl. assignment-mandated skips."""
    cells = []
    for arch in archs or ASSIGNED:
        cfg = get_config(arch)
        for shape_name, (kind, seq, batch) in SHAPES.items():
            if shapes and shape_name not in shapes:
                continue
            skip = None
            if shape_name == "long_500k" and not cfg.sub_quadratic:
                skip = "full-attention arch: 500k decode excluded by assignment rule"
            if kind == "decode" and not cfg.has_decoder:
                skip = "encoder-only arch has no decode step"
            cells.append(
                {"arch": arch, "shape": shape_name, "kind": kind,
                 "seq": seq, "batch": batch, "skip": skip}
            )
    return cells


def _replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec

    return NamedSharding(mesh, PartitionSpec())


def lower_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    kind, seq, batch = SHAPES[shape_name]
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    rules = rules_for(
        cfg, mesh,
        param_defs=model.param_defs,
        batch_size=batch,
        extra_dims={"kv_seq": seq, "heads": cfg.n_heads, "seq": seq},
        fsdp=cfg.fsdp and kind == "train",  # ZeRO-3 is a training-path rule
    )

    if kind == "train":
        optimizer = make_optimizer(cfg.optimizer)
        state = abstract_train_state(model, optimizer)
        state_sh = resolve_tree(mesh, train_state_logical(model, optimizer), rules)
        batch_abs = model.train_inputs(batch, seq)
        batch_sh = resolve_tree(mesh, model.train_input_logical(), rules)
        step = make_train_step(model, optimizer, rules, mesh)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state, batch_abs)
    elif kind == "prefill":
        params = model.abstract_params()
        params_sh = resolve_tree(mesh, model.param_logical(), rules)
        batch_abs = model.prefill_inputs(batch, seq)
        batch_sh = resolve_tree(mesh, model.prefill_input_logical(), rules)
        step = make_prefill_step(model, rules, mesh)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(params_sh, batch_sh)
            ).lower(params, batch_abs)
    else:  # decode
        params = model.abstract_params()
        params_sh = resolve_tree(mesh, model.param_logical(), rules)
        cache = model.cache_defs_fn(batch, seq)
        cache_sh = resolve_tree(mesh, model.cache_logical_fn(), rules)
        toks = model.decode_inputs(batch)
        step = make_serve_step(model, rules, mesh)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, None, None),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(params, cache, toks["tokens"], toks["pos"])

    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        try:
            mem_rec[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo)

    pv = model.cfg.vocab_size  # padded
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "seq": seq,
        "batch": batch,
        "compile_s": round(compile_s, 1),
        "rules": {k: (list(v) if isinstance(v, tuple) else v) for k, v in rules.items()},
        "memory_analysis": mem_rec,
        "cost_analysis": {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed", "optimal_seconds")
        },
        "hlo_flops_per_device": stats.flops,
        "hlo_hbm_bytes_per_device": stats.hbm_bytes,
        "collective_wire_bytes_per_device": stats.collective_wire_bytes,
        "collective_by_type": stats.collective_by_type,
        "collective_count": stats.collective_count,
        "while_trip_counts": stats.while_trip_counts[:32],
        "analysis_notes": stats.notes[:8],
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "padded_vocab": pv,
    }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", help="arch id (repeatable)")
    ap.add_argument("--shape", action="append", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = plan_cells(args.arch, args.shape)
    failures = 0
    for cell in cells:
        for mesh_name in meshes:
            tag = f"{cell['arch']}__{cell['shape']}__{mesh_name}"
            path = outdir / f"{tag}.json"
            if cell["skip"]:
                path.write_text(json.dumps({**cell, "mesh": mesh_name, "status": "skipped",
                                            "skip_reason": cell["skip"]}, indent=2))
                print(f"[skip] {tag}: {cell['skip']}")
                continue
            if path.exists() and not args.force:
                try:
                    prev = json.loads(path.read_text())
                    if prev.get("status") == "ok":
                        print(f"[cached] {tag}")
                        continue
                except Exception:
                    pass
            print(f"[lower] {tag} ...", flush=True)
            try:
                rec = lower_cell(cell["arch"], cell["shape"], MESHES[mesh_name])
                rec["status"] = "ok"
                path.write_text(json.dumps(rec, indent=2))
                print(
                    f"[ok] {tag} compile={rec['compile_s']}s "
                    f"flops/dev={rec['hlo_flops_per_device']:.3e} "
                    f"wire/dev={rec['collective_wire_bytes_per_device']:.3e}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                path.write_text(json.dumps({**cell, "mesh": mesh_name, "status": "error",
                                            "error": f"{type(e).__name__}: {e}",
                                            "traceback": traceback.format_exc()[-4000:]},
                                           indent=2))
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
    print(f"done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
