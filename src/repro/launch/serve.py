"""serve — batched decode service driver.

    python -m repro.launch.serve --arch nbi-100m --smoke --batch 4 \
        --prompt-len 32 --gen-len 16

Implements the inference side of the framework: a :class:`ServeEngine`
that prefills a batch of prompts, pads the prompt-sized KV cache into the
fixed-capacity decode cache, then runs the jit'd single-token decode step
in a loop (greedy or temperature sampling). A tiny dynamic batcher groups
queued requests into engine-sized batches (left-aligned, right-padded)
so the expensive compiled shapes stay fixed — the vLLM-style idiom of
"compile once per (batch, max_seq), feed many requests".

On a pod this runs under ``nbilaunch serve arch=...`` with the KV cache
sequence dim sharded over the ``model`` mesh axis (flash-decoding split-KV,
see DESIGN.md); on CPU the smoke config serves real tokens.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.parallel.sharding import resolve_tree, rules_for
from repro.training.steps import make_prefill_step, make_serve_step


def pad_cache_to(cache, cache_defs):
    """Zero-pad a prompt-sized prefill cache into the fixed decode layout.

    Leaves match rank; any axis where the prefill extent is smaller (the
    kv-seq axis) is right-padded. Zero padding is safe: decode masks by
    position, and recurrent states (rwkv/rglru) match shape exactly.
    """
    def pad(leaf, want):
        target = want.shape
        if tuple(leaf.shape) == tuple(target):
            return leaf.astype(want.dtype)
        pads = []
        for have, need in zip(leaf.shape, target):
            if have > need:
                raise ValueError(f"cache leaf {leaf.shape} exceeds {target}")
            pads.append((0, need - have))
        return jnp.pad(leaf, pads).astype(want.dtype)

    return jax.tree_util.tree_map(pad, cache, cache_defs)


class ServeEngine:
    """Fixed-shape batched generation over one model."""

    def __init__(self, cfg, *, batch: int, max_seq: int, mesh=None, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self.mesh = mesh or make_host_mesh()
        self.model = build_model(cfg)
        rules = rules_for(
            cfg, self.mesh,
            param_defs=self.model.param_defs,
            batch_size=batch,
            extra_dims={"kv_seq": max_seq, "heads": cfg.n_heads},
        )
        self.rules = rules
        with self.mesh:
            self.params = self.model.init(jax.random.PRNGKey(seed))
        self._prefill = jax.jit(make_prefill_step(self.model, rules, self.mesh))
        self._decode = jax.jit(make_serve_step(self.model, rules, self.mesh))
        self.stats = {"requests": 0, "prefill_tokens": 0, "decode_tokens": 0,
                      "prefill_s": 0.0, "decode_s": 0.0}

    # -- one fixed-shape batch ------------------------------------------------

    def generate_batch(
        self, prompts: np.ndarray, gen_len: int, *,
        temperature: float = 0.0, eos_id: int | None = None, rng=None,
    ) -> np.ndarray:
        """prompts: (batch, prompt_len) int32 → (batch, gen_len) int32."""
        B, P = prompts.shape
        assert B == self.batch, (B, self.batch)
        assert P + gen_len <= self.max_seq, "exceeds engine capacity"
        cache_defs = self.model.cache_defs_fn(B, self.max_seq)
        t0 = time.perf_counter()
        with self.mesh:
            batch_in = {"tokens": jnp.asarray(prompts, jnp.int32)}
            if self.cfg.family == "encdec":
                batch_in["frames"] = jnp.zeros(
                    (B, self.cfg.enc_len, self.cfg.d_model), self.cfg.dtype
                )
            logits, cache = self._prefill(self.params, batch_in)
            cache = pad_cache_to(cache, cache_defs)
            jax.block_until_ready(logits)
            t1 = time.perf_counter()

            out = np.zeros((B, gen_len), np.int32)
            finished = np.zeros((B,), bool)
            rng = rng or jax.random.PRNGKey(0)
            tok = self._sample(logits[:, -1], temperature, rng)
            for i in range(gen_len):
                out[:, i] = np.where(finished, eos_id or 0, np.asarray(tok))
                if eos_id is not None:
                    finished |= out[:, i] == eos_id
                    if finished.all():
                        out = out[:, : i + 1]
                        break
                pos = jnp.asarray(P + i, jnp.int32)
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(out[:, i : i + 1]), pos
                )
                rng, sub = jax.random.split(rng)
                tok = self._sample(logits[:, -1], temperature, sub)
            jax.block_until_ready(logits)
        t2 = time.perf_counter()
        self.stats["requests"] += B
        self.stats["prefill_tokens"] += B * P
        self.stats["decode_tokens"] += B * out.shape[1]
        self.stats["prefill_s"] += t1 - t0
        self.stats["decode_s"] += t2 - t1
        return out

    @staticmethod
    def _sample(logits, temperature, rng):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / temperature, axis=-1)

    # -- dynamic batcher ----------------------------------------------------------

    def serve_requests(
        self, requests: list[np.ndarray], gen_len: int, *,
        temperature: float = 0.0,
    ) -> list[np.ndarray]:
        """Group variable-length requests into fixed engine batches.

        Requests are bucketed by *exact prompt length* (rows in one batch
        never see padding tokens, so a request's output is independent of
        its batch-mates — asserted by the serving tests). Short buckets are
        filled up to the engine batch by repeating the first row; filler
        rows are discarded. Responses return in input order.
        """
        results: list = [None] * len(requests)
        buckets: dict[int, list[int]] = {}
        for i, r in enumerate(requests):
            buckets.setdefault(len(r), []).append(i)
        for length, idxs in sorted(buckets.items()):
            for g in range(0, len(idxs), self.batch):
                group = idxs[g : g + self.batch]
                block = np.empty((self.batch, length), np.int32)
                for row in range(self.batch):
                    src = group[row] if row < len(group) else group[0]  # filler
                    block[row] = requests[src]
                out = self.generate_batch(block, gen_len, temperature=temperature)
                for row, i in enumerate(group):
                    results[i] = out[row]
        return results


class ContinuousBatchingEngine:
    """Slot-based continuous batching (the vLLM idiom, shapes held fixed).

    A fixed pool of ``batch`` decode slots advances every step with
    *per-slot positions* (the vector-``pos`` decode path); when a request
    finishes, the next queued request is prefilled (single-row, exact
    length) and written into the free slot's cache rows while the other
    slots keep decoding — no generation stalls on batch-mates, unlike
    static batching where the whole batch waits for its slowest member.

    Restricted to families whose decode is row-independent (dense GQA/MLA;
    MoE routing couples rows through capacity and is excluded).
    """

    def __init__(self, cfg, *, batch: int, max_seq: int, mesh=None, seed: int = 0):
        assert cfg.family in ("dense",), "continuous batching: dense families"
        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self.mesh = mesh or make_host_mesh()
        self.model = build_model(cfg)
        rules = rules_for(
            cfg, self.mesh, param_defs=self.model.param_defs, batch_size=batch,
            extra_dims={"kv_seq": max_seq, "heads": cfg.n_heads},
        )
        with self.mesh:
            self.params = self.model.init(jax.random.PRNGKey(seed))
        self._prefill = jax.jit(make_prefill_step(self.model, rules, self.mesh))
        self._decode = jax.jit(make_serve_step(self.model, rules, self.mesh))
        self.stats = {"requests": 0, "decode_steps": 0, "slot_tokens": 0,
                      "occupancy_sum": 0.0}

    def _insert(self, cache, slot: int, prompt: np.ndarray):
        """Prefill one request and write its rows into ``slot``. Returns
        (cache, first generated token)."""
        toks = jnp.asarray(prompt[None, :], jnp.int32)
        logits, row_cache = self._prefill(self.params, {"tokens": toks})
        row_cache = pad_cache_to(
            row_cache, self.model.cache_defs_fn(1, self.max_seq)
        )
        cache = jax.tree_util.tree_map(
            lambda full, one: full.at[:, slot].set(one[:, 0]), cache, row_cache
        )
        return cache, int(jnp.argmax(logits[0, -1]))

    def serve(self, requests: list, gen_len: int) -> list:
        """Greedy-decode every request; returns outputs in input order."""
        B = self.batch
        cache = jax.tree_util.tree_map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            self.model.cache_defs_fn(B, self.max_seq),
        )
        queue = list(range(len(requests)))
        outputs: list = [[] for _ in requests]
        slot_req = [-1] * B  # which request occupies each slot
        pos = np.zeros(B, np.int64)  # next write position per slot
        cur_tok = np.zeros(B, np.int64)

        def fill_free_slots(cache):
            for b in range(B):
                if slot_req[b] == -1 and queue:
                    i = queue.pop(0)
                    prompt = requests[i]
                    assert len(prompt) + gen_len <= self.max_seq
                    cache, tok = self._insert(cache, b, prompt)
                    slot_req[b] = i
                    pos[b] = len(prompt)
                    cur_tok[b] = tok
                    outputs[i].append(tok)
                    self.stats["requests"] += 1
            return cache

        with self.mesh:
            cache = fill_free_slots(cache)
            while any(s != -1 for s in slot_req):
                active = np.array([s != -1 for s in slot_req])
                self.stats["occupancy_sum"] += active.mean()
                self.stats["decode_steps"] += 1
                logits, cache = self._decode(
                    self.params, cache,
                    jnp.asarray(cur_tok[:, None], jnp.int32),
                    jnp.asarray(pos, jnp.int32),
                )
                nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
                for b in range(B):
                    if slot_req[b] == -1:
                        continue
                    i = slot_req[b]
                    self.stats["slot_tokens"] += 1
                    if len(outputs[i]) < gen_len:
                        outputs[i].append(int(nxt[b]))
                        cur_tok[b] = nxt[b]
                        pos[b] += 1
                    if len(outputs[i]) >= gen_len:
                        slot_req[b] = -1  # request done → slot free
                        pos[b] = 0
                        cur_tok[b] = 0
                cache = fill_free_slots(cache)
        return [np.asarray(o, np.int32) for o in outputs]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    engine = ServeEngine(
        cfg,
        batch=args.batch,
        max_seq=args.prompt_len + args.gen_len,
        seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    requests = [
        rng.integers(0, cfg.vocab_size, size=rng.integers(4, args.prompt_len + 1))
        .astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    outs = engine.serve_requests(requests, args.gen_len, temperature=args.temperature)
    dt = time.perf_counter() - t0
    for i, o in enumerate(outs[: 4]):
        print(f"[serve] req{i}: prompt_len={len(requests[i])} -> {o[:8].tolist()}...")
    s = engine.stats
    print(
        f"[serve] {len(requests)} requests in {dt:.2f}s | "
        f"prefill {s['prefill_tokens'] / max(s['prefill_s'], 1e-9):.0f} tok/s | "
        f"decode {s['decode_tokens'] / max(s['decode_s'], 1e-9):.0f} tok/s"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
