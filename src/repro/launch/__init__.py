"""Launch layer: meshes, dry-run, drivers, multi-host bootstrap, launchers."""
