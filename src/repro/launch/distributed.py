"""Multi-host process bootstrap: SLURM env → jax.distributed.

On a real cluster every host runs the same ``python -m repro.launch.train``
under ``srun``; this module derives the coordinator/process topology from
SLURM's environment (no extra config system):

    SLURM_JOB_NODELIST   → coordinator host (first entry, expanded)
    SLURM_NTASKS         → process count
    SLURM_PROCID         → process index
    SLURM_JOB_ID         → coordinator port (stable per job, 20000-29999)

``maybe_initialize()`` is a no-op outside SLURM (single-process dev loop) and
under ``REPRO_DISABLE_DISTRIBUTED=1`` (unit tests). Returns (process_index,
process_count) either way, so the data pipeline's host sharding can always be
derived from it.
"""

from __future__ import annotations

import os
import re


def _expand_first_host(nodelist: str) -> str:
    """First hostname of a SLURM nodelist (handles "n[001-004,007],m01")."""
    m = re.match(r"^([^,\[]+)(\[([^\]]+)\])?", nodelist.strip())
    if not m:
        return nodelist.strip()
    prefix, _, ranges = m.groups()
    if not ranges:
        return prefix
    first = ranges.split(",")[0].split("-")[0]
    return f"{prefix}{first}"


def coordinator_address() -> "str | None":
    nodelist = os.environ.get("SLURM_JOB_NODELIST", "")
    if not nodelist:
        return None
    host = _expand_first_host(nodelist)
    port = 20000 + int(os.environ.get("SLURM_JOB_ID", "0")) % 10000
    return f"{host}:{port}"


def slurm_topology() -> "tuple[int, int] | None":
    """(process_index, process_count) from SLURM env, or None."""
    try:
        n = int(os.environ["SLURM_NTASKS"])
        i = int(os.environ["SLURM_PROCID"])
    except (KeyError, ValueError):
        return None
    return (i, n) if n > 1 else None


def maybe_initialize() -> "tuple[int, int]":
    """Initialize jax.distributed when launched as a multi-task SLURM job."""
    if os.environ.get("REPRO_DISABLE_DISTRIBUTED") == "1":
        return 0, 1
    topo = slurm_topology()
    if topo is None:
        return 0, 1
    index, count = topo
    import jax

    jax.distributed.initialize(
        coordinator_address=coordinator_address(),
        num_processes=count,
        process_id=index,
    )
    return index, count


def multinode_sbatch(
    *, job_name: str, hosts: int, tasks_per_host: int = 1,
    command: str, time: str = "1-00:00:00", partition: str = "",
    gres: str = "tpu:v5e:4", mem_mb: int = 300_000, logdir: str = "logs",
) -> str:
    """A complete multi-host sbatch script: one srun task per host, each
    running the SAME command; repro.launch.distributed picks up the topology.
    Used by TrainLauncher when the derived host count exceeds 1."""
    lines = [
        "#!/bin/bash",
        f"#SBATCH --job-name={job_name}",
        f"#SBATCH --nodes={hosts}",
        f"#SBATCH --ntasks={hosts * tasks_per_host}",
        f"#SBATCH --ntasks-per-node={tasks_per_host}",
        f"#SBATCH --mem={mem_mb}",
        f"#SBATCH --time={time}",
        f"#SBATCH --output={logdir}/{job_name}.%j.out",
        f"#SBATCH --error={logdir}/{job_name}.%j.err",
        "#SBATCH --requeue",
    ]
    if partition:
        lines.insert(2, f"#SBATCH --partition={partition}")
    if gres:
        lines.append(f"#SBATCH --gres={gres}")
    lines += [
        "",
        "set -euo pipefail",
        f"mkdir -p {logdir}",
        "# every task runs the same command; topology comes from SLURM env",
        f"srun --kill-on-bad-exit=1 {command}",
    ]
    return "\n".join(lines) + "\n"
