"""train — the end-to-end training driver.

    python -m repro.launch.train --arch nbi-100m --steps 300 \
        --global-batch 16 --seq 512 --ckpt-dir ckpt/nbi100m

Assembles the full stack: config → model → mesh/sharding rules → optimizer →
data pipeline → jit'd train step → checkpoint manager, with:

* **restart safety** — on start, the latest checkpoint (weights, optimizer,
  data cursor, RNG) is restored if present; a SIGTERM/SIGINT triggers a
  final synchronous save, so preemption loses at most the steps since the
  last periodic save;
* **eco-preemption** (beyond-paper, built on the paper's EcoScheduler) —
  with ``--eco-preempt``, the loop checkpoints and exits cleanly at the
  next peak-hours boundary, printing the ``--begin`` directive for the
  next eco window so the wrapper can resubmit the remainder of the run;
* **throughput accounting** — tokens/s and an analytic MFU estimate
  against the local device's peak (the real MFU story lives in the
  dry-run roofline; this is the live-run counterpart).

On the CPU container this is exercised with ``--smoke`` configs and the
``examples/train_100m.py`` driver; on a real pod the same file runs under
``nbilaunch train arch=...`` with the mesh from repro.launch.mesh.
"""

from __future__ import annotations

import argparse
import signal
import time
from datetime import datetime
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core.eco import EcoScheduler
from repro.data import make_train_loader
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim import make_optimizer
from repro.optim.schedules import cosine_warmup
from repro.parallel.sharding import resolve_tree, rules_for
from repro.training.steps import (
    init_train_state,
    make_train_step,
    train_state_logical,
)


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.launch.train")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--host-index", type=int, default=0)
    ap.add_argument("--host-count", type=int, default=1)
    ap.add_argument("--eco-preempt", action="store_true",
                    help="checkpoint + exit at the next peak-hours boundary")
    ap.add_argument("--now", default=None, help=argparse.SUPPRESS)  # tests
    return ap


def train(args, *, mesh=None, on_metrics=None) -> dict:
    # multi-host: under a multi-task SLURM job, join the jax.distributed
    # cluster and derive this host's data shard; no-op in single-process runs
    from repro.launch.distributed import maybe_initialize

    proc_index, proc_count = maybe_initialize()
    if proc_count > 1 and args.host_count == 1:
        args.host_index, args.host_count = proc_index, proc_count

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = mesh or make_host_mesh()

    optimizer = make_optimizer(
        cfg.optimizer, lr=cosine_warmup(args.lr, args.warmup, max(args.steps, 1))
    )
    rules = rules_for(
        cfg, mesh, param_defs=model.param_defs, batch_size=args.global_batch,
        extra_dims={"heads": cfg.n_heads},
    )
    state_sh = resolve_tree(mesh, train_state_logical(model, optimizer), rules)
    step_fn = jax.jit(
        make_train_step(model, optimizer, rules, mesh),
        in_shardings=(state_sh, None),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )

    # ---- state: fresh init or checkpoint restore --------------------------
    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    data_cursor = 0
    with mesh:
        state = init_train_state(model, optimizer, jax.random.PRNGKey(args.seed))
    if manager and manager.latest_step() is not None:
        state, extra, start_step = manager.restore(state, shardings=state_sh)
        data_cursor = int(extra.get("data_cursor", start_step))
        print(f"[train] resumed from step {start_step}")

    loader = make_train_loader(
        model.cfg.vocab_size,
        args.global_batch,
        args.seq,
        seed=args.seed,
        host_index=args.host_index,
        host_count=args.host_count,
        start=data_cursor,
    )

    # ---- eco-preemption & signal handling ----------------------------------
    # ``--now`` (tests/examples) sets a *virtual clock start*: simulated time
    # advances with real elapsed time from that instant.
    wall_t0 = time.monotonic()
    virtual_start = datetime.fromisoformat(args.now) if args.now else None

    def clock() -> datetime:
        if virtual_start is None:
            return datetime.now()
        from datetime import timedelta

        return virtual_start + timedelta(seconds=time.monotonic() - wall_t0)

    eco_deadline = None
    sched = None
    if args.eco_preempt:
        sched = EcoScheduler()
        eco_deadline = sched.next_peak_start(clock())
        if eco_deadline:
            print(f"[eco] will checkpoint+exit at peak boundary {eco_deadline}")

    stop = {"reason": None}

    def _sig(signum, _frame):
        stop["reason"] = f"signal {signum}"

    old_handlers = {}
    for s in (signal.SIGINT, signal.SIGTERM):
        try:
            old_handlers[s] = signal.signal(s, _sig)
        except ValueError:
            pass  # not the main thread (tests)

    # ---- loop ---------------------------------------------------------------
    metrics_hist = []
    t_start = time.perf_counter()
    tokens_per_step = args.global_batch * args.seq
    step = start_step
    steps_done = start_step  # steps whose update actually applied
    try:
        with mesh:
            for step in range(start_step, args.steps):
                batch_np = next(loader)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                state, metrics = step_fn(state, batch)
                steps_done = step + 1
                if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
                    m = {k: float(v) for k, v in metrics.items()}
                    dt = time.perf_counter() - t_start
                    done = step + 1 - start_step
                    m.update(step=step + 1, tokens_per_s=tokens_per_step * done / dt)
                    metrics_hist.append(m)
                    if on_metrics:
                        on_metrics(m)
                    print(
                        f"[train] step {step + 1}/{args.steps} "
                        f"loss={m['loss']:.4f} acc={m.get('accuracy', 0):.3f} "
                        f"tok/s={m['tokens_per_s']:.0f}",
                        flush=True,
                    )
                if manager and (step + 1) % args.ckpt_every == 0:
                    manager.save(
                        step + 1, state,
                        extra={"data_cursor": loader.state_dict()["cursor"],
                               "arch": args.arch},
                        blocking=False,
                    )
                if stop["reason"]:
                    break
                if eco_deadline and clock() >= eco_deadline:
                    stop["reason"] = "eco-preempt"
                    break
    finally:
        for s, h in old_handlers.items():
            signal.signal(s, h)
        loader.close()

    completed = steps_done
    result = {
        "completed_steps": completed,
        "stopped": stop["reason"],
        "metrics": metrics_hist,
        "final_loss": metrics_hist[-1]["loss"] if metrics_hist else None,
    }

    if manager and (stop["reason"] or args.steps > start_step):
        manager.save(
            completed, state,
            extra={"data_cursor": loader.state_dict()["cursor"], "arch": args.arch,
                   "stopped": stop["reason"]},
            blocking=True,
        )
    if stop["reason"] == "eco-preempt" and sched is not None:
        remaining_s = 3600  # conservative: at least an hour of work left
        directive = sched.begin_directive(remaining_s, clock())
        result["resubmit_begin"] = directive
        print(f"[eco] resubmit with --begin={directive}")
    return result


def main(argv=None) -> int:
    args = build_argparser().parse_args(argv)
    result = train(args)
    if result["final_loss"] is not None:
        print(f"[train] done: steps={result['completed_steps']} "
              f"final_loss={result['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
