"""Trip-count-aware HLO accounting for the roofline analysis.

``jax.stages.Compiled.cost_analysis()`` visits each ``while`` body ONCE —
for scan-over-layers models that undercounts FLOPs by the layer count
(verified empirically; see EXPERIMENTS.md §Roofline/Method). This module
parses the *optimized, partitioned* HLO text instead:

* splits the module into computations and builds a name → shape symbol
  table (operands are name references in optimized HLO),
* recovers each ``while`` trip count from its
  ``backend_config={"known_trip_count":{"n":...}}`` (falls back to the
  condition's compare-against-constant),
* multiplies nested body costs by trip counts,
* FLOPs: ``dot`` ops — 2 × result_elems × contracted_extent (elementwise
  FLOPs ignored; sub-% for these models),
* HBM bytes: operand + result bytes at op/fusion boundaries (fusion
  internals excluded — they live in registers/VMEM),
* collective "wire bytes" per participant with ring formulas:
    all-reduce 2·s·(N−1)/N · all-gather r·(N−1)/N · reduce-scatter r·(N−1)
    all-to-all s·(N−1)/N · collective-permute s

Shapes in partitioned HLO are per-device, so all quantities are per-chip.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w\.\-]+),?\s+body=%?([\w\.\-]+)")
_TRIP_BC_RE = re.compile(r'known_trip_count.*?"n"\s*:\s*"(\d+)"')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_REPL_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_FREE_OPS = (
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id",
)


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_by_type: dict = dataclasses.field(default_factory=dict)
    collective_count: float = 0.0
    while_trip_counts: list = dataclasses.field(default_factory=list)
    notes: list = dataclasses.field(default_factory=list)
    # op-kind → accumulated hbm bytes (trip-scaled); for §Perf diagnosis
    bytes_by_op: dict = dataclasses.field(default_factory=dict)
    flops_by_op: dict = dataclasses.field(default_factory=dict)
    # f32 attention score-tile traffic (elementwise fusions whose result is a
    # (…, qb, kv_chunk) tile). The Pallas flash kernel keeps these tiles in
    # VMEM, so the kernel-path memory term subtracts them (dot-boundary
    # streaming of q/k/v/acc stays counted — that is real HBM traffic both
    # ways). See §Roofline/Method in EXPERIMENTS.md.
    attn_tile_bytes: float = 0.0

    def merge_scaled(self, other: "HloStats", k: float) -> None:
        self.flops += k * other.flops
        self.hbm_bytes += k * other.hbm_bytes
        self.collective_wire_bytes += k * other.collective_wire_bytes
        for t, v in other.collective_by_type.items():
            self.collective_by_type[t] = self.collective_by_type.get(t, 0.0) + k * v
        self.collective_count += k * other.collective_count
        self.attn_tile_bytes += k * other.attn_tile_bytes
        self.while_trip_counts.extend(other.while_trip_counts)
        for t, v in other.bytes_by_op.items():
            self.bytes_by_op[t] = self.bytes_by_op.get(t, 0.0) + k * v
        for t, v in other.flops_by_op.items():
            self.flops_by_op[t] = self.flops_by_op.get(t, 0.0) + k * v

    def top_bytes(self, n: int = 12) -> list:
        return sorted(self.bytes_by_op.items(), key=lambda kv: -kv[1])[:n]


def _shape_list_bytes(text: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_elems(text: str) -> float:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0.0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return float(n)


def _split(line: str):
    """(name, result_text, body_text) for an instruction line."""
    nm = _NAME_RE.match(line)
    if not nm:
        return None, "", line
    rest = line.split("=", 1)[1]
    # result shapes run until the op token; op token = first bare word
    # followed by '(' that is not a shape. Split at the op-name boundary:
    m = re.search(r"\s([a-z][\w\-]*)\(", rest)
    if m:
        return nm.group(1), rest[: m.start()], rest[m.start() :]
    return nm.group(1), rest, rest


class _Module:
    def __init__(self, hlo: str):
        self.comps: dict[str, list[str]] = {}
        self.shapes: dict[str, str] = {}  # op name → result-shape text
        current = None
        for raw in hlo.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if s.endswith("{") and (") -> " in s or s.startswith("ENTRY")):
                name = s.split("(", 1)[0].replace("ENTRY", "").strip().lstrip("%").strip()
                current = name
                self.comps[current] = []
                self.entry = name if s.lstrip().startswith("ENTRY") else getattr(self, "entry", None)
                continue
            if s.startswith("}"):
                current = None
                continue
            if current is not None and "=" in s and s.startswith(("%", "ROOT")):
                self.comps[current].append(s)
                name, result, _ = _split(s)
                if name:
                    self.shapes[name] = result
        # parameters: "%param_0.1 = f32[...] parameter(0)" already covered.

    def operand_bytes(self, body: str) -> float:
        total = 0.0
        inner = body[body.find("(") + 1 :]
        for name in _OPERAND_RE.findall(inner.split("), ")[0] if "), " in inner else inner):
            total += _shape_list_bytes(self.shapes.get(name, ""))
        return total

    def operand_names(self, body: str) -> list:
        inner = body[body.find("(") + 1 :]
        return _OPERAND_RE.findall(inner.split("), ")[0] if "), " in inner else inner)

    def fusion_traffic_bytes(self, result_text: str, body: str) -> float:
        """Realistic HBM traffic (reads + writes) for one fusion.

        Two scan-over-layers corrections, both measured to dominate the
        naive boundary count on deep stacked models (88-layer mistral:
        4.7e14 → ~1e13 bytes/step/device, a ~40× fix):

        * a parameter whose only in-fusion consumers are ``dynamic-slice``
          ops reads only the slices (one layer of the (L, ...) stack), not
          the stack;
        * a ``dynamic-update-slice`` whose target is a parameter is an
          in-place write of the update region (XLA aliases the buffer) —
          the (L, ...) accumulator is neither fully read nor fully written
          per trip; the fusion result charges update bytes, not stack bytes.
        """
        cm = _CALLS_RE.search(body)
        names = self.operand_names(body.split("calls=")[0])
        full_result = _shape_list_bytes(result_text)
        if not cm or cm.group(1) not in self.comps:
            return full_result + sum(
                _shape_list_bytes(self.shapes.get(n, "")) for n in names
            )
        lines = self.comps[cm.group(1)]
        parsed = [(nm, res, bd) for nm, res, bd in map(_split, lines) if nm]
        op_of = {
            nm: (re.match(r"\s*([a-z][\w\-]*)\(", bd) or [None, ""])[1]
            for nm, _, bd in parsed
        }
        operands_of = {
            nm: _OPERAND_RE.findall(bd[bd.find("(") + 1 :]) for nm, _, bd in parsed
        }
        result_of = {nm: res for nm, res, _ in parsed}
        param_of: dict[int, str] = {}
        dus_updates: dict[str, float] = {}  # DUS name → update bytes
        root_name = ""
        for nm, res, bd in parsed:
            pm = re.search(r"parameter\((\d+)\)", bd)
            if pm:
                param_of[int(pm.group(1))] = nm
            if op_of[nm] == "dynamic-update-slice" and len(operands_of[nm]) >= 2:
                dus_updates[nm] = _shape_list_bytes(
                    self.shapes.get(operands_of[nm][1], "")
                )
        for ln in lines:
            if ln.lstrip().startswith("ROOT"):
                root_name = _split(ln)[0]

        # dtype/layout transforms XLA-TPU folds into the surrounding access —
        # a convert/copy of the stack never round-trips HBM on the target.
        _ALIAS_OPS = ("convert", "bitcast", "copy", "reshape")

        def alias_set(seed: str) -> set:
            out = {seed}
            grew = True
            while grew:
                grew = False
                for nm in op_of:
                    if nm in out or op_of[nm] not in _ALIAS_OPS:
                        continue
                    if any(o in out for o in operands_of[nm]):
                        out.add(nm)
                        grew = True
            return out

        # ---- reads -------------------------------------------------------
        total = 0.0
        for idx, opname in enumerate(names):
            full = _shape_list_bytes(self.shapes.get(opname, ""))
            local = param_of.get(idx)
            if local is None:
                total += full
                continue
            aliases = alias_set(local)
            charged = 0.0
            only_cheap = True
            used = False
            for nm in op_of:
                if nm in aliases:
                    continue
                hit = [o for o in operands_of[nm] if o in aliases]
                if not hit:
                    continue
                used = True
                if op_of[nm] == "dynamic-slice":
                    charged += _shape_list_bytes(result_of.get(nm, ""))
                elif (
                    op_of[nm] == "dynamic-update-slice"
                    and operands_of[nm]
                    and operands_of[nm][0] in aliases
                    and all(h == operands_of[nm][0] for h in hit)
                ):
                    charged += 0.0  # in-place target: stack not re-read
                else:
                    only_cheap = False
                    break
            if not used:
                continue
            total += charged if only_cheap else full

        # ---- writes ------------------------------------------------------
        def resolve_write(nm: str) -> float:
            seen = set()
            while nm in op_of and op_of[nm] in _ALIAS_OPS and nm not in seen:
                seen.add(nm)
                ops = operands_of[nm]
                if not ops:
                    break
                nm = ops[0]
            if nm in dus_updates:
                return dus_updates[nm]
            return _shape_list_bytes(
                result_of.get(nm, "")
            ) or full_result

        if root_name and op_of.get(root_name) == "tuple":
            for el in operands_of[root_name]:
                total += resolve_write(el)
        elif root_name:
            total += resolve_write(root_name)
        else:
            total += full_result
        return total

    def lhs_shape_dims(self, body: str) -> list[int]:
        inner = body[body.find("(") + 1 :]
        ops = _OPERAND_RE.findall(inner)
        if not ops:
            return []
        m = _SHAPE_RE.search(self.shapes.get(ops[0], ""))
        if not m or not m.group(2):
            return []
        return [int(d) for d in m.group(2).split(",")]


def _group_size(line: str) -> int:
    m = _REPL_GROUPS_V2_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _REPL_GROUPS_RE.search(line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip() != ""]))
    return 2


def analyze_hlo(hlo_text: str, tile_dims: "tuple | None" = None) -> HloStats:
    """``tile_dims=(qb, kv_chunk)``: classify f32 fusions whose result's two
    trailing dims equal the attention tile as score-tile traffic (VMEM-
    resident under the Pallas kernel)."""
    mod = _Module(hlo_text)
    memo: dict[str, HloStats] = {}

    def is_tile(result_text: str) -> bool:
        if tile_dims is None:
            return False
        m = _SHAPE_RE.search(result_text)
        if not m or m.group(1) != "f32" or not m.group(2):
            return False
        dims = [int(d) for d in m.group(2).split(",")]
        return len(dims) >= 2 and tuple(dims[-2:]) == tuple(tile_dims)

    def dot_flops(result: str, body: str) -> float:
        out = 2.0 * _first_shape_elems(result)
        m = _CONTRACT_RE.search(body)
        if not m:
            return out
        lhs = mod.lhs_shape_dims(body)
        contracted = 1
        for c in (int(x) for x in m.group(1).split(",") if x != ""):
            if c < len(lhs):
                contracted *= lhs[c]
        return out * contracted

    def collective_wire(result: str, body: str, kind: str) -> float:
        n = _group_size(body)
        size = _shape_list_bytes(result)
        if kind == "all-gather":
            return size * (n - 1) / n
        if kind == "reduce-scatter":
            return size * (n - 1)
        if kind == "all-reduce":
            return 2.0 * size * (n - 1) / n
        if kind == "all-to-all":
            return size * (n - 1) / n
        return size  # collective-permute

    def cost(comp: str, seen=()) -> HloStats:
        if comp in memo:
            return memo[comp]
        if comp in seen or comp not in mod.comps:
            return HloStats()
        st = HloStats()
        for line in mod.comps[comp]:
            name, result, body = _split(line)
            opm = re.match(r"\s*([a-z][\w\-]*)\(", body)
            op = opm.group(1) if opm else ""
            if op in _FREE_OPS:
                continue
            if op == "while":
                wm = _WHILE_ATTR_RE.search(body)
                trips = 1
                tm = _TRIP_BC_RE.search(line)
                if tm:
                    trips = int(tm.group(1))
                elif wm:
                    consts = []
                    for cl in mod.comps.get(wm.group(1), []):
                        cm = _CONST_RE.search(cl)
                        if cm:
                            consts.append(int(cm.group(1)))
                    trips = max(consts) if consts else 1
                if wm:
                    inner = cost(wm.group(2), seen + (comp,))
                    st.merge_scaled(inner, trips)
                st.while_trip_counts.append(trips)
                continue
            if op in ("conditional", "call", "async-start"):
                cm = _CALLS_RE.search(body)
                if cm:
                    st.merge_scaled(cost(cm.group(1), seen + (comp,)), 1.0)
                continue
            coll = next(
                (k for k in _COLLECTIVE_KINDS if op.startswith(k)), None
            )
            if coll and not op.endswith("-done"):
                wire = collective_wire(result, body, coll)
                st.collective_wire_bytes += wire
                st.collective_by_type[coll] = st.collective_by_type.get(coll, 0.0) + wire
                st.collective_count += 1
                b = _shape_list_bytes(result)
                st.hbm_bytes += b
                st.bytes_by_op[coll] = st.bytes_by_op.get(coll, 0.0) + b
                continue
            if op == "dot":
                fl = dot_flops(result, body)
                st.flops += fl
                st.flops_by_op["dot"] = st.flops_by_op.get("dot", 0.0) + fl
                b = _shape_list_bytes(result) + mod.operand_bytes(body)
                st.hbm_bytes += b
                st.bytes_by_op["dot"] = st.bytes_by_op.get("dot", 0.0) + b
                continue
            if op == "fusion":
                b = mod.fusion_traffic_bytes(result, body)
                st.hbm_bytes += b
                st.bytes_by_op["fusion"] = st.bytes_by_op.get("fusion", 0.0) + b
                if is_tile(result):
                    st.attn_tile_bytes += b
                cm = _CALLS_RE.search(body)
                if cm:
                    for fl_line in mod.comps.get(cm.group(1), []):
                        fname, fres, fbody = _split(fl_line)
                        if re.match(r"\s*dot\(", fbody):
                            fl = dot_flops(fres, fbody)
                            st.flops += fl
                            st.flops_by_op["fusion.dot"] = (
                                st.flops_by_op.get("fusion.dot", 0.0) + fl
                            )
                continue
            if op == "custom-call":
                # e.g. oneDNN matmul on CPU, TopK — count boundary bytes;
                # matmul custom-calls also carry flops we cannot see → note.
                b = _shape_list_bytes(result) + mod.operand_bytes(body)
                st.hbm_bytes += b
                st.bytes_by_op["custom-call"] = st.bytes_by_op.get("custom-call", 0.0) + b
                if "matmul" in body or "dot" in body:
                    st.notes.append(f"custom-call matmul uncounted: {name}")
                continue
            # remaining real ops: boundary bytes (result + operands)
            b = _shape_list_bytes(result) + mod.operand_bytes(body)
            st.hbm_bytes += b
            st.bytes_by_op[op] = st.bytes_by_op.get(op, 0.0) + b
        memo[comp] = st
        return st

    entry = getattr(mod, "entry", None)
    if entry is None:
        out = HloStats()
        out.notes.append("no ENTRY found")
        return out
    total = cost(entry)
    total.notes = list(dict.fromkeys(total.notes))
    return total
