"""Three-term roofline from dry-run artifacts (TPU v5e constants).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

HLO quantities come from :mod:`repro.analysis.hlo` (trip-count-aware,
per-device); ``MODEL_FLOPS = 6·N·D`` (dense) or ``6·N_active·D`` (MoE).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (assignment-specified)."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    link_bw: float = 50e9  # bytes/s per ICI link
    hbm_bytes: float = 16e9  # capacity


V5E = HW()


def roofline_report(
    *,
    per_device_flops: float,
    per_device_hbm_bytes: float,
    per_device_wire_bytes: float,
    chips: int,
    model_flops: float,
    tokens: float,
    hw: HW = V5E,
) -> dict:
    """All quantities per step. Returns terms in seconds + diagnosis."""
    compute_t = per_device_flops / hw.peak_flops
    memory_t = per_device_hbm_bytes / hw.hbm_bw
    collective_t = per_device_wire_bytes / hw.link_bw
    terms = {"compute": compute_t, "memory": memory_t, "collective": collective_t}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())  # perfectly-overlapped lower bound
    total_hlo_flops = per_device_flops * chips
    useful_ratio = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    # roofline fraction: useful model FLOP/s achieved vs peak, at the
    # overlapped-lower-bound step time
    mfu = (
        model_flops / (step_time * chips * hw.peak_flops) if step_time > 0 else 0.0
    )
    return {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
        "bottleneck": bottleneck,
        "step_time_lb_s": step_time,
        "model_flops": model_flops,
        "hlo_flops_total": total_hlo_flops,
        "useful_flop_ratio": useful_ratio,
        "roofline_fraction_mfu": mfu,
        "tokens_per_s_lb": tokens / step_time if step_time > 0 else 0.0,
        "chips": chips,
    }
