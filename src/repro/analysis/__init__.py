from .hlo import HloStats, analyze_hlo
from .roofline import HW, roofline_report

__all__ = ["HloStats", "analyze_hlo", "HW", "roofline_report"]
