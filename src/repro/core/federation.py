"""Multi-cluster federation — registry, routing and carbon-aware placement.

The paper's eco mode defers jobs in *time*; federation adds the second
axis, deferring in *space*: a flexible job is routed to whichever member
cluster is cheapest in carbon-and-queue-wait terms.

Three pieces, layered on the existing :class:`~repro.core.backend.Backend`
protocol so nothing above the backend seam needs to know how many clusters
exist:

* :class:`ClusterRegistry` — named :class:`ClusterHandle` s built from
  ``[cluster.<name>]`` stanzas in ``~/.nbislurm.config`` (kind, per-cluster
  carbon trace, capacity/TDP metadata, per-cluster eco windows);
* :class:`FederatedBackend` — implements the Backend protocol by fanning
  ``queue()`` / ``cancel()`` / ``accounting()`` out across the members and
  namespacing every job id as ``<cluster>:<jobid>`` at its boundary, with
  one aggregated :class:`~repro.core.events.EventBus` re-emitting member
  events cluster-tagged;
* :class:`Placer` — scores each *feasible* member by predicted queue wait
  (live queue backlog, durations refined by the
  :class:`~repro.accounting.predict.RuntimePredictor`) combined with the
  member's carbon intensity over the job's predicted span. Eco-tier jobs
  land on the greenest feasible cluster; urgent jobs land on the fastest.

With no stanzas configured none of this is instantiated — ``get_backend()``
returns the plain single-cluster backend and every decision is bit-identical
to the pre-federation stack (property-pinned in ``tests/test_federation.py``).
"""

from __future__ import annotations

import math
import time as _time
from dataclasses import dataclass, replace as _dc_replace
from datetime import datetime, timedelta

from repro.obs.metrics import get_registry as _get_registry

from .config import NBIConfig, load_config
from .eco import CarbonTrace, EcoScheduler
from . import events as _ev
from .events import EventBus, TERMINAL_EVENTS

try:
    import numpy as _np
except ImportError:  # pragma: no cover — numpy is optional for the core
    _np = None

#: per-cluster config keys that override the global eco-window/horizon
#: settings when present inside a ``[cluster.<name>]`` stanza
_ECO_OVERRIDE_KEYS = (
    "eco_weekday_windows", "eco_weekend_windows", "peak_hours",
    "eco_horizon_days", "eco_min_delay_minutes",
)

_VALID_KINDS = ("sim", "slurm")


# ---------------------------------------------------------------------------
# Namespaced job ids
# ---------------------------------------------------------------------------


def split_cluster_id(jobid) -> "tuple[str, str]":
    """``"green:123_4"`` → ``("green", "123_4")``; bare ids → ``("", id)``."""
    s = str(jobid)
    cluster, sep, bare = s.partition(":")
    if sep and cluster and bare:
        return cluster, bare
    return "", s


def join_cluster_id(cluster: str, jobid) -> str:
    """Prefix ``jobid`` with its cluster (no-op for an empty cluster)."""
    bare = str(jobid)
    return f"{cluster}:{bare}" if cluster else bare


def array_base_id(jobid) -> str:
    """The array base of an id, cluster prefix preserved.

    ``green:123_4`` → ``green:123``; ``123_4`` → ``123``. Safe for
    cluster names containing ``_`` (the prefix is split on ``:`` first).
    """
    cluster, bare = split_cluster_id(jobid)
    return join_cluster_id(cluster, bare.partition("_")[0])


def id_covers(row_id, requested) -> bool:
    """Does a queue row id cover a requested id?

    A request may name the row exactly, its array base (with or without
    the federation cluster prefix), or the bare id without the prefix —
    ``1000001``, ``green:1000001`` and ``green:1000001_3`` all match the
    row ``green:1000001_3``. Cluster names may themselves contain ``_``.
    One matcher shared by ``waitjobs``, the gateway's server-side ``ids``
    filter pushdown, and the thin client's local fallback filtering, so
    every path resolves the same watch set.
    """
    row_id = str(row_id)
    bare = split_cluster_id(row_id)[1]
    return str(requested) in (
        row_id, array_base_id(row_id), bare, bare.partition("_")[0],
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclass
class ClusterHandle:
    """One federation member: a named backend plus placement metadata."""

    name: str
    kind: str = "sim"  # sim | slurm
    backend: object = None
    carbon_trace: CarbonTrace | None = None
    #: per-cluster EcoScheduler (this cluster's carbon trace and window
    #: overrides); the engine prices eco deferral through it
    scheduler: EcoScheduler | None = None
    watts_per_cpu: float = 12.0  # TDP metadata (the sim charges with it)
    nodes: int = 4
    cpus_per_node: int = 64
    memory_mb_per_node: int = 262144
    queue: str = ""  # default partition override for routed jobs

    @property
    def total_cpus(self) -> int:
        return self.nodes * self.cpus_per_node

    def fits(self, cpus: int, memory_mb: int) -> bool:
        """Could one node of this cluster ever run this job?"""
        return cpus <= self.cpus_per_node and memory_mb <= self.memory_mb_per_node


class ClusterRegistry:
    """Ordered collection of named :class:`ClusterHandle` s.

    Built from config stanzas (:meth:`from_config`) or assembled directly
    in tests/benchmarks. The first declared cluster is the **default** —
    the anchor for placement counterfactuals and for jobs pinned with
    ``runjob`` (no ``--anywhere``) — unless the top-level config key
    ``default_cluster`` names another member.
    """

    def __init__(self, handles: "list[ClusterHandle]", default: str = ""):
        if not handles:
            raise ValueError("a ClusterRegistry needs at least one cluster")
        self._handles: dict[str, ClusterHandle] = {}
        for h in handles:
            if h.name in self._handles:
                raise ValueError(f"duplicate cluster name {h.name!r}")
            self._handles[h.name] = h
        if default and default not in self._handles:
            raise ValueError(
                f"default_cluster {default!r} is not a configured cluster "
                f"(have: {', '.join(self._handles)})"
            )
        self.default_name = default or next(iter(self._handles))

    @classmethod
    def from_config(cls, cfg: NBIConfig | None = None) -> "ClusterRegistry":
        """Build the registry the ``[cluster.<name>]`` stanzas describe."""
        cfg = cfg if cfg is not None else load_config()
        names = cfg.cluster_names()
        if not names:
            raise ValueError(
                "no [cluster.<name>] stanzas in "
                + (cfg.path or "the config file")
            )
        handles = [cls._handle_from_section(cfg, n) for n in names]
        return cls(handles, default=cfg.get("default_cluster", "").strip())

    @staticmethod
    def _handle_from_section(cfg: NBIConfig, name: str) -> ClusterHandle:
        sec = cfg.cluster_section(name)
        kind = (sec.get("kind", "sim") or "sim").strip().lower()
        if kind not in _VALID_KINDS:
            raise ValueError(
                f"cluster {name!r}: unknown kind {kind!r} "
                f"(valid kinds: {', '.join(_VALID_KINDS)})"
            )
        trace_path = sec.get("carbon_trace", "").strip()
        trace = CarbonTrace.from_csv(trace_path) if trace_path else None
        nodes = int(sec.get("nodes", "4") or 4)
        cpus = int(sec.get("cpus_per_node", "64") or 64)
        mem = int(sec.get("memory_mb", "262144") or 262144)
        watts = float(sec.get("watts_per_cpu", cfg.get("energy_cpu_watts")))
        # per-cluster eco windows: stanza keys overlay the global ones
        overlay = {k: v for k, v in sec.items() if k in _ECO_OVERRIDE_KEYS}
        sched_cfg = NBIConfig(values={**cfg.values, **overlay}, path=cfg.path)
        scheduler = EcoScheduler(sched_cfg, carbon_trace=trace)
        if kind == "slurm":
            from .backend import SlurmBackend

            backend = SlurmBackend()
        else:
            from .backend import _current_user
            from .simcluster import SimCluster, SimNode

            backend = SimCluster(
                nodes=[
                    SimNode(f"{name}-n{i:03d}", cpus=cpus, memory_mb=mem)
                    for i in range(nodes)
                ],
                default_user=_current_user(),
                watts_per_cpu=watts,
                name=name,
            )
        return ClusterHandle(
            name=name, kind=kind, backend=backend,
            carbon_trace=trace, scheduler=scheduler,
            watts_per_cpu=watts, nodes=nodes, cpus_per_node=cpus,
            memory_mb_per_node=mem, queue=sec.get("queue", "").strip(),
        )

    # -- collection protocol --------------------------------------------------

    def __len__(self) -> int:
        return len(self._handles)

    def __iter__(self):
        return iter(self._handles.values())

    def __contains__(self, name: str) -> bool:
        return name in self._handles

    def names(self) -> "list[str]":
        return list(self._handles)

    def get(self, name: str) -> ClusterHandle:
        try:
            return self._handles[name]
        except KeyError:
            raise KeyError(
                f"unknown cluster {name!r} (have: {', '.join(self._handles)})"
            ) from None

    def default(self) -> ClusterHandle:
        return self._handles[self.default_name]


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Placement:
    """One routing decision, with the scored alternatives kept for audit."""

    cluster: str
    wait_s: float  # predicted queue wait on the chosen cluster
    carbon_gco2_kwh: float | None  # mean intensity over the predicted span
    eco: bool  # scored green-first (True) or fast-first (False)
    #: every feasible candidate as (name, wait_s, carbon) — chosen included
    candidates: tuple = ()


class Placer:
    """Score member clusters for one job; greenest-feasible vs fastest.

    *Feasibility* is static capacity: a cluster whose largest node cannot
    hold the job's cpus/memory is never a candidate. *Queue wait* is a
    backlog estimate from the live queue snapshot — cpu-seconds of work
    ahead (running jobs' remaining time, pending jobs' limits, refined by
    the ``predictor`` when it knows the job) divided by cluster capacity.
    *Carbon* is the member trace's mean intensity over the job's predicted
    span starting after that wait.

    Eco-tier jobs sort green-first (carbon, then wait); urgent jobs sort
    fast-first (wait, then carbon). Ties break on the cluster name so
    placement is deterministic.
    """

    def __init__(self, registry: ClusterRegistry, *, predictor=None):
        self.registry = registry
        self.predictor = predictor
        self.placements = 0  # observability (bench_federation reports it)
        #: cpu-seconds charged for placements not yet visible in queue():
        #: within one batch the live snapshot lags the routing, so each
        #: choice is charged here and cleared once actually submitted —
        #: an urgent batch then spreads by capacity instead of piling onto
        #: whichever member looked fastest at batch start
        self._inflight: dict[str, float] = {}
        #: per-batch member queue snapshots (one queue() per member per
        #: batch, not per placement; cleared with the in-flight charges)
        self._snapshots: dict[str, list] = {}
        #: optional :class:`BacklogTracker` (set by FederatedBackend):
        #: when attached, backlog comes from the event-driven incremental
        #: state instead of fresh queue() snapshots
        self.tracker: "BacklogTracker | None" = None
        #: per-batch base-backlog cache (one backlog computation per
        #: member per batch, whatever the source; cleared with the
        #: in-flight charges)
        self._base_cache: dict[str, float] = {}

    # -- public API -----------------------------------------------------------

    def place(self, job, now: datetime, *, eco: bool = False,
              charge: bool = True) -> Placement:
        """Route one :class:`~repro.core.job.Job`-shaped object."""
        opts = job.opts
        return self.place_spec(
            cpus=getattr(opts, "threads", 1),
            memory_mb=getattr(opts, "memory_mb", 0),
            time_s=getattr(opts, "time_s", 3600),
            now=now,
            name=getattr(job, "name", ""),
            tool=getattr(job, "tool", ""),
            eco=eco,
            charge=charge,
        )

    def place_spec(
        self,
        cpus: int,
        memory_mb: int,
        time_s: int,
        now: datetime,
        *,
        name: str = "",
        tool: str = "",
        eco: bool = False,
        charge: bool = True,
    ) -> Placement:
        duration_s = self._duration(time_s, name, tool)
        feasible = [h for h in self.registry if h.fits(cpus, memory_mb)]
        if not feasible:
            # nothing fits anywhere: fall back to every member and let the
            # chosen backend queue (and eventually reject) it — a job must
            # never be silently dropped at placement time
            feasible = list(self.registry)
        cands = []
        for h in feasible:
            wait = self.queue_wait_s(h)
            start = now + timedelta(seconds=wait)
            carbon = (
                h.carbon_trace.mean_over(start, duration_s)
                if h.carbon_trace is not None
                else None
            )
            cands.append((h.name, wait, carbon))
        inf = float("inf")
        if eco:
            key = lambda c: (c[2] if c[2] is not None else inf, c[1], c[0])  # noqa: E731
        else:
            key = lambda c: (c[1], c[2] if c[2] is not None else inf, c[0])  # noqa: E731
        best = min(cands, key=key)
        self.placements += 1
        if charge:  # probes (dry runs) must not skew later placements
            self._inflight[best[0]] = (
                self._inflight.get(best[0], 0.0) + max(1, cpus) * duration_s
            )
        return Placement(
            cluster=best[0], wait_s=best[1], carbon_gco2_kwh=best[2],
            eco=eco, candidates=tuple(cands),
        )

    def place_many(self, specs, now: datetime, *, charge: bool = True) -> "list[Placement]":
        """Route a batch of job specs, in order — the vectorized hot path.

        Each spec is a mapping with keys ``cpus``, ``memory_mb``,
        ``time_s`` and optional ``name``, ``tool``, ``eco``. The result is
        bit-identical to calling :meth:`place_spec` once per spec in the
        same order (property-pinned in ``tests/test_placer_vectorized.py``):
        same chosen clusters, same wait/carbon floats, same tie-breaks,
        same in-flight charge state afterwards.

        The per-job Python work is batched through numpy — feasibility
        matrix, predicted durations, span hours and charge amounts are one
        array pass each, and carbon-over-span collapses to a 168-entry
        lookup table per (member, span) — leaving only the inherently
        sequential part (each charged placement shifts the next job's
        wait) as a cheap O(members) inner step. Without numpy it falls
        back to the scalar loop.
        """
        specs = list(specs)
        if not specs:
            return []
        _reg = _get_registry()
        _t0 = _time.perf_counter() if _reg.enabled else 0.0
        if _np is None:  # numpy unavailable — the scalar loop is the spec
            placements = [
                self.place_spec(
                    cpus=int(s.get("cpus", 1)),
                    memory_mb=int(s.get("memory_mb", 0)),
                    time_s=int(s.get("time_s", 3600)),
                    now=now,
                    name=s.get("name", ""),
                    tool=s.get("tool", ""),
                    eco=bool(s.get("eco", False)),
                    charge=charge,
                )
                for s in specs
            ]
            self._record_place_many(_reg, "fallback", len(specs), _t0)
            return placements
        handles = list(self.registry)
        m_count = len(handles)
        names = [h.name for h in handles]
        caps = [max(1, h.total_cpus) for h in handles]
        traces = [h.carbon_trace for h in handles]
        base = [self._backlog_cpu_s(h) for h in handles]
        infl = [self._inflight.get(n, 0.0) for n in names]
        wait = [(base[m] + infl[m]) / caps[m] for m in range(m_count)]
        e0_us = _week_us(now)
        h0 = [_hour_of_week_after(e0_us, wait[m]) for m in range(m_count)]
        tables: dict[tuple[int, int], list] = {}  # (member, hours) → mean table

        # one numpy pass over the whole batch: durations, feasibility,
        # span hours, charge amounts
        durs = self._durations(specs)
        cpus_a = _np.asarray([int(s.get("cpus", 1)) for s in specs], dtype=_np.int64)
        mem_a = _np.asarray([int(s.get("memory_mb", 0)) for s in specs], dtype=_np.int64)
        dur_a = _np.asarray(durs, dtype=_np.int64)
        node_cpus = _np.asarray([h.cpus_per_node for h in handles], dtype=_np.int64)
        node_mem = _np.asarray([h.memory_mb_per_node for h in handles], dtype=_np.int64)
        feas = (cpus_a[:, None] <= node_cpus[None, :]) & (
            mem_a[:, None] <= node_mem[None, :]
        )
        # nothing fits anywhere → fall back to every member (a job must
        # never be silently dropped at placement time)
        feas[~feas.any(axis=1)] = True
        masks = (feas @ (1 << _np.arange(m_count, dtype=_np.int64))).tolist()
        hours_l = _np.maximum(1, _np.rint(dur_a / 3600.0)).astype(_np.int64).tolist()
        charge_l = (_np.maximum(1, cpus_a) * dur_a).tolist()
        eco_l = [bool(s.get("eco", False)) for s in specs]
        members_by_mask: dict[int, tuple] = {}
        inf = float("inf")

        out: list[Placement] = []
        for i in range(len(specs)):
            idxs = members_by_mask.get(masks[i])
            if idxs is None:
                idxs = tuple(m for m in range(m_count) if masks[i] >> m & 1)
                members_by_mask[masks[i]] = idxs
            hours = hours_l[i]
            eco_i = eco_l[i]
            cands = []
            best = -1
            best_key = None
            best_wait = 0.0
            best_carbon: float | None = None
            for m in idxs:
                tr = traces[m]
                if tr is None:
                    carbon = None
                    ckey = inf
                else:
                    tbl = tables.get((m, hours))
                    if tbl is None:
                        tbl = _mean_table(tr, hours)
                        tables[(m, hours)] = tbl
                    carbon = tbl[h0[m]]
                    ckey = carbon
                w = wait[m]
                cands.append((names[m], w, carbon))
                key = (ckey, w, names[m]) if eco_i else (w, ckey, names[m])
                if best_key is None or key < best_key:
                    best_key, best, best_wait, best_carbon = key, m, w, carbon
            self.placements += 1
            if charge:
                infl[best] += charge_l[i]
                wait[best] = (base[best] + infl[best]) / caps[best]
                h0[best] = _hour_of_week_after(e0_us, wait[best])
            out.append(Placement(
                cluster=names[best], wait_s=best_wait,
                carbon_gco2_kwh=best_carbon, eco=eco_i,
                candidates=tuple(cands),
            ))
        if charge:
            for m in range(m_count):
                if infl[m]:
                    self._inflight[names[m]] = infl[m]
        self._record_place_many(_reg, "vectorized", len(specs), _t0)
        return out

    @staticmethod
    def _record_place_many(reg, path: str, n: int, t0: float) -> None:
        if not reg.enabled:
            return
        reg.counter(
            "nbi_placer_placements_total",
            "batch placements, by scoring path",
            labels=("path",),
        ).labels(path=path).inc(n)
        reg.histogram(
            "nbi_placer_score_seconds", "place_many batch scoring wall time"
        ).observe(_time.perf_counter() - t0)

    def place_jobs(self, jobs, now: datetime, eco_flags=None, *,
                   charge: bool = True) -> "list[Placement]":
        """Batch-route :class:`~repro.core.job.Job`-shaped objects (the
        SubmitEngine's path); same order/charging as per-job :meth:`place`."""
        jobs = list(jobs)
        if eco_flags is None:
            eco_flags = [False] * len(jobs)
        specs = []
        for job, eco in zip(jobs, eco_flags):
            opts = job.opts
            specs.append({
                "cpus": getattr(opts, "threads", 1),
                "memory_mb": getattr(opts, "memory_mb", 0),
                "time_s": getattr(opts, "time_s", 3600),
                "name": getattr(job, "name", ""),
                "tool": getattr(job, "tool", ""),
                "eco": bool(eco),
            })
        return self.place_many(specs, now, charge=charge)

    def clear_inflight(self) -> None:
        """Forget placement charges, the per-batch queue snapshots and the
        per-batch backlog cache — the member queues now reflect them."""
        self._inflight.clear()
        self._snapshots.clear()
        self._base_cache.clear()

    def queue_wait_s(self, handle: ClusterHandle) -> float:
        """Backlog estimate: cpu-seconds of queued work / cluster capacity.

        The base backlog comes from the attached :class:`BacklogTracker`
        when there is one (event-driven, no queue() calls), else from a
        member queue snapshot taken once per batch (a 500-job batch across
        real SLURM members must not fork 500 squeues per member);
        in-flight charges model everything placed since.
        """
        backlog = self._backlog_cpu_s(handle)
        backlog += self._inflight.get(handle.name, 0.0)
        return backlog / max(1, handle.total_cpus)

    # -- internals ------------------------------------------------------------

    def _backlog_cpu_s(self, handle: ClusterHandle) -> float:
        """Base backlog (no in-flight charges), cached for the batch."""
        cached = self._base_cache.get(handle.name)
        if cached is not None:
            return cached
        if self.tracker is not None and self.tracker.covers(handle.name):
            backlog = self.tracker.backlog_cpu_s(handle.name)
        else:
            backlog = self._snapshot_backlog(handle)
        self._base_cache[handle.name] = backlog
        return backlog

    def _snapshot_backlog(self, handle: ClusterHandle) -> float:
        from .resources import parse_time_s

        if handle.name not in self._snapshots:
            self._snapshots[handle.name] = handle.backend.queue()
        backlog = 0.0
        for row in self._snapshots[handle.name]:
            try:
                cpus = float(row.get("cpus") or 1)
            except ValueError:
                cpus = 1.0
            state = row.get("state", "")
            span = ""
            if state == "RUNNING":
                span = row.get("time_left", "")
            elif state == "PENDING":
                span = row.get("time_limit", "")
            if not span:
                continue
            try:
                seconds = parse_time_s(span)
            except ValueError:
                continue
            if state == "PENDING":
                seconds = self._duration(
                    seconds, row.get("name", ""), ""
                )
            backlog += cpus * seconds
        return backlog

    def _duration(self, time_s: int, name: str, tool: str) -> int:
        return _predicted_duration(self.predictor, time_s, name, tool)

    def _durations(self, specs) -> list:
        """Predicted durations for a batch, memoized per distinct key —
        a sweep of N identical jobs costs one predictor call, not N."""
        memo: dict = {}
        out = []
        for s in specs:
            key = (
                int(s.get("time_s", 3600)), s.get("name", ""), s.get("tool", ""),
            )
            d = memo.get(key)
            if d is None:
                d = _predicted_duration(self.predictor, *key)
                memo[key] = d
            out.append(d)
        return out


def _predicted_duration(predictor, time_s: int, name: str, tool: str) -> int:
    if predictor is None or not (name or tool):
        return time_s
    return predictor.predict(time_s, name=name, tool=tool)


# -- exact-arithmetic helpers for the vectorized scorer ----------------------
#
# place_spec computes carbon as trace.mean_over(now + timedelta(seconds=wait),
# duration): the vectorized path must reproduce that float-for-float. The
# helpers below replicate (a) timedelta's microsecond quantisation of a float
# seconds value (round-half-even, like CPython's accumulate()), and (b)
# mean_over's sequential hourly accumulation, as a 168-entry table over the
# start hour-of-week.

_US_PER_HOUR = 3_600_000_000


def _week_us(t: datetime) -> int:
    """Microseconds since Monday 00:00 of ``t``'s week."""
    return (
        (t.weekday() * 86400 + t.hour * 3600 + t.minute * 60 + t.second)
        * 1_000_000
        + t.microsecond
    )


def _hour_of_week_after(e0_us: int, wait_s: float) -> int:
    frac, whole = math.modf(wait_s)
    us = int(whole) * 1_000_000 + round(frac * 1e6)
    return (e0_us + us) // _US_PER_HOUR % 168


def _mean_table(trace: CarbonTrace, hours: int) -> "list[float]":
    """``tbl[h0]`` = mean_over for a span of ``hours`` starting in week
    hour ``h0`` — same sequential accumulation as CarbonTrace.mean_over."""
    hourly = trace.hourly
    length = len(hourly)
    tbl = []
    for h0 in range(168):
        total = 0.0
        for i in range(hours):
            total += hourly[(h0 + i) % 168 % length]
        tbl.append(total / hours)
    return tbl


# ---------------------------------------------------------------------------
# BacklogTracker
# ---------------------------------------------------------------------------


class BacklogTracker:
    """Event-driven per-cluster backlog, in cpu-seconds of queued work.

    The Placer's original backlog source re-snapshots every member queue
    once per batch — O(queue) per member per batch, which dominates the
    placement hot path on a busy simulated day. The tracker instead
    subscribes to the federation's :class:`~repro.core.events.EventBus`
    and charges/discharges each cluster's backlog as SUBMITTED / STARTED /
    REQUEUED / terminal events arrive, so a backlog query is O(running)
    with no queue() call at all.

    Every contribution replicates the snapshot-walk formula exactly —
    pending jobs charge ``cpus × predicted(time_limit, name)`` (the same
    format/parse roundtrip and name-only predictor key the snapshot path
    uses), running jobs charge ``cpus × max(0, limit - int(now - start))``
    with the same integer truncation — and all contributions are integral
    floats, so the incremental sum is *bit-identical* to a fresh snapshot,
    not merely close. :meth:`reconcile` verifies that against real
    snapshots (recording any drift, then adopting the snapshot state) and
    runs automatically every ``reconcile_every`` events as a drift guard.

    Only members whose backend resolves ``get(jobid)`` (the simulator)
    are covered; real-SLURM members transparently keep the snapshot path.
    """

    def __init__(self, registry: ClusterRegistry, bus: EventBus | None, *,
                 predictor=None, reconcile_every: int = 4096):
        self.registry = registry
        self.predictor = predictor
        self.reconcile_every = max(0, int(reconcile_every))
        self._pending: dict[str, dict[str, float]] = {}  # cluster → jobid → charge
        self._pending_sum: dict[str, float] = {}
        #: cluster → jobid → (cpus, time_limit_s, started_at)
        self._running: dict[str, dict[str, tuple]] = {}
        self._covered: dict[str, bool] = {}
        for h in registry:
            self._pending[h.name] = {}
            self._pending_sum[h.name] = 0.0
            self._running[h.name] = {}
            self._covered[h.name] = hasattr(h.backend, "get")
        # observability
        self.events_seen = 0
        self.reconciles = 0
        self.max_drift_cpu_s = 0.0
        self._events_since_reconcile = 0
        self._bus = bus
        self._token = bus.subscribe(self._on_event) if bus is not None else None
        self.prime()

    # -- lifecycle ------------------------------------------------------------

    def close(self) -> None:
        """Unsubscribe (a discarded tracker must stop receiving events)."""
        if self._token is not None:
            self._bus.unsubscribe(self._token)
            self._token = None

    def covers(self, name: str) -> bool:
        return self._covered.get(name, False)

    def prime(self) -> None:
        """Adopt the current member queues (initial sync; no drift read)."""
        for h in self.registry:
            if not self._covered.get(h.name):
                continue
            pend, run = self._state_from_queue(h)
            self._pending[h.name] = pend
            self._pending_sum[h.name] = sum(pend.values())
            self._running[h.name] = run

    # -- queries --------------------------------------------------------------

    def backlog_cpu_s(self, name: str, now: datetime | None = None) -> float:
        """Cluster ``name``'s backlog in cpu-seconds, at ``now`` (default:
        the member's own clock) — same value a fresh snapshot walk gives."""
        handle = self.registry.get(name)
        if now is None:
            now = getattr(handle.backend, "now", None) or datetime.now()
        backlog = self._pending_sum[name]
        for cpus_f, limit_s, started_at in self._running[name].values():
            left = limit_s - int((now - started_at).total_seconds())
            if left > 0:
                backlog += cpus_f * left
        return backlog

    def reconcile(self) -> "dict[str, float]":
        """Recompute every covered member from a fresh queue() snapshot;
        returns per-cluster drift (incremental − fresh, in cpu-seconds)
        and adopts the snapshot state."""
        drift: dict[str, float] = {}
        for h in self.registry:
            if not self._covered.get(h.name):
                continue
            now = getattr(h.backend, "now", None) or datetime.now()
            incremental = self.backlog_cpu_s(h.name, now=now)
            pend, run = self._state_from_queue(h)
            self._pending[h.name] = pend
            self._pending_sum[h.name] = sum(pend.values())
            self._running[h.name] = run
            fresh = self.backlog_cpu_s(h.name, now=now)
            drift[h.name] = incremental - fresh
            self.max_drift_cpu_s = max(self.max_drift_cpu_s, abs(drift[h.name]))
        self.reconciles += 1
        self._events_since_reconcile = 0
        return drift

    # -- event handling --------------------------------------------------------

    def _on_event(self, event) -> None:
        cname = getattr(event, "cluster", "") or ""
        if not self._covered.get(cname):
            return
        _, bare = split_cluster_id(event.jobid)
        etype = event.type
        if etype == _ev.SUBMITTED:
            self._charge_pending(cname, bare)
        elif etype == _ev.STARTED:
            self._discharge_pending(cname, bare)
            job = self._job(cname, bare)
            if job is not None and job.started_at is not None:
                self._running[cname][bare] = (
                    float(job.cpus), int(job.time_limit_s), job.started_at,
                )
        elif etype == _ev.REQUEUED:
            self._running[cname].pop(bare, None)
            self._charge_pending(cname, bare)
        elif etype in TERMINAL_EVENTS:
            self._discharge_pending(cname, bare)
            self._running[cname].pop(bare, None)
        self.events_seen += 1
        self._events_since_reconcile += 1
        if self.reconcile_every and self._events_since_reconcile >= self.reconcile_every:
            self.reconcile()

    # -- internals -------------------------------------------------------------

    def _job(self, cname: str, bare: str):
        return self.registry.get(cname).backend.get(bare)

    def _charge_pending(self, cname: str, bare: str) -> None:
        job = self._job(cname, bare)
        if job is None:
            return
        charge = float(job.cpus) * _predicted_duration(
            self.predictor, int(job.time_limit_s), getattr(job, "name", ""), "",
        )
        pend = self._pending[cname]
        old = pend.get(bare)
        if old is not None:
            self._pending_sum[cname] -= old
        pend[bare] = charge
        self._pending_sum[cname] += charge

    def _discharge_pending(self, cname: str, bare: str) -> None:
        old = self._pending[cname].pop(bare, None)
        if old is not None:
            self._pending_sum[cname] -= old

    def _state_from_queue(self, handle: ClusterHandle):
        """Pending charges + running tuples from a fresh queue() snapshot,
        with exactly the snapshot-walk arithmetic."""
        from .resources import parse_time_s

        pend: dict[str, float] = {}
        run: dict[str, tuple] = {}
        get = getattr(handle.backend, "get", None)
        for row in handle.backend.queue():
            jid = str(row.get("jobid", ""))
            try:
                cpus = float(row.get("cpus") or 1)
            except ValueError:
                cpus = 1.0
            state = row.get("state", "")
            if state == "PENDING":
                span = row.get("time_limit", "")
                if not span:
                    continue
                try:
                    seconds = parse_time_s(span)
                except ValueError:
                    continue
                pend[jid] = cpus * _predicted_duration(
                    self.predictor, seconds, row.get("name", ""), "",
                )
            elif state == "RUNNING":
                job = get(jid) if get is not None else None
                if job is None or job.started_at is None:
                    continue
                run[jid] = (float(job.cpus), int(job.time_limit_s), job.started_at)
        return pend, run


# ---------------------------------------------------------------------------
# FederatedBackend
# ---------------------------------------------------------------------------


class FederatedBackend:
    """The Backend protocol, fanned out across a :class:`ClusterRegistry`.

    Job ids cross this boundary namespaced as ``<cluster>:<jobid>``
    (``<cluster>:<base>_<task>`` for array tasks); queue rows, accounting
    rows, node records and re-emitted events all carry a ``cluster``
    field. Inward, each member backend sees exactly the bare ids and jobs
    it always did — a member cannot tell it is federated.
    """

    def __init__(self, registry: ClusterRegistry, *, placer: Placer | None = None,
                 predictor=None, tracker: bool = True):
        self.registry = registry
        self.placer = placer if placer is not None else Placer(
            registry, predictor=predictor
        )
        #: aggregated event stream: member events re-emitted with the
        #: jobid namespaced and ``cluster`` set
        self.bus = EventBus()
        self._member_tokens: list = []
        for h in registry:
            mbus = getattr(h.backend, "bus", None)
            if mbus is not None:
                token = mbus.subscribe(self._reemitter(h.name))
                self._member_tokens.append((mbus, token))
        #: event-driven backlog tracking (on by default): members whose
        #: backend resolves get() — the simulator — are tracked
        #: incrementally; others keep the per-batch snapshot path
        self.tracker: BacklogTracker | None = None
        if tracker:
            self.tracker = BacklogTracker(
                registry, self.bus, predictor=self.placer.predictor,
            )
            self.placer.tracker = self.tracker
        # config fingerprint for the shared-instance cache (backend.py)
        self._config_key = None

    def _reemitter(self, name: str):
        def forward(event):
            self.bus.emit(_dc_replace(
                event, jobid=join_cluster_id(name, event.jobid), cluster=name,
            ))

        return forward

    def close(self) -> None:
        """Unsubscribe from member buses (discarded instances must not
        keep re-emitting)."""
        for mbus, token in self._member_tokens:
            mbus.unsubscribe(token)
        self._member_tokens = []
        if self.tracker is not None:
            self.tracker.close()
            if self.placer.tracker is self.tracker:
                self.placer.tracker = None
            self.tracker = None

    # -- properties ------------------------------------------------------------

    @property
    def all_sim(self) -> bool:
        """True when every member can advance simulated time (tests, demos)."""
        return all(hasattr(h.backend, "advance") for h in self.registry)

    @property
    def now(self) -> datetime:
        """The federation clock: the latest member sim clock, else wall time."""
        clocks = [
            h.backend.now for h in self.registry if hasattr(h.backend, "now")
        ]
        return max(clocks) if clocks else datetime.now()

    def names(self) -> "list[str]":
        return self.registry.names()

    # -- Backend protocol: submission -------------------------------------------

    def _route(self, job, now: datetime | None = None) -> str:
        """The member this job goes to: its pin, or the placer's choice."""
        pinned = getattr(job, "cluster", "") or ""
        if pinned:
            self.registry.get(pinned)  # raise early on unknown pins
            return pinned
        eco = bool((getattr(job, "eco_meta", None) or {}).get("deferred"))
        return self.placer.place(job, now or self.now, eco=eco).cluster

    def submit(self, job) -> str:
        name = self._route(job)
        handle = self.registry.get(name)
        if handle.queue and not job.opts.queue:
            job.opts.queue = handle.queue
        base = handle.backend.submit(job)
        job.cluster = name
        self.placer.clear_inflight()  # the member queue now shows the job
        return join_cluster_id(name, base)

    def submit_many(self, jobs: list) -> "list[str]":
        """Route every job, then batch per member (order preserved)."""
        jobs = list(jobs)
        now = self.now
        ids: "list[str | None]" = [None] * len(jobs)
        groups: dict[str, list[int]] = {}
        for i, job in enumerate(jobs):
            name = self._route(job, now)
            job.cluster = name
            groups.setdefault(name, []).append(i)
        for name, idxs in groups.items():
            handle = self.registry.get(name)
            for i in idxs:
                if handle.queue and not jobs[i].opts.queue:
                    jobs[i].opts.queue = handle.queue
            be = handle.backend
            many = getattr(be, "submit_many", None)
            batch = [jobs[i] for i in idxs]
            base_ids = many(batch) if many else [be.submit(j) for j in batch]
            for i, base in zip(idxs, base_ids):
                ids[i] = join_cluster_id(name, base)
        self.placer.clear_inflight()  # member queues now show the batch
        return ids  # type: ignore[return-value]

    # -- Backend protocol: queries ----------------------------------------------

    def queue(self) -> "list[dict]":
        reg = _get_registry()
        fanout = reg.histogram(
            "nbi_federation_member_queue_seconds",
            "per-member queue() fanout latency",
            labels=("cluster",),
        ) if reg.enabled else None
        rows = []
        for h in self.registry:
            t0 = _time.perf_counter() if fanout is not None else 0.0
            member_rows = h.backend.queue()
            if fanout is not None:
                fanout.labels(cluster=h.name).observe(_time.perf_counter() - t0)
            for row in member_rows:
                row = dict(row)
                row["jobid"] = join_cluster_id(h.name, row["jobid"])
                row["cluster"] = h.name
                rows.append(row)
        return rows

    def nodes_info(self) -> "list[dict]":
        out = []
        for h in self.registry:
            for rec in h.backend.nodes_info():
                rec = dict(rec)
                rec["name"] = join_cluster_id(h.name, rec.get("name", ""))
                rec["cluster"] = h.name
                out.append(rec)
        return out

    def accounting(self, **kw) -> list:
        """Every member's accounting, cluster-tagged and id-namespaced.

        Keyword arguments (``since=``, ``user=``) are forwarded only to
        members whose accounting accepts them (sacct-backed members do;
        the simulator takes none).
        """
        out = []
        for h in self.registry:
            acct = getattr(h.backend, "accounting", None)
            if acct is None:
                continue
            rows = acct(**kw) if kw and _accepts_kwargs(acct, kw) else acct()
            for row in rows:
                if isinstance(row, dict):
                    row = dict(row)
                    row["jobid"] = join_cluster_id(h.name, str(row.get("jobid", "")))
                    row["cluster"] = h.name
                else:  # SimJob dataclass: copy, never mutate the member's
                    row = _dc_replace(row, jobid=join_cluster_id(h.name, row.jobid))
                    row.cluster = h.name
                out.append(row)
        return out

    def get(self, jobid):
        """Resolve one job (simulator members only), namespaced copy out."""
        cluster, bare = split_cluster_id(jobid)
        handles = [self.registry.get(cluster)] if cluster else list(self.registry)
        for h in handles:
            getter = getattr(h.backend, "get", None)
            if getter is None:
                continue
            job = getter(bare)
            if job is not None:
                job = _dc_replace(job, jobid=join_cluster_id(h.name, job.jobid))
                job.cluster = h.name
                return job
        return None

    # -- Backend protocol: control -----------------------------------------------

    def _group_ids(self, jobids: list) -> "dict[str, list[str]]":
        """Split namespaced ids per member; bare ids go to the default."""
        groups: dict[str, list[str]] = {}
        for jid in jobids:
            cluster, bare = split_cluster_id(jid)
            groups.setdefault(cluster or self.registry.default_name, []).append(bare)
        return groups

    def cancel(self, jobids: list) -> None:
        for name, bare in self._group_ids(jobids).items():
            self.registry.get(name).backend.cancel(bare)

    def release(self, jobids: list) -> None:
        for name, bare in self._group_ids(jobids).items():
            be = self.registry.get(name).backend
            rel = getattr(be, "release", None)
            if rel is not None:
                rel(bare)

    # -- simulator conveniences (every member must be a sim) ----------------------

    def advance(self, seconds: float = 0, *, to: datetime | None = None):
        """Advance every sim member in lockstep (tests/demos/benchmarks)."""
        self._require_sim("advance")
        target = to if to is not None else self.now + timedelta(seconds=seconds)
        for h in self.registry:
            h.backend.advance(to=target)
        return self

    def run_until_idle(self, max_days: int = 30):
        self._require_sim("run_until_idle")
        for h in self.registry:
            h.backend.run_until_idle(max_days)
        # re-sync member clocks so the next advance() is a true lockstep
        latest = self.now
        for h in self.registry:
            if h.backend.now < latest:
                h.backend.advance(to=latest)
        return self

    def wake_at(self, t: datetime, cluster: str = "") -> None:
        """Register a controller deadline; with ``cluster=`` only that
        member's calendar gets the entry (an eco deadline on a held job
        concerns one cluster — waking every member would add a spurious
        ``advance()`` stop per member per deadline)."""
        for h in self.registry:
            if cluster and h.name != cluster:
                continue
            wake = getattr(h.backend, "wake_at", None)
            if wake is not None:
                wake(t)

    def add_tick_hook(self, fn) -> None:
        """Register a reactive controller hook on every sim member."""
        for h in self.registry:
            add = getattr(h.backend, "add_tick_hook", None)
            if add is not None:
                add(fn)

    def remove_tick_hook(self, fn) -> None:
        for h in self.registry:
            rem = getattr(h.backend, "remove_tick_hook", None)
            if rem is not None:
                rem(fn)

    def _require_sim(self, op: str) -> None:
        if not self.all_sim:
            raise RuntimeError(
                f"{op}() needs every federation member to be a simulator"
            )


def _accepts_kwargs(fn, kw: dict) -> bool:
    import inspect

    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return True
    return all(k in params for k in kw)
