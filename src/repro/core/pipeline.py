"""``Pipeline`` — multistep analyses with automatic ``afterok`` wiring.

Port of ``NBI::Pipeline``: wire SLURM dependencies between ``Job`` (or
``Launcher``) instances automatically. Steps are named; edges are declared
with ``after=[...]``; ``run()`` submits in topological order, threading the
real job ids into each dependant's ``--dependency=afterok:...``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .job import Job
from .launcher import Launcher


class PipelineError(ValueError):
    pass


@dataclass
class _Step:
    name: str
    payload: object  # Job | Launcher
    after: list = field(default_factory=list)
    jobid: int | None = None


class Pipeline:
    """A DAG of jobs with afterok dependencies."""

    def __init__(self, name: str = "pipeline", backend=None):
        self.name = name
        self.backend = backend
        self.steps: dict[str, _Step] = {}

    def add(self, name: str, payload, after: "list[str] | str | None" = None) -> "Pipeline":
        if name in self.steps:
            raise PipelineError(f"duplicate step {name!r}")
        if isinstance(after, str):
            after = [after]
        self.steps[name] = _Step(name=name, payload=payload, after=list(after or []))
        return self

    # -- ordering -----------------------------------------------------------

    def toposort(self) -> list[_Step]:
        for s in self.steps.values():
            for dep in s.after:
                if dep not in self.steps:
                    raise PipelineError(f"step {s.name!r} depends on unknown {dep!r}")
        order: list[_Step] = []
        seen: dict[str, int] = {}  # 0=visiting, 1=done

        def visit(name: str):
            state = seen.get(name)
            if state == 1:
                return
            if state == 0:
                raise PipelineError(f"dependency cycle involving {name!r}")
            seen[name] = 0
            for dep in self.steps[name].after:
                visit(dep)
            seen[name] = 1
            order.append(self.steps[name])

        for name in self.steps:
            visit(name)
        return order

    # -- submission -----------------------------------------------------------

    def run(self, **submit_kw) -> dict[str, int]:
        """Submit every step in dependency order; returns name → jobid."""
        ids: dict[str, int] = {}
        for step in self.toposort():
            dep_ids = [ids[d] for d in step.after]
            payload = step.payload
            if isinstance(payload, Launcher):
                payload.opts.dependencies = dep_ids
                if self.backend is not None and payload.backend is None:
                    payload.backend = self.backend
                jobid = payload.submit(**submit_kw)
            elif isinstance(payload, Job):
                payload.opts.dependencies = dep_ids
                jobid = payload.run(self.backend or payload.backend)
            else:
                raise PipelineError(f"step {step.name!r}: unsupported payload type")
            step.jobid = jobid
            ids[step.name] = jobid
        return ids
