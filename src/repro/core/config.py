"""Configuration file handling for NBI-Slurm (``~/.nbislurm.config``).

The paper specifies a user-level settings file, by default
``~/.nbislurm.config``, controlling queue defaults and the eco-mode windows.
The format is intentionally trivial (``key=value`` lines, ``#`` comments) so a
user can edit it without documentation.

Recognised keys (all optional):

``economy_mode``            1/0 — eco mode on by default (paper: default ON)
``queue``                   default partition name
``tmpdir``                  scratch directory for generated scripts
``email``                   notification address
``eco_weekday_windows``     comma list of HH:MM-HH:MM windows (Mon-Fri)
``eco_weekend_windows``     comma list of HH:MM-HH:MM windows (Sat-Sun)
``peak_hours``              comma list of HH:MM-HH:MM peak windows (daily)
``eco_horizon_days``        how far ahead the scheduler searches
``eco_min_delay_minutes``   do not schedule sooner than now + this
``carbon_trace``            optional CSV path for carbon-aware scoring
``history_file``            job archive path (default ``~/.nbi/history.jsonl``)
``eco_prediction``          1/0 — estimate durations from the job archive
``energy_cpu_watts``        per-allocated-core draw for the energy model
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from pathlib import Path

DEFAULT_CONFIG_PATH = "~/.nbislurm.config"

_DEFAULTS = {
    "economy_mode": "1",
    "queue": "",
    "tmpdir": "",
    "email": "",
    "eco_weekday_windows": "00:00-06:00",
    "eco_weekend_windows": "00:00-07:00,11:00-16:00",
    "peak_hours": "17:00-20:00",
    "eco_horizon_days": "14",
    "eco_min_delay_minutes": "0",
    "carbon_trace": "",
    "history_file": "",
    "eco_prediction": "1",
    "energy_cpu_watts": "12.0",
}


@dataclass
class NBIConfig:
    """Parsed contents of an ``.nbislurm.config`` file (plus defaults)."""

    values: dict = field(default_factory=dict)
    path: str = ""

    def get(self, key: str, default: str | None = None) -> str:
        if key in self.values:
            return self.values[key]
        if key in _DEFAULTS:
            return _DEFAULTS[key]
        if default is not None:
            return default
        raise KeyError(key)

    def get_bool(self, key: str) -> bool:
        return self.get(key).strip().lower() in ("1", "true", "yes", "on")

    def get_int(self, key: str) -> int:
        return int(self.get(key).strip())

    def get_windows(self, key: str) -> list[tuple[int, int]]:
        """Parse ``HH:MM-HH:MM[,HH:MM-HH:MM...]`` into minute-of-day pairs."""
        out: list[tuple[int, int]] = []
        raw = self.get(key).strip()
        if not raw:
            return out
        for part in raw.split(","):
            part = part.strip()
            if not part:
                continue
            lo, hi = part.split("-")
            out.append((_parse_hhmm(lo), _parse_hhmm(hi)))
        return out


def _parse_hhmm(s: str) -> int:
    """``HH:MM`` → minute of day. ``24:00`` is accepted as end-of-day."""
    h, m = s.strip().split(":")
    minute = int(h) * 60 + int(m)
    if not (0 <= minute <= 24 * 60):
        raise ValueError(f"time of day out of range: {s!r}")
    return minute


def load_config(path: str | None = None) -> NBIConfig:
    """Load the config file; missing file yields pure defaults.

    Precedence: explicit ``path`` arg > ``$NBISLURM_CONFIG`` > default path.
    """
    if path is None:
        path = os.environ.get("NBISLURM_CONFIG", DEFAULT_CONFIG_PATH)
    p = Path(path).expanduser()
    values: dict[str, str] = {}
    if p.is_file():
        for line in p.read_text().splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            if "=" not in line:
                continue
            key, _, val = line.partition("=")
            values[key.strip()] = val.strip()
    return NBIConfig(values=values, path=str(p))


def write_config(cfg: dict, path: str) -> None:
    """Write a key=value config file (used by tests and ``session --init``)."""
    p = Path(path).expanduser()
    p.parent.mkdir(parents=True, exist_ok=True)
    lines = [f"{k}={v}" for k, v in cfg.items()]
    p.write_text("\n".join(lines) + "\n")
