"""Configuration file handling for NBI-Slurm (``~/.nbislurm.config``).

The paper specifies a user-level settings file, by default
``~/.nbislurm.config``, controlling queue defaults and the eco-mode windows.
The format is intentionally trivial (``key=value`` lines, ``#`` comments) so a
user can edit it without documentation.

Recognised keys (all optional):

``economy_mode``            1/0 — eco mode on by default (paper: default ON)
``queue``                   default partition name
``tmpdir``                  scratch directory for generated scripts
``email``                   notification address
``eco_weekday_windows``     comma list of HH:MM-HH:MM windows (Mon-Fri)
``eco_weekend_windows``     comma list of HH:MM-HH:MM windows (Sat-Sun)
``peak_hours``              comma list of HH:MM-HH:MM peak windows (daily)
``eco_horizon_days``        how far ahead the scheduler searches
``eco_min_delay_minutes``   do not schedule sooner than now + this
``carbon_trace``            optional CSV path for carbon-aware scoring
``history_file``            job archive path (default ``~/.nbi/history.jsonl``)
``eco_prediction``          1/0 — estimate durations from the job archive
``energy_cpu_watts``        per-allocated-core draw for the energy model
``default_cluster``         federation: member that anchors counterfactuals

Multi-cluster federation adds INI-style ``[cluster.<name>]`` stanzas; keys
inside a stanza are stored flat as ``cluster.<name>.<key>`` and read back
through :meth:`NBIConfig.cluster_names` / :meth:`NBIConfig.cluster_section`
(see :mod:`repro.core.federation` for the recognised per-cluster keys)::

    [cluster.green]
    kind = sim
    carbon_trace = ~/traces/hydro.csv
    nodes = 8
    cpus_per_node = 64

A file with no stanzas parses exactly as before — single-cluster users see
zero change.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from pathlib import Path

DEFAULT_CONFIG_PATH = "~/.nbislurm.config"

_DEFAULTS = {
    "economy_mode": "1",
    "queue": "",
    "tmpdir": "",
    "email": "",
    "eco_weekday_windows": "00:00-06:00",
    "eco_weekend_windows": "00:00-07:00,11:00-16:00",
    "peak_hours": "17:00-20:00",
    "eco_horizon_days": "14",
    "eco_min_delay_minutes": "0",
    "carbon_trace": "",
    "history_file": "",
    "eco_prediction": "1",
    "energy_cpu_watts": "12.0",
}


@dataclass
class NBIConfig:
    """Parsed contents of an ``.nbislurm.config`` file (plus defaults)."""

    values: dict = field(default_factory=dict)
    path: str = ""

    def get(self, key: str, default: str | None = None) -> str:
        if key in self.values:
            return self.values[key]
        if key in _DEFAULTS:
            return _DEFAULTS[key]
        if default is not None:
            return default
        raise KeyError(key)

    def get_bool(self, key: str) -> bool:
        return self.get(key).strip().lower() in ("1", "true", "yes", "on")

    def get_int(self, key: str) -> int:
        return int(self.get(key).strip())

    def get_windows(self, key: str) -> list[tuple[int, int]]:
        """Parse ``HH:MM-HH:MM[,HH:MM-HH:MM...]`` into minute-of-day pairs.

        An overnight window whose end precedes its start (``22:00-06:00``)
        is split at midnight into ``(22:00, 24:00)`` plus ``(00:00, 06:00)``
        — both halves apply on every day the key covers, so the early-
        morning half of a weekday window lands on weekday mornings.
        Malformed stanzas raise :class:`ValueError` naming the key and the
        offending fragment.
        """
        out: list[tuple[int, int]] = []
        raw = self.get(key).strip()
        if not raw:
            return out
        for part in raw.split(","):
            part = part.strip()
            if not part:
                continue
            lo_s, sep, hi_s = part.partition("-")
            if not sep or not lo_s.strip() or not hi_s.strip():
                raise ValueError(
                    f"malformed window {part!r} in {key}: expected HH:MM-HH:MM"
                )
            try:
                lo, hi = _parse_hhmm(lo_s), _parse_hhmm(hi_s)
            except ValueError as e:
                raise ValueError(
                    f"malformed window {part!r} in {key}: {e}"
                ) from None
            if hi >= lo:
                out.append((lo, hi))
            else:  # spans midnight: split into the two same-day halves
                out.append((lo, 24 * 60))
                if hi > 0:
                    out.append((0, hi))
        return out

    # -- federation stanzas ---------------------------------------------------

    def cluster_names(self) -> list[str]:
        """Names of the ``[cluster.<name>]`` stanzas, in declaration order."""
        seen: dict[str, None] = {}
        for key in self.values:
            parts = key.split(".")
            if len(parts) >= 3 and parts[0] == "cluster" and parts[1]:
                seen.setdefault(parts[1])
        return list(seen)

    def cluster_section(self, name: str) -> dict:
        """The flat key→value dict of one ``[cluster.<name>]`` stanza."""
        prefix = f"cluster.{name}."
        return {
            key[len(prefix):]: val
            for key, val in self.values.items()
            if key.startswith(prefix)
        }


def _parse_hhmm(s: str) -> int:
    """``HH:MM`` → minute of day. ``24:00`` is accepted as end-of-day."""
    s = s.strip()
    if ":" not in s:
        raise ValueError(f"malformed time of day {s!r}: expected HH:MM")
    h, _, m = s.partition(":")
    try:
        minute = int(h) * 60 + int(m)
    except ValueError:
        raise ValueError(f"malformed time of day {s!r}: expected HH:MM") from None
    if not (0 <= minute <= 24 * 60):
        raise ValueError(f"time of day out of range: {s!r}")
    return minute


def load_config(path: str | None = None) -> NBIConfig:
    """Load the config file; missing file yields pure defaults.

    Precedence: explicit ``path`` arg > ``$NBISLURM_CONFIG`` > default path.
    """
    if path is None:
        path = os.environ.get("NBISLURM_CONFIG", DEFAULT_CONFIG_PATH)
    p = Path(path).expanduser()
    values: dict[str, str] = {}
    section = ""
    if p.is_file():
        for line in p.read_text().splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            if line.startswith("[") and line.endswith("]"):
                # INI-style stanza ([cluster.green]); keys inside are
                # stored flat as "<section>.<key>"
                section = line[1:-1].strip()
                continue
            if "=" not in line:
                continue
            key, _, val = line.partition("=")
            key = key.strip()
            if section:
                key = f"{section}.{key}"
            values[key] = val.strip()
    return NBIConfig(values=values, path=str(p))


def write_config(cfg: dict, path: str) -> None:
    """Write a key=value config file (used by tests and ``session --init``)."""
    p = Path(path).expanduser()
    p.parent.mkdir(parents=True, exist_ok=True)
    lines = [f"{k}={v}" for k, v in cfg.items()]
    p.write_text("\n".join(lines) + "\n")
