"""NBI-Slurm core — the paper's contribution, reproduced in Python.

Programmatic use mirrors the paper's Perl API::

    from repro.core import Job, Opts

    opts = Opts.new(queue="main", threads=4, memory=8, time="1h")
    job1 = Job(name="step1", command="bash analyse.sh", opts=opts)
    jid = job1.run()

    job2 = Job(name="step2", command="python report.py --input results/")
    job2.set_dependencies(jid)
    job2.run()
"""

from .backend import (
    BatchSubmitError,
    SlurmBackend,
    get_backend,
    parse_sacct_output,
    reset_backend,
    reset_shared_sim,
)
from .gateway import (
    GatewayConnectionLost,
    GatewayError,
    GatewayServer,
    default_socket_path,
)
from .config import NBIConfig, load_config, write_config
from .eco import CarbonTrace, EcoDecision, EcoScheduler
from .ecocontroller import EcoController, HeldJob, ReleaseRecord
from .engine import BatchResult, QueueCache, SubmitEngine, get_queue_cache, reset_queue_cache
from .federation import (
    BacklogTracker,
    ClusterHandle,
    ClusterRegistry,
    FederatedBackend,
    Placement,
    Placer,
    array_base_id,
    join_cluster_id,
    split_cluster_id,
)
from .events import (
    EVENT_TYPES,
    TERMINAL_EVENTS,
    EventBus,
    JobEvent,
    PollingEventAdapter,
    diff_snapshots,
    terminal_event_for_state,
)
from .job import FILE_PLACEHOLDER, Job
from .launcher import InputSpec, Kraken2, Launcher, LauncherError, discover_launchers
from .manifest import Manifest
from .pipeline import Pipeline, PipelineError
from .queue import Queue, QueuedJob
from .resources import Opts, format_slurm_time, parse_memory_mb, parse_time_s
from .simcluster import SimCluster, SimJob, SimNode

__all__ = [
    "BatchResult", "QueueCache", "SubmitEngine",
    "get_queue_cache", "reset_queue_cache",
    "CarbonTrace", "EcoDecision", "EcoScheduler",
    "EcoController", "HeldJob", "ReleaseRecord",
    "BacklogTracker", "ClusterHandle", "ClusterRegistry", "FederatedBackend",
    "Placement", "Placer", "array_base_id",
    "join_cluster_id", "split_cluster_id",
    "EVENT_TYPES", "TERMINAL_EVENTS", "EventBus", "JobEvent",
    "PollingEventAdapter", "diff_snapshots", "terminal_event_for_state",
    "FILE_PLACEHOLDER", "Job", "Opts",
    "InputSpec", "Kraken2", "Launcher", "LauncherError", "discover_launchers",
    "Manifest", "Pipeline", "PipelineError",
    "Queue", "QueuedJob",
    "NBIConfig", "load_config", "write_config",
    "SimCluster", "SimJob", "SimNode",
    "BatchSubmitError", "SlurmBackend", "get_backend",
    "reset_backend", "reset_shared_sim",
    "GatewayConnectionLost", "GatewayError", "GatewayServer",
    "default_socket_path",
    "format_slurm_time", "parse_memory_mb", "parse_sacct_output", "parse_time_s",
]
