"""``Opts`` — SLURM resource directives with human-friendly parsing.

Python port of ``NBI::Opts``: encapsulates queue, threads, memory, wall-time,
email, job arrays and start time, accepting inputs such as ``"8GB"`` or
``"2h30m"`` and converting them to SLURM's expected formats (memory in
megabytes, time in ``D-HH:MM:SS``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Unit parsing
# ---------------------------------------------------------------------------

_MEM_UNITS = {
    "": 1,  # bare numbers are megabytes (SLURM convention)
    "k": 1 / 1024,
    "kb": 1 / 1024,
    "m": 1,
    "mb": 1,
    "g": 1024,
    "gb": 1024,
    "t": 1024 * 1024,
    "tb": 1024 * 1024,
}

_TIME_UNITS = {"s": 1, "m": 60, "h": 3600, "d": 86400}

_MEM_RE = re.compile(r"^\s*(\d+(?:\.\d+)?)\s*([a-zA-Z]*)\s*$")
_TIME_TOKEN_RE = re.compile(r"(\d+(?:\.\d+)?)\s*([smhd])", re.IGNORECASE)


def parse_memory_mb(value) -> int:
    """Parse a human-friendly memory amount into integer megabytes.

    ``64`` → 64 (MB); ``"8GB"`` → 8192; ``"500 MB"`` → 500; ``"1.5G"`` → 1536.
    """
    if isinstance(value, (int, float)):
        if value <= 0:
            raise ValueError(f"memory must be positive, got {value}")
        return int(value)
    m = _MEM_RE.match(str(value))
    if not m:
        raise ValueError(f"cannot parse memory: {value!r}")
    qty, unit = float(m.group(1)), m.group(2).lower()
    if unit not in _MEM_UNITS:
        raise ValueError(f"unknown memory unit {unit!r} in {value!r}")
    mb = int(round(qty * _MEM_UNITS[unit]))
    if mb <= 0:
        raise ValueError(f"memory must be positive, got {value!r}")
    return mb


def parse_time_s(value) -> int:
    """Parse a human-friendly duration into integer seconds.

    Accepted forms:
      * int/float      → hours               (paper: ``-t 12`` = 12 h)
      * ``"2h30m"``    → unit suffix tokens  (s/m/h/d)
      * ``"1d2h"``
      * ``"0-12:00:00"`` / ``"2-12:00"``  → SLURM D-HH:MM[:SS]
      * ``"12:30:00"`` → HH:MM:SS
      * ``"12:30"``    → HH:MM
    """
    if isinstance(value, (int, float)):
        if value <= 0:
            raise ValueError(f"time must be positive, got {value}")
        return int(round(float(value) * 3600))
    s = str(value).strip().lower()
    if not s:
        raise ValueError("empty time string")
    # SLURM D-HH:MM[:SS]
    m = re.match(r"^(\d+)-(\d{1,2}):(\d{1,2})(?::(\d{1,2}))?$", s)
    if m:
        d, h, mi, sec = (int(g or 0) for g in m.groups())
        return d * 86400 + h * 3600 + mi * 60 + sec
    # HH:MM[:SS]
    m = re.match(r"^(\d+):(\d{1,2})(?::(\d{1,2}))?$", s)
    if m:
        h, mi, sec = (int(g or 0) for g in m.groups())
        return h * 3600 + mi * 60 + sec
    # token form: 2h30m, 1d, 90s ...
    tokens = _TIME_TOKEN_RE.findall(s)
    if tokens and "".join(f"{q}{u}" for q, u in tokens).replace(" ", "") == s.replace(" ", ""):
        total = sum(float(q) * _TIME_UNITS[u.lower()] for q, u in tokens)
        return int(round(total))
    # bare number (string) → hours, mirroring the int behaviour
    if re.match(r"^\d+(\.\d+)?$", s):
        return int(round(float(s) * 3600))
    raise ValueError(f"cannot parse time: {value!r}")


def format_slurm_time(seconds: int) -> str:
    """Seconds → SLURM ``D-HH:MM:SS``."""
    d, rem = divmod(int(seconds), 86400)
    h, rem = divmod(rem, 3600)
    m, s = divmod(rem, 60)
    return f"{d}-{h:02d}:{m:02d}:{s:02d}"


# ---------------------------------------------------------------------------
# Opts
# ---------------------------------------------------------------------------


@dataclass
class Opts:
    """SLURM resource directives for one job (port of ``NBI::Opts``).

    Memory is stored in MB, wall-time in seconds; ``sbatch_directives()``
    renders SLURM's expected units.
    """

    queue: str = ""
    threads: int = 1
    memory_mb: int = 1024
    time_s: int = 3600
    email_address: str = ""
    email_type: str = "NONE"  # NONE|BEGIN|END|FAIL|ALL
    tmpdir: str = ""
    output_dir: str = ""  # -w in runjob: where stdout/err logs go
    begin: str = ""  # ISO8601 --begin directive (eco mode injects this)
    hold: bool = False  # submit held (--hold); EcoController releases later
    array_size: int = 0  # >0 → job array 0..array_size-1
    array_throttle: int = 0  # simultaneous array tasks (0 = unlimited)
    dependencies: list = field(default_factory=list)  # job ids (afterok)
    dependency_type: str = "afterok"
    nodes: int = 1
    ntasks: int = 1
    gres: str = ""  # e.g. "tpu:v5e:4"
    account: str = ""
    requeue: bool = True  # production default: jobs survive node failure
    extra: list = field(default_factory=list)  # raw pass-through directives

    # -- constructors -------------------------------------------------------

    @classmethod
    def new(cls, *, queue: str = "", threads: int = 1, memory="1GB",
            time="1h", email: str = "", email_type: str = "NONE",
            tmpdir: str = "", output_dir: str = "", **kw) -> "Opts":
        """Human-friendly constructor mirroring ``NBI::Opts->new``."""
        return cls(
            queue=queue,
            threads=int(threads),
            memory_mb=parse_memory_mb(memory),
            time_s=parse_time_s(time),
            email_address=email,
            email_type=email_type if email_type != "NONE" or not email else "END",
            tmpdir=tmpdir,
            output_dir=output_dir,
            **kw,
        )

    # -- mutators (human-friendly setters, chainable) -----------------------

    def set_memory(self, value) -> "Opts":
        self.memory_mb = parse_memory_mb(value)
        return self

    def set_time(self, value) -> "Opts":
        self.time_s = parse_time_s(value)
        return self

    def set_begin(self, iso: str) -> "Opts":
        self.begin = iso
        return self

    # -- rendering -----------------------------------------------------------

    @property
    def slurm_time(self) -> str:
        return format_slurm_time(self.time_s)

    def sbatch_directives(self, job_name: str = "job") -> list[str]:
        """Render the ``#SBATCH`` header lines for this option set."""
        lines = [f"#SBATCH --job-name={job_name}"]
        if self.queue:
            lines.append(f"#SBATCH --partition={self.queue}")
        lines.append(f"#SBATCH --nodes={self.nodes}")
        lines.append(f"#SBATCH --ntasks={self.ntasks}")
        lines.append(f"#SBATCH --cpus-per-task={self.threads}")
        lines.append(f"#SBATCH --mem={self.memory_mb}")
        lines.append(f"#SBATCH --time={self.slurm_time}")
        if self.account:
            lines.append(f"#SBATCH --account={self.account}")
        if self.gres:
            lines.append(f"#SBATCH --gres={self.gres}")
        out_dir = self.output_dir.rstrip("/") if self.output_dir else "."
        if self.array_size > 0:
            spec = f"0-{self.array_size - 1}"
            if self.array_throttle > 0:
                spec += f"%{self.array_throttle}"
            lines.append(f"#SBATCH --array={spec}")
            lines.append(f"#SBATCH --output={out_dir}/{job_name}.%A_%a.out")
            lines.append(f"#SBATCH --error={out_dir}/{job_name}.%A_%a.err")
        else:
            lines.append(f"#SBATCH --output={out_dir}/{job_name}.%j.out")
            lines.append(f"#SBATCH --error={out_dir}/{job_name}.%j.err")
        if self.email_address:
            lines.append(f"#SBATCH --mail-user={self.email_address}")
            lines.append(f"#SBATCH --mail-type={self.email_type}")
        if self.begin:
            lines.append(f"#SBATCH --begin={self.begin}")
        if self.hold:
            lines.append("#SBATCH --hold")
        if self.dependencies:
            dep = ":".join(str(d) for d in self.dependencies)
            lines.append(f"#SBATCH --dependency={self.dependency_type}:{dep}")
        if self.requeue:
            lines.append("#SBATCH --requeue")
        for raw in self.extra:
            raw = raw.strip()
            lines.append(raw if raw.startswith("#SBATCH") else f"#SBATCH {raw}")
        return lines

    def view(self) -> str:
        """Human-readable summary (port of ``NBI::Opts->view``)."""
        gb = self.memory_mb / 1024
        return (
            f"queue={self.queue or '(default)'} threads={self.threads} "
            f"memory={gb:g}GB time={self.slurm_time}"
            + (f" begin={self.begin}" if self.begin else "")
        )
