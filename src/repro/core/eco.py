"""``EcoScheduler`` — energy-aware scheduling ("eco mode").

Python port of ``NBI::EcoScheduler``, the paper's distinctive contribution.
Given a job's expected duration and a set of configurable windows, it finds
the next period satisfying a three-tier preference:

  Tier 1: the job *completes* within an eco window and avoids peak hours;
  Tier 2: the job *starts* in an eco window and avoids peak hours but may
          overrun the window;
  Tier 3: the job starts in an eco window and partially overlaps peak hours.

Default windows target weekday nights (00:00-06:00) and weekend off-peak
periods (00:00-07:00, 11:00-16:00), avoiding evening peaks (17:00-20:00);
all configurable through ``~/.nbislurm.config`` (see :mod:`repro.core.config`).

The scheduler's only side effect on a submission is injecting a
``--begin=<ISO8601>`` directive — no change to the underlying command.

Beyond the paper, the scheduler can *score* candidate starts against a
carbon-intensity trace (gCO2/kWh per hour-of-week): among candidates of the
best achievable tier it picks the lowest-carbon start. With no trace the
behaviour is exactly the paper's (earliest candidate of the best tier).

It can also consult a :class:`~repro.accounting.predict.RuntimePredictor`
(``predictor=`` / the ``decide``/``decide_many`` entry points): the tier is
then computed from the *historically observed* duration of this kind of job
instead of the padded request limit, so habitually short jobs complete
inside tier-1 windows. With no predictor — or an empty history — decisions
are bit-identical to the plain scheduler.
"""

from __future__ import annotations

import csv
from dataclasses import dataclass
from datetime import datetime, timedelta
from pathlib import Path

from .config import NBIConfig, load_config

# Minute-of-day window pair: (start_minute, end_minute), end exclusive-ish
MinuteWindow = tuple[int, int]

_DAY = 86400


@dataclass(frozen=True)
class EcoDecision:
    """Outcome of a scheduling query."""

    begin: datetime  # when the job should start
    tier: int  # 1/2/3 per the paper; 0 = no eco window found (run now)
    deferred: bool  # False when begin == now (job may start immediately)
    window_start: datetime | None = None
    window_end: datetime | None = None
    carbon_gco2_kwh: float | None = None  # mean intensity over the job span

    @property
    def begin_directive(self) -> str:
        """Value for ``--begin=`` (second resolution, ISO 8601)."""
        return self.begin.strftime("%Y-%m-%dT%H:%M:%S")


@dataclass(frozen=True)
class _Candidate:
    start: datetime
    window_start: datetime
    window_end: datetime
    tier: int
    carbon: float | None


class EcoScheduler:
    """Energy-aware window scheduler (three-tier preference).

    Parameters mirror the config file; any explicit keyword overrides it.
    """

    def __init__(
        self,
        config: NBIConfig | None = None,
        *,
        weekday_windows: list[MinuteWindow] | None = None,
        weekend_windows: list[MinuteWindow] | None = None,
        peak_hours: list[MinuteWindow] | None = None,
        horizon_days: int | None = None,
        min_delay_s: int | None = None,
        carbon_trace: "CarbonTrace | None" = None,
        predictor=None,
    ):
        cfg = config if config is not None else load_config()
        self.weekday_windows = (
            weekday_windows
            if weekday_windows is not None
            else cfg.get_windows("eco_weekday_windows")
        )
        self.weekend_windows = (
            weekend_windows
            if weekend_windows is not None
            else cfg.get_windows("eco_weekend_windows")
        )
        self.peak_hours = (
            peak_hours if peak_hours is not None else cfg.get_windows("peak_hours")
        )
        self.horizon_days = (
            horizon_days if horizon_days is not None else cfg.get_int("eco_horizon_days")
        )
        self.min_delay_s = (
            min_delay_s
            if min_delay_s is not None
            else cfg.get_int("eco_min_delay_minutes") * 60
        )
        if carbon_trace is None:
            trace_path = cfg.get("carbon_trace")
            carbon_trace = CarbonTrace.from_csv(trace_path) if trace_path else None
        self.carbon_trace = carbon_trace
        #: optional RuntimePredictor (duck-typed: .predict(default_s, name=,
        #: user=)); None ⇒ decisions use the requested limit verbatim.
        self.predictor = predictor

    # -- public API ---------------------------------------------------------

    def next_window(self, duration_s: int, now: datetime) -> EcoDecision:
        """Find the next start time for a ``duration_s``-second job.

        Returns the earliest candidate achieving the best achievable tier
        within the horizon (lowest-carbon candidate of that tier when a
        carbon trace is configured).
        """
        return self._decide(duration_s, now)

    def decide(
        self,
        duration_s: int,
        now: datetime,
        *,
        name: str = "",
        user: str = "",
        tool: str = "",
    ) -> EcoDecision:
        """Predictor-aware :meth:`next_window`.

        When a predictor is attached and the job is identifiable
        (``tool``, preferred, is matched verbatim against archived tool
        names; ``name`` is matched by stem), the decision is computed from
        the predicted duration instead of the requested limit. No
        predictor, no history for this key, or no identity ⇒ exactly
        ``next_window(duration_s, now)``.
        """
        return self._decide(
            self.effective_duration(duration_s, name, user, tool), now
        )

    def effective_duration(
        self, duration_s: int, name: str = "", user: str = "", tool: str = ""
    ) -> int:
        """The duration the tier maths will use (predicted when possible)."""
        if self.predictor is None or not (name or tool):
            return duration_s
        return self.predictor.predict(duration_s, name=name, user=user, tool=tool)

    def decide_many(
        self,
        durations_s: "list[int]",
        now: datetime,
        keys: "list[tuple[str, str]] | None" = None,
    ) -> "list[EcoDecision]":
        """Vectorized :meth:`next_window`: one decision per duration.

        The absolute eco/peak windows over the horizon are computed once and
        shared across the whole batch, so pricing N jobs costs one window
        scan instead of N. Decisions are bit-identical to calling
        ``next_window`` per job.

        ``keys`` (optional, one ``(name, user)`` or ``(name, user, tool)``
        tuple per duration) routes each duration through the attached
        predictor first — the batched equivalent of :meth:`decide`.
        """
        if not durations_s:
            return []
        if keys is not None:
            if len(keys) != len(durations_s):
                raise ValueError("keys must match durations_s 1:1")
            durations_s = [
                self.effective_duration(d, *key)
                for d, key in zip(durations_s, keys)
            ]
        earliest = now + timedelta(seconds=self.min_delay_s)
        horizon = now + timedelta(days=self.horizon_days)
        max_dur = max(max(durations_s), 1)
        eco_windows = self._absolute_eco_windows(earliest, horizon)
        peak_windows = self._absolute_peak_windows(
            earliest, horizon + timedelta(seconds=max_dur)
        )
        from repro.obs.metrics import get_registry, timed

        reg = get_registry()
        with timed(reg.histogram(
            "nbi_eco_decide_seconds", "decide_many batch pricing wall time"
        )):
            decisions = [
                self._decide(d, now, eco_windows=eco_windows,
                             peak_windows=peak_windows)
                for d in durations_s
            ]
        if reg.enabled:
            tiers = reg.counter(
                "nbi_eco_decisions_total", "eco pricing decisions, by tier",
                labels=("tier",),
            )
            for dec in decisions:
                tiers.labels(tier=str(dec.tier)).inc()
        return decisions

    def _decide(
        self,
        duration_s: int,
        now: datetime,
        *,
        eco_windows=None,
        peak_windows=None,
    ) -> EcoDecision:
        if duration_s <= 0:
            raise ValueError("duration must be positive")
        candidates = self._candidates(
            duration_s, now, eco_windows=eco_windows, peak_windows=peak_windows
        )
        if not candidates:
            # No eco windows configured / none in horizon → do not defer.
            return EcoDecision(
                begin=now,
                tier=0,
                deferred=False,
                carbon_gco2_kwh=self._mean_carbon(now, duration_s),
            )
        best_tier = min(c.tier for c in candidates)
        pool = [c for c in candidates if c.tier == best_tier]
        if self.carbon_trace is not None:
            chosen = min(pool, key=lambda c: (c.carbon, c.start))
        else:
            chosen = pool[0]  # candidates are generated in chronological order
        return EcoDecision(
            begin=chosen.start,
            tier=chosen.tier,
            deferred=chosen.start > now,
            window_start=chosen.window_start,
            window_end=chosen.window_end,
            carbon_gco2_kwh=chosen.carbon,
        )

    def begin_directive(self, duration_s: int, now: datetime) -> str | None:
        """The ``--begin`` value for a job, or None when no deferral needed."""
        decision = self.next_window(duration_s, now)
        return decision.begin_directive if decision.deferred else None

    def in_eco_window(self, t: datetime) -> bool:
        for ws, we in self._absolute_eco_windows(t, t + timedelta(seconds=1)):
            if ws <= t < we:
                return True
        return False

    def in_peak(self, t: datetime) -> bool:
        for ps, pe in self._absolute_peak_windows(t, t + timedelta(seconds=1)):
            if ps <= t < pe:
                return True
        return False

    def span_overlaps_peak(self, start: datetime, duration_s: int) -> bool:
        """Would a job running ``[start, start+duration_s)`` touch peak hours?

        The tier-≤2 condition, as a reusable predicate — the
        :class:`~repro.core.ecocontroller.EcoController` uses it to check
        that an *early* release keeps the tier promise made at submission.
        """
        end = start + timedelta(seconds=duration_s)
        return any(
            ps < end and start < pe
            for ps, pe in self._absolute_peak_windows(start, end)
        )

    def next_peak_start(self, now: datetime) -> datetime | None:
        """Start of the next peak period at or after ``now`` (for
        eco-preemption: a training run checkpoints itself at this boundary)."""
        horizon = now + timedelta(days=self.horizon_days)
        peaks = self._absolute_peak_windows(now, horizon)
        starts = [ps for ps, pe in peaks if pe > now]
        if not starts:
            return None
        first = min(starts)
        return max(first, now)

    # -- internals ------------------------------------------------------------

    def _windows_for_day(self, day: datetime) -> list[MinuteWindow]:
        return self.weekend_windows if day.weekday() >= 5 else self.weekday_windows

    def _absolute_eco_windows(self, lo: datetime, hi: datetime):
        """All eco windows as absolute (start, end) intersecting [lo, hi)."""
        out = []
        day = lo.replace(hour=0, minute=0, second=0, microsecond=0)
        while day < hi:
            for ws_min, we_min in self._windows_for_day(day):
                ws = day + timedelta(minutes=ws_min)
                we = day + timedelta(minutes=we_min)
                if we > lo and ws < hi:
                    out.append((ws, we))
            day += timedelta(days=1)
        out.sort()
        return out

    def _absolute_peak_windows(self, lo: datetime, hi: datetime):
        out = []
        day = (lo - timedelta(days=1)).replace(hour=0, minute=0, second=0, microsecond=0)
        while day < hi:
            for ps_min, pe_min in self.peak_hours:
                ps = day + timedelta(minutes=ps_min)
                pe = day + timedelta(minutes=pe_min)
                if pe > lo and ps < hi:
                    out.append((ps, pe))
            day += timedelta(days=1)
        out.sort()
        return out

    def _candidates(
        self,
        duration_s: int,
        now: datetime,
        *,
        eco_windows=None,
        peak_windows=None,
    ) -> list[_Candidate]:
        earliest = now + timedelta(seconds=self.min_delay_s)
        horizon = now + timedelta(days=self.horizon_days)
        dur = timedelta(seconds=duration_s)
        if eco_windows is None:
            eco_windows = self._absolute_eco_windows(earliest, horizon)
        cands: list[_Candidate] = []
        for ws, we in eco_windows:
            start = max(ws, earliest)
            if start >= we:
                continue  # window already over by the time we may start
            end = start + dur
            peaks = (
                peak_windows
                if peak_windows is not None
                else self._absolute_peak_windows(start, end)
            )
            overlaps_peak = any(ps < end and start < pe for ps, pe in peaks)
            fits_window = end <= we
            if fits_window and not overlaps_peak:
                tier = 1
            elif not overlaps_peak:
                tier = 2
            else:
                tier = 3
            cands.append(
                _Candidate(
                    start=start,
                    window_start=ws,
                    window_end=we,
                    tier=tier,
                    carbon=self._mean_carbon(start, duration_s),
                )
            )
        return cands

    def _mean_carbon(self, start: datetime, duration_s: int) -> float | None:
        if self.carbon_trace is None:
            return None
        return self.carbon_trace.mean_over(start, duration_s)


class CarbonTrace:
    """gCO2/kWh grid-intensity by hour-of-week (0 = Monday 00:00).

    CSV format: two columns ``hour_of_week,gco2_kwh`` (header optional),
    168 rows. Shorter traces wrap modulo their length.
    """

    def __init__(self, hourly: list[float]):
        if not hourly:
            raise ValueError("empty carbon trace")
        self.hourly = list(hourly)

    @classmethod
    def from_csv(cls, path: str) -> "CarbonTrace":
        rows: list[float] = []
        with Path(path).expanduser().open() as fh:
            for rec in csv.reader(fh):
                if not rec:
                    continue
                try:
                    rows.append(float(rec[-1]))
                except ValueError:
                    continue  # header
        return cls(rows)

    def at(self, t: datetime) -> float:
        hour_of_week = t.weekday() * 24 + t.hour
        return self.hourly[hour_of_week % len(self.hourly)]

    def mean_over(self, start: datetime, duration_s: int) -> float:
        """Mean intensity over [start, start+duration], hourly sampling."""
        hours = max(1, int(round(duration_s / 3600)))
        total = 0.0
        for i in range(hours):
            total += self.at(start + timedelta(hours=i))
        return total / hours
