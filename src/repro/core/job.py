"""``Job`` — a submittable SLURM job (port of ``NBI::Job``).

Holds a command (or list of commands) plus an :class:`~repro.core.resources.Opts`
object. ``script()`` generates a complete sbatch script; ``run()`` submits it
through the configured backend and returns the job identifier.

Job arrays: pass ``files`` (a list of inputs, or a path to a text file with
one input per line) and use the ``#FILE#`` placeholder inside the command —
the generated script maps ``SLURM_ARRAY_TASK_ID`` to the corresponding line.
"""

from __future__ import annotations

import os
import re
import tempfile
import time
from pathlib import Path

from .resources import Opts

FILE_PLACEHOLDER = "#FILE#"


class Job:
    """One SLURM job: name + command(s) + resource opts."""

    def __init__(
        self,
        name: str = "job",
        command: "str | list[str] | None" = None,
        opts: Opts | None = None,
        files: "list[str] | str | None" = None,
        backend=None,
        workdir: str = "",
        sim_duration_s: int | None = None,
    ):
        self.name = _sanitize_name(name)
        if command is None:
            commands: list[str] = []
        elif isinstance(command, str):
            commands = [command]
        else:
            commands = list(command)
        self.commands = commands
        self.opts = opts if opts is not None else Opts()
        self.workdir = workdir
        self.files = self._load_files(files)
        self.backend = backend
        self.jobid: int | None = None
        self.script_path: str | None = None
        # Simulator hint: how long this job "runs" in simulated time.
        self.sim_duration_s = sim_duration_s
        # Coalesced-array mode (set by SubmitEngine): one command per array
        # task, dispatched on SLURM_ARRAY_TASK_ID.
        self.task_commands: list[str] | None = None
        # Optional lines injected before the commands (module loads, env).
        self.prelude: list[str] = []
        # Optional lines injected after the commands (manifest patching).
        self.trailer: list[str] = []
        # Accounting metadata: originating tool/wrapper name (predictor key)
        # and the eco decision made at submission ({"tier": int, "deferred":
        # bool}); both flow into the job archive at completion.
        self.tool: str = ""
        self.eco_meta: dict | None = None

    # -- composition ---------------------------------------------------------

    def add_command(self, command: str) -> "Job":
        self.commands.append(command)
        return self

    def set_dependencies(self, jobids: "int | list[int]") -> "Job":
        if isinstance(jobids, int):
            jobids = [jobids]
        self.opts.dependencies = list(jobids)
        return self

    @staticmethod
    def _load_files(files) -> list[str]:
        if files is None:
            return []
        if isinstance(files, (list, tuple)):
            return [str(f) for f in files]
        # a path to a list file: one entry per line, '#' comments allowed
        entries = []
        for line in Path(files).read_text().splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                entries.append(line)
        return entries

    # -- script generation ----------------------------------------------------

    def script(self) -> str:
        """Generate the complete sbatch script for this job."""
        if not self.commands and not self.task_commands:
            raise ValueError(f"job {self.name!r} has no command")
        opts = self.opts
        if self.task_commands:
            opts.array_size = len(self.task_commands)
        elif self.files:
            opts.array_size = len(self.files)
        lines = ["#!/bin/bash"]
        lines += opts.sbatch_directives(self.name)
        lines += ["", "set -euo pipefail", ""]
        if self.workdir:
            lines.append(f"cd {_shquote(self.workdir)}")
        lines += self.prelude
        if self.task_commands:
            # Coalesced array: task k runs the k-th command verbatim.
            listing = " ".join(_shquote(c) for c in self.task_commands)
            lines.append(f"NBI_TASKS=({listing})")
            lines.append('eval "${NBI_TASKS[$SLURM_ARRAY_TASK_ID]}"')
        elif self.files:
            listing = " ".join(_shquote(f) for f in self.files)
            lines.append(f"NBI_FILES=({listing})")
            lines.append('FILE="${NBI_FILES[$SLURM_ARRAY_TASK_ID]}"')
            for cmd in self.commands:
                lines.append(cmd.replace(FILE_PLACEHOLDER, '"$FILE"'))
        else:
            lines += list(self.commands)
        lines += self.trailer
        return "\n".join(lines) + "\n"

    # -- submission ------------------------------------------------------------

    def prepare(self) -> "Job":
        """Generate and write the sbatch script (idempotent prerequisite of
        ``submit``; the SubmitEngine calls this before pipelining)."""
        self.script_path = self._write_script(self.script())
        return self

    def run(self, backend=None) -> int:
        """Submit the job; returns the SLURM job id."""
        be = backend or self.backend
        if be is None:
            from .backend import get_backend

            be = get_backend()
        self.prepare()
        self.jobid = be.submit(self)
        return self.jobid

    def _write_script(self, text: str) -> str:
        tmpdir = self.opts.tmpdir or os.environ.get("NBI_TMPDIR") or tempfile.gettempdir()
        Path(tmpdir).mkdir(parents=True, exist_ok=True)
        stamp = time.strftime("%Y%m%d-%H%M%S")
        path = Path(tmpdir) / f"nbi-{self.name}-{stamp}-{os.getpid()}-{id(self) & 0xFFFF}.sh"
        path.write_text(text)
        path.chmod(0o755)
        return str(path)


def _sanitize_name(name: str) -> str:
    name = re.sub(r"\s+", "_", name.strip()) or "job"
    return re.sub(r"[^A-Za-z0-9._+-]", "", name)


def _shquote(s: str) -> str:
    if re.match(r"^[A-Za-z0-9._/+=:,@%^-]+$", s):
        return s
    return "'" + s.replace("'", "'\"'\"'") + "'"
