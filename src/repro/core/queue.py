"""``Queue`` / ``QueuedJob`` — live queue querying (port of ``NBI::Queue``).

``Queue`` queries the workload manager (real ``squeue`` or the simulator)
and returns a list of :class:`QueuedJob` objects, optionally filtered by
user, status, name, or queue. ``QueuedJob`` is a lightweight data object
used by the queue-management tools (lsjobs, viewjobs, whojobs, waitjobs).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# Canonical squeue format used by the real backend; the simulator emits the
# same record schema so every tool works identically against both.
SQUEUE_FORMAT = "%i|%u|%P|%j|%T|%M|%L|%l|%N|%R|%C|%m"
SQUEUE_FIELDS = (
    "jobid", "user", "queue", "name", "state",
    "time_used", "time_left", "time_limit", "nodelist", "reason",
    "cpus", "memory",
)

ACTIVE_STATES = ("PENDING", "RUNNING", "SUSPENDED", "CONFIGURING", "COMPLETING")


@dataclass
class QueuedJob:
    """One row of the queue."""

    jobid: str = ""
    user: str = ""
    queue: str = ""
    name: str = ""
    state: str = ""
    time_used: str = ""
    time_left: str = ""
    time_limit: str = ""
    nodelist: str = ""
    reason: str = ""
    cpus: str = ""
    memory: str = ""
    #: federation member this row came from ("" on a plain backend; the
    #: jobid is then cluster-prefixed, e.g. ``green:123_4``)
    cluster: str = ""

    @property
    def jobid_num(self) -> int:
        """Numeric job id (``123_4`` → 123; ``green:123_4`` → 123)."""
        m = re.match(r"^(?:[^:\s]+:)?(\d+)", self.jobid)
        return int(m.group(1)) if m else -1

    @property
    def array_task(self) -> "int | None":
        """Array task index (``123_4`` → 4); None for plain jobs."""
        m = re.match(r"^(?:[^:\s]+:)?\d+_(\d+)$", self.jobid)
        return int(m.group(1)) if m else None

    def is_active(self) -> bool:
        return self.state in ACTIVE_STATES

    @classmethod
    def from_record(cls, rec: dict) -> "QueuedJob":
        job = cls(**{k: str(rec.get(k, "")) for k in SQUEUE_FIELDS})
        job.cluster = str(rec.get("cluster", ""))
        return job

    def to_dict(self) -> dict:
        """JSON payload with numeric fields typed (one dialect across all
        ``--json`` tools: whojobs emits ints, so must lsjobs)."""
        out = {k: getattr(self, k) for k in SQUEUE_FIELDS}
        for key in ("cpus", "memory"):
            try:
                out[key] = int(out[key])
            except ValueError:
                pass  # squeue oddities ("4000Mc") stay verbatim
        if self.cluster:  # federation only — single-cluster JSON unchanged
            out["cluster"] = self.cluster
        return out

    @classmethod
    def from_squeue_line(cls, line: str) -> "QueuedJob | None":
        parts = line.rstrip("\n").split("|")
        if len(parts) != len(SQUEUE_FIELDS):
            return None
        return cls(**dict(zip(SQUEUE_FIELDS, (p.strip() for p in parts))))


@dataclass
class Queue:
    """A filtered snapshot of the queue (fetched on construction).

    When the backend supports **server-side filter pushdown** (the gateway
    thin client's ``queue_filtered``), the ``user``/``state``/``cluster``/
    ``ids`` filters travel with the RPC so the daemon ships only the
    matching rows instead of the whole 100k-job snapshot. Every filter is
    *re-applied* locally afterwards — pushdown is a transport optimisation,
    never a semantic one, so results are identical whether or not the
    backend understood the filters (an old daemon simply returns the full
    snapshot and the rows are trimmed here as before).
    """

    user: str | None = None
    state: "str | list[str] | None" = None
    name: str | None = None  # regex on job name
    queue: str | None = None  # partition
    cluster: str | None = None  # federation member
    jobids: "list | None" = None  # job ids (exact / array-base / bare forms)
    backend: object = None
    jobs: list[QueuedJob] = field(default_factory=list)

    def __post_init__(self):
        self.refresh()

    def refresh(self) -> "Queue":
        be = self.backend
        if be is None:
            from .backend import get_backend

            be = get_backend()
            self.backend = be
        qf = getattr(be, "queue_filtered", None)
        if qf is not None:
            raw = qf(
                user=self.user or None,
                states=self._states() or None,
                cluster=self.cluster if self.cluster is not None else None,
                ids=[str(i) for i in self.jobids] if self.jobids else None,
            )
        else:
            raw = be.queue()
        rows = [QueuedJob.from_record(r) for r in raw]
        self.jobs = [j for j in rows if self._match(j)]
        return self

    def _states(self) -> list[str]:
        if not self.state:
            return []
        states = [self.state] if isinstance(self.state, str) else self.state
        return [s.upper() for s in states]

    def _match(self, j: QueuedJob) -> bool:
        if self.user and j.user != self.user:
            return False
        if self.state and j.state not in self._states():
            return False
        if self.name and not re.search(self.name, j.name):
            return False
        if self.queue and j.queue != self.queue:
            return False
        if self.cluster is not None and j.cluster != self.cluster:
            return False
        if self.jobids:
            from .federation import id_covers

            if not any(id_covers(j.jobid, req) for req in self.jobids):
                return False
        return True

    # -- conveniences used by the CLI tools ----------------------------------

    def __len__(self) -> int:
        return len(self.jobs)

    def __iter__(self):
        return iter(self.jobs)

    def ids(self) -> list[str]:
        return [j.jobid for j in self.jobs]

    def base_ids(self) -> list[int]:
        """Unique sbatch-level ids, array tasks collapsed (order preserved)."""
        seen: dict[int, None] = {}
        for j in self.jobs:
            seen.setdefault(j.jobid_num)
        return list(seen)

    def by_array(self) -> dict[int, list[QueuedJob]]:
        """Group rows by base id (an N-task array → one entry of N rows)."""
        out: dict[int, list[QueuedJob]] = {}
        for j in self.jobs:
            out.setdefault(j.jobid_num, []).append(j)
        return out

    def by_user(self) -> dict[str, list[QueuedJob]]:
        out: dict[str, list[QueuedJob]] = {}
        for j in self.jobs:
            out.setdefault(j.user, []).append(j)
        return out

    def cancel(self, jobids: "list[str] | None" = None) -> int:
        """Cancel the given ids (default: everything in this snapshot)."""
        ids = jobids if jobids is not None else self.ids()
        if not ids:
            return 0
        self.backend.cancel(ids)
        return len(ids)
