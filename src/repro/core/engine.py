"""``SubmitEngine`` — batch submission with job-array coalescing.

Every ``Job.run()`` is one synchronous ``sbatch`` fork; submitting a
thousand-job sweep that way costs a thousand subprocess round-trips and a
thousand scheduler insertions. The engine takes N jobs at once and:

* **coalesces** homogeneous jobs — same resources/partition, differing only
  in their command — into a single SLURM job array (one ``sbatch`` call,
  one generated script, per-task command dispatch via
  ``SLURM_ARRAY_TASK_ID``);
* **pipelines** whatever cannot be coalesced through the backend's
  ``submit_many`` (a bounded thread pool on the real ``SlurmBackend``);
* prices eco deferral for the whole batch with one
  :meth:`~repro.core.eco.EcoScheduler.decide_many` window scan instead of
  N independent scans.

:class:`QueueCache` is the companion read-side optimisation: a TTL cache
over ``backend.queue()`` shared by the queue tools (lsjobs / viewjobs /
whojobs / waitjobs) and the engine's completion tracking, with explicit
invalidation on submit/cancel so tools never act on a stale snapshot of
their own mutations.
"""

from __future__ import annotations

import threading
import time as _time
from dataclasses import dataclass, field
from datetime import datetime

from .job import Job
from .queue import SQUEUE_FIELDS  # noqa: F401  (re-exported schema for callers)
from repro.obs.metrics import get_registry, timed


# ---------------------------------------------------------------------------
# QueueCache
# ---------------------------------------------------------------------------

#: inner-backend methods that mutate simulated/real cluster state; calls are
#: forwarded and the cached snapshot is dropped afterwards.
_MUTATORS = ("advance", "run_until_idle", "fail_node", "restore_node")


class QueueCache:
    """TTL + event cache over a backend's ``queue()`` (Backend-protocol
    compatible).

    Wraps any backend (``SlurmBackend`` or ``SimCluster``) and serves
    repeated ``queue()`` calls from a snapshot for ``ttl_s`` seconds.
    ``submit``/``cancel``/``release`` are forwarded and invalidate the
    snapshot, as do the simulator's clock/state mutators, so a caller can
    never observe the queue missing its own just-submitted job.

    When the wrapped backend announces transitions on an
    :class:`~repro.core.events.EventBus` (the simulator does natively),
    the cache also subscribes and drops its snapshot on every event — the
    snapshot then goes stale the *instant* the cluster changes rather
    than only when the TTL runs out, and stays valid indefinitely while
    nothing happens. Construction binds automatically; ``bind_bus()``
    attaches an external bus (e.g. a ``PollingEventAdapter``'s).

    ``clock`` is injectable for deterministic tests (defaults to
    ``time.monotonic``).
    """

    def __init__(self, backend, ttl_s: float = 2.0, clock=_time.monotonic):
        self.inner = backend
        self.ttl_s = float(ttl_s)
        self._clock = clock
        self._rows: list[dict] | None = None
        self._fetched_at: float = 0.0
        # Held across the whole check-then-refresh in queue(): concurrent
        # readers (gateway daemon connection threads) single-flight through
        # one backend poll per invalidation window instead of racing N
        # refreshes and tearing each other's snapshots. RLock because a
        # refresh against the simulator can emit events that re-enter
        # invalidate() on this same thread.
        self._mu = threading.RLock()
        self._bus_token: "tuple | None" = None  # (bus, token)
        #: monotonically bumped whenever the cached snapshot changes
        #: identity — on every refresh and on every invalidation — so a
        #: ``(generation, rows)`` pair is immutable: one generation never
        #: maps to two different snapshots. The gateway's snapshot encoder
        #: keys its pre-serialised wire frames on this.
        self.generation = 0
        # observability (the queue-tools benchmark reports these)
        self.polls = 0  # real backend.queue() calls
        self.hits = 0  # calls served from the snapshot
        self.event_invalidations = 0
        bus = getattr(backend, "bus", None)
        if bus is not None:
            self.bind_bus(bus)

    # -- Backend protocol -----------------------------------------------------

    def queue(self) -> list[dict]:
        reg = get_registry()
        with self._mu:
            now = self._clock()
            if self._rows is not None and now - self._fetched_at < self.ttl_s:
                self.hits += 1
                reg.counter(
                    "nbi_queuecache_hits_total", "queue() calls served from snapshot"
                ).inc()
                return self._rows
            with timed(reg.histogram(
                "nbi_queuecache_refresh_seconds", "backend.queue() refresh latency"
            )):
                rows = self.inner.queue()
            self._rows = rows
            self._fetched_at = now
            self.generation += 1
            self.polls += 1
            reg.counter(
                "nbi_queuecache_polls_total", "real backend.queue() polls"
            ).inc()
            return rows

    def submit(self, job) -> int:
        jobid = self.inner.submit(job)
        self.invalidate()
        return jobid

    def submit_many(self, jobs) -> list[int]:
        inner_many = getattr(self.inner, "submit_many", None)
        ids = inner_many(jobs) if inner_many else [self.inner.submit(j) for j in jobs]
        self.invalidate()
        return ids

    def cancel(self, jobids: list) -> None:
        self.inner.cancel(jobids)
        self.invalidate()

    def release(self, jobids: list) -> None:
        self.inner.release(jobids)
        self.invalidate()

    def nodes_info(self) -> list[dict]:
        return self.inner.nodes_info()

    # -- cache control ---------------------------------------------------------

    def invalidate(self) -> None:
        """Drop the snapshot; the next ``queue()`` re-polls the backend."""
        with self._mu:
            if self._rows is not None:
                self.generation += 1
            self._rows = None

    def snapshot_generation(self) -> "int | None":
        """Generation of the currently *valid* snapshot, or None when a
        fresh ``queue()`` would re-poll (invalidated or TTL-lapsed).

        Deliberately lock-free — plain attribute reads — so the gateway's
        serve loop can check frame currency without ever blocking behind a
        refresh in progress. The race is benign: at worst a frame one
        generation behind is served once more, and generations are
        immutable so it is a *consistent* stale snapshot, never a torn one.
        """
        rows = self._rows
        if rows is None:
            return None
        if self._clock() - self._fetched_at >= self.ttl_s:
            return None
        return self.generation

    def queue_with_generation(self) -> "tuple[list, int]":
        """Atomic ``(rows, generation)`` pair — the seam the gateway's
        snapshot encoder refreshes through (a concurrent invalidation
        cannot slip between serving the rows and reading their tag)."""
        with self._mu:
            return self.queue(), self.generation

    def bind_bus(self, bus) -> None:
        """Invalidate on every :class:`~repro.core.events.JobEvent` on ``bus``."""
        if self._bus_token is not None:
            old_bus, token = self._bus_token
            if old_bus is bus:
                return
            old_bus.unsubscribe(token)
        self._bus_token = (bus, bus.subscribe(self._on_event))

    def unbind_bus(self) -> None:
        """Detach from the bus — a discarded cache must stop receiving
        events (the bus otherwise keeps it alive and busy forever)."""
        if self._bus_token is not None:
            bus, token = self._bus_token
            bus.unsubscribe(token)
            self._bus_token = None

    def _on_event(self, event) -> None:
        with self._mu:
            if self._rows is not None:
                self.event_invalidations += 1
                # counted only on a real invalidation (bounded by polls),
                # never on the per-event fast path — native emission stays
                # obs-free
                get_registry().counter(
                    "nbi_queuecache_event_invalidations_total",
                    "snapshots dropped by bus events",
                ).inc()
                self.generation += 1
            self._rows = None

    def __getattr__(self, name):
        # Delegate simulator conveniences (get, accounting, jobs, now, ...);
        # state mutators additionally invalidate the snapshot.
        attr = getattr(self.inner, name)
        if name in _MUTATORS:
            def wrapped(*a, **kw):
                out = attr(*a, **kw)
                self.invalidate()
                return out

            return wrapped
        return attr


_SHARED_CACHE: QueueCache | None = None


def get_queue_cache(backend=None, ttl_s: float | None = None) -> QueueCache:
    """Process-wide shared cache so every tool dedupes against one snapshot.

    A fresh wrapper is built when the resolved backend changes (e.g. a test
    reset the shared simulator). TTL: ``$REPRO_QUEUE_TTL`` seconds, default 2.
    """
    global _SHARED_CACHE
    import os

    from .backend import get_backend

    inner = backend if backend is not None else get_backend()
    if isinstance(inner, QueueCache):
        return inner
    if ttl_s is None:
        ttl_s = float(os.environ.get("REPRO_QUEUE_TTL", "2.0"))
    if _SHARED_CACHE is None or _SHARED_CACHE.inner is not inner:
        if _SHARED_CACHE is not None:
            _SHARED_CACHE.unbind_bus()  # don't leak the stale cache
        _SHARED_CACHE = QueueCache(inner, ttl_s=ttl_s)
    else:
        _SHARED_CACHE.ttl_s = float(ttl_s)
    return _SHARED_CACHE


def reset_queue_cache() -> None:
    """Forget the shared cache (test isolation)."""
    global _SHARED_CACHE
    if _SHARED_CACHE is not None:
        _SHARED_CACHE.unbind_bus()
    _SHARED_CACHE = None


def _invalidate_shared_for(backend) -> None:
    """Invalidate the shared snapshot if it fronts this backend — a writer
    going straight to the backend must not leave stale shared reads."""
    if _SHARED_CACHE is None:
        return
    inner = backend.inner if isinstance(backend, QueueCache) else backend
    if _SHARED_CACHE.inner is inner:
        _SHARED_CACHE.invalidate()


# ---------------------------------------------------------------------------
# SubmitEngine
# ---------------------------------------------------------------------------


@dataclass
class BatchResult:
    """Outcome of one :meth:`SubmitEngine.submit_many` call."""

    ids: list[str] = field(default_factory=list)  # per input job, "123" or "123_7"
    base_ids: list = field(default_factory=list)  # unique sbatch-level ids
    sbatch_calls: int = 0  # submissions actually issued
    coalesced: int = 0  # input jobs folded into arrays
    eco_deferred: int = 0  # submissions given a --begin directive
    placements: set = field(default_factory=set)  # clusters used (federation)

    def __len__(self) -> int:
        return len(self.ids)


def _coalesce_key(job: Job):
    """Grouping key: jobs sharing it differ only in their single command.

    ``None`` marks a job that must be submitted on its own (multi-command
    bodies, explicit file arrays, pre-set array sizes, per-job preludes).
    """
    if len(job.commands) != 1 or job.files or job.opts.array_size:
        return None
    if job.prelude or job.trailer or getattr(job, "task_commands", None):
        return None
    o = job.opts
    return (
        job.workdir,
        job.sim_duration_s,
        getattr(job, "tool", ""),  # accounting key must survive coalescing
        getattr(job, "cluster", ""),  # pinned members never coalesce across
        o.queue, o.threads, o.memory_mb, o.time_s,
        o.email_address, o.email_type, o.tmpdir, o.output_dir,
        o.begin, o.array_throttle,
        tuple(str(d) for d in o.dependencies), o.dependency_type,
        o.nodes, o.ntasks, o.gres, o.account, o.requeue,
        tuple(o.extra),
    )


class SubmitEngine:
    """Submit N jobs at scale: coalesce, batch, defer, track.

    Parameters
    ----------
    backend:
        Any Backend-protocol object; default resolves via ``get_backend()``.
    coalesce:
        Fold homogeneous single-command jobs into SLURM job arrays
        (``min_array_size`` controls the smallest group worth folding).
    eco:
        ``True`` → price the whole batch through one
        ``EcoScheduler.decide_many`` scan and inject ``--begin``.
        Default ``False``: callers like runjob decide per-job policy
        themselves before handing jobs over.
    predictor:
        Optional :class:`~repro.accounting.predict.RuntimePredictor`; eco
        decisions are then priced from each job's historical runtime
        instead of its padded request limit. With no predictor (or an
        empty history) decisions are bit-identical to before.
    controller:
        Optional :class:`~repro.core.ecocontroller.EcoController` (implies
        eco pricing). Deferred units are then submitted HELD — no
        ``--begin`` — and registered with the controller, which releases
        them reactively no later than the static deadline. ``None``
        (default) keeps the static ``--begin`` path bit-identical.
    now:
        Injectable clock for deterministic eco decisions.
    """

    def __init__(
        self,
        backend=None,
        *,
        coalesce: bool = True,
        min_array_size: int = 2,
        eco: bool = False,
        scheduler=None,
        predictor=None,
        controller=None,
        now: datetime | None = None,
        cache: QueueCache | None = None,
    ):
        if backend is None:
            from .backend import get_backend

            backend = get_backend()
        self.backend = backend
        self.coalesce = coalesce
        self.min_array_size = max(2, int(min_array_size))
        self.controller = controller
        self.eco = eco or controller is not None
        self.scheduler = scheduler
        if scheduler is None and controller is not None and (
            getattr(controller, "registry", None) is None
        ):
            # a federation-aware controller leaves the engine free to price
            # each placed group through its member's own scheduler
            self.scheduler = controller.scheduler
        self.predictor = predictor
        self.now = now
        self.cache = cache

    # -- submission -----------------------------------------------------------

    def submit_many(self, jobs: "list[Job]") -> BatchResult:
        """Submit every job; returns per-job ids in input order."""
        jobs = list(jobs)
        result = BatchResult(ids=[""] * len(jobs))
        _reg = get_registry()  # per-batch instrumentation, never per-job
        _t0 = _time.perf_counter() if _reg.enabled else 0.0

        # 1. partition into coalescible groups and singletons
        groups: dict[object, list[int]] = {}
        singles: list[int] = []
        if self.coalesce:
            for i, job in enumerate(jobs):
                key = _coalesce_key(job)
                if key is None:
                    singles.append(i)
                else:
                    groups.setdefault(key, []).append(i)
            for key, members in list(groups.items()):
                if len(members) < self.min_array_size:
                    singles.extend(members)
                    del groups[key]
            singles.sort()
        else:
            singles = list(range(len(jobs)))

        # 2. materialise one array Job per group
        units: list[tuple[Job, list[int]]] = []  # (submission unit, member idxs)
        for members in groups.values():
            first = jobs[members[0]]
            array_job = Job(
                # per-job names collapse to one array-level name; tasks stay
                # addressable by index (base_k), not by their original name
                name=_array_name([jobs[i].name for i in members]),
                opts=_clone_opts(first.opts),
                workdir=first.workdir,
                sim_duration_s=first.sim_duration_s,
            )
            array_job.task_commands = [jobs[i].commands[0] for i in members]
            array_job.eco_meta = getattr(first, "eco_meta", None)
            array_job.tool = getattr(first, "tool", "")
            if getattr(first, "cluster", ""):  # the pin survives coalescing
                array_job.cluster = first.cluster
            units.append((array_job, members))
            result.coalesced += len(members)
        for i in singles:
            units.append((jobs[i], [i]))

        # 2b. federation: route every submission unit to a member cluster
        # (a coalesced array lands whole — arrays cannot span clusters).
        # Pre-placed/pinned units (job.cluster already set) are respected.
        placer = getattr(self.backend, "placer", None)
        registry = getattr(self.backend, "registry", None)
        if placer is not None:
            clock = self.now or datetime.now()
            unplaced = [u for u, _ in units if not getattr(u, "cluster", "")]
            eco_flags = [
                self.eco or bool(
                    (getattr(u, "eco_meta", None) or {}).get("deferred")
                )
                for u in unplaced
            ]
            if unplaced and hasattr(placer, "place_jobs"):
                # one batched (vectorized) placement pass; identical
                # order and charging to the per-unit place() loop
                placements = placer.place_jobs(unplaced, clock, eco_flags)
                for unit, placement in zip(unplaced, placements):
                    unit.cluster = placement.cluster
            else:  # duck-typed placers only need place()
                for unit, eco_unit in zip(unplaced, eco_flags):
                    unit.cluster = placer.place(unit, clock, eco=eco_unit).cluster
            result.placements = {
                getattr(u, "cluster", "") for u, _ in units
            }

        # 3. eco: one window scan prices the whole batch — per placed
        # cluster when federated, so each member prices through its own
        # windows and carbon trace
        if self.eco:
            clock = self.now or datetime.now()
            pending = [(u, m) for u, m in units if not u.opts.begin]
            if registry is not None and self.scheduler is None:
                by_cluster: dict[str, list] = {}
                for u, m in pending:
                    by_cluster.setdefault(getattr(u, "cluster", ""), []).append((u, m))
                eco_groups = sorted(by_cluster.items())
            else:
                eco_groups = [("", pending)]
            deferred_units: list[tuple[Job, object]] = []  # (unit, decision)
            for cname, group in eco_groups:
                sched = self._batch_scheduler(cname, registry)
                # history-driven durations (identity when no predictor /
                # history); tool is the verbatim archive key, name falls
                # back by stem
                keys = None
                if getattr(sched, "predictor", None) is not None:
                    keys = [(u.name, "", getattr(u, "tool", "")) for u, _ in group]
                decisions = sched.decide_many(
                    [u.opts.time_s for u, _ in group], clock, keys=keys
                )
                for (unit, _), dec in zip(group, decisions):
                    unit.eco_meta = {"tier": dec.tier, "deferred": dec.deferred}
                    if dec.deferred:
                        if self.controller is not None:
                            # eco v2: hold now, release reactively (deadline
                            # = the exact begin the static path would set)
                            unit.opts.hold = True
                            unit.eco_meta = self.controller.hold_meta(
                                dec,
                                sched.effective_duration(
                                    unit.opts.time_s, unit.name, "",
                                    getattr(unit, "tool", ""),
                                ),
                            )
                            deferred_units.append((unit, dec))
                        else:
                            unit.opts.set_begin(dec.begin_directive)
                        result.eco_deferred += 1

        # 4. write scripts, then pipeline the actual submissions
        prepared = [unit.prepare() for unit, _ in units]
        submit_many = getattr(self.backend, "submit_many", None)
        if submit_many is not None:
            base_ids = submit_many(prepared)
        else:
            base_ids = [self.backend.submit(u) for u in prepared]
        if self.cache is not None:
            self.cache.invalidate()
        _invalidate_shared_for(self.backend)
        if self.eco and self.controller is not None:
            clock = self.now or datetime.now()
            unit_to_base = {id(u): b for (u, _), b in zip(units, base_ids)}
            for unit, dec in deferred_units:
                self.controller.register(
                    unit_to_base[id(unit)], dec, now=clock,
                    duration_s=unit.eco_meta.get("duration_s"),
                )

        # 5. map ids back onto the input jobs
        for (unit, members), base in zip(units, base_ids):
            unit.jobid = base
            if len(members) > 1 or unit is not jobs[members[0]]:
                for task, i in enumerate(members):
                    jobs[i].jobid = base
                    jobs[i].script_path = unit.script_path
                    result.ids[i] = f"{base}_{task}"
            else:
                result.ids[members[0]] = str(base)
        result.base_ids = list(base_ids)
        result.sbatch_calls = len(units)

        # 6. journal engine-made eco decisions for the accounting layer
        # (real SLURM cannot report them back through sacct) — one batched
        # write, not one file open per task
        if self.eco:
            from repro.accounting import log_submissions

            entries = []
            for (unit, members), base in zip(units, base_ids):
                if not unit.eco_meta:
                    continue
                if len(members) > 1 or unit is not jobs[members[0]]:
                    entries += [(f"{base}_{t}", unit.tool, unit.eco_meta)
                                for t in range(len(members))]
                else:
                    entries.append((str(base), unit.tool, unit.eco_meta))
            log_submissions(entries)

        if _reg.enabled:
            _reg.counter(
                "nbi_engine_batches_total", "submit_many calls"
            ).inc()
            _reg.counter(
                "nbi_engine_jobs_total", "jobs submitted through the engine"
            ).inc(len(jobs))
            _reg.counter(
                "nbi_engine_coalesced_jobs_total",
                "input jobs folded into job arrays",
            ).inc(result.coalesced)
            _reg.counter(
                "nbi_engine_sbatch_calls_total", "submission units issued"
            ).inc(result.sbatch_calls)
            _reg.counter(
                "nbi_engine_eco_deferred_total",
                "submission units deferred by eco pricing",
            ).inc(result.eco_deferred)
            if jobs:
                _reg.histogram(
                    "nbi_engine_batch_size", "jobs per submit_many batch",
                    buckets=(1, 2, 5, 10, 25, 50, 100, 250, 500,
                             1000, 2500, 5000, 10000),
                ).observe(len(jobs))
                # coalesce ratio: fraction of the batch that rode an array
                _reg.gauge(
                    "nbi_engine_coalesce_ratio",
                    "coalesced fraction of the last batch",
                ).set(result.coalesced / len(jobs))
            _reg.histogram(
                "nbi_engine_submit_seconds", "submit_many wall time"
            ).observe(_time.perf_counter() - _t0)
        return result

    def _batch_scheduler(self, cluster: str, registry):
        """The scheduler pricing one placed group.

        An explicit ``scheduler=`` always wins; a federation member prices
        through its own per-cluster :class:`EcoScheduler`; otherwise one is
        built from config — exactly the pre-federation behaviour. The
        engine's predictor is attached through a copy so a caller-supplied
        scheduler keeps exactly the behaviour it was configured with.
        """
        sched = self.scheduler
        if sched is None and cluster and registry is not None:
            sched = registry.get(cluster).scheduler
        if sched is None:
            from .eco import EcoScheduler

            return EcoScheduler(predictor=self.predictor)
        if self.predictor is not None and getattr(sched, "predictor", None) is None:
            import copy

            sched = copy.copy(sched)
            sched.predictor = self.predictor
        return sched

    # -- completion tracking ---------------------------------------------------

    def states(self, result: BatchResult) -> dict[str, str]:
        """One cached poll → state per submitted id (gone ⇒ ``COMPLETED``)."""
        be = self.cache if self.cache is not None else self.backend
        live: dict[str, str] = {}
        compressed: list[tuple[int, set, str]] = []  # pending "123_[0-9%4]" rows
        for r in be.queue():
            jid, state = r["jobid"], r["state"]
            live[jid] = state
            parsed = _parse_array_spec(jid)
            if parsed is not None:
                compressed.append((*parsed, state))
        from .federation import array_base_id

        out: dict[str, str] = {}
        for jid in result.ids:
            state = live.get(jid) or live.get(array_base_id(jid))
            if state is None:
                state = _compressed_state(jid, compressed) or "COMPLETED"
            out[jid] = state
        return out

    def pending(self, result: BatchResult) -> list[str]:
        """Ids from this batch still visible in the queue."""
        from .queue import ACTIVE_STATES

        return [j for j, s in self.states(result).items() if s in ACTIVE_STATES]


def _clone_opts(opts):
    from copy import deepcopy

    return deepcopy(opts)


def _array_name(names: "list[str]") -> str:
    """Display name for a coalesced array: the shared name if uniform, else
    the common stem of the members (``j0..j999`` → ``j``), else ``batch``."""
    uniq = set(names)
    if len(uniq) == 1:
        return names[0]
    import os.path

    stem = os.path.commonprefix(names).rstrip("0123456789").rstrip("-_.")
    return stem or "batch"


_ARRAY_SPEC_RE = None


def _parse_array_spec(jobid: str):
    """Parse squeue's compressed pending-array id ``123_[0-4,7%2]``.

    Real SLURM reports a pending array as ONE row in this form (tasks only
    get their own ``123_k`` rows once running); the simulator always emits
    expanded rows. A federation prefix (``green:123_[0-4]``) is kept on the
    base. Returns ``(base, {task, ...})`` or None.
    """
    global _ARRAY_SPEC_RE
    import re

    if _ARRAY_SPEC_RE is None:
        _ARRAY_SPEC_RE = re.compile(
            r"^((?:[^:\s]+:)?\d+)_\[([0-9,\-]+)(?:%\d+)?\]$"
        )
    m = _ARRAY_SPEC_RE.match(jobid)
    if not m:
        return None
    tasks: set[int] = set()
    for part in m.group(2).split(","):
        if "-" in part:
            lo, hi = part.split("-")
            tasks.update(range(int(lo), int(hi) + 1))
        elif part:
            tasks.add(int(part))
    return m.group(1), tasks


def _compressed_state(jid: str, compressed) -> "str | None":
    from .federation import join_cluster_id, split_cluster_id

    cluster, bare = split_cluster_id(jid)  # cluster names may contain "_"
    if "_" not in bare:
        return None
    base_s, _, task_s = bare.partition("_")
    if not task_s.isdigit():
        return None
    base_key, task = join_cluster_id(cluster, base_s), int(task_s)
    for cbase, tasks, state in compressed:
        if cbase == base_key and task in tasks:
            return state
    return None
