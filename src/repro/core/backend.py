"""Workload-manager backends.

``SlurmBackend`` shells out to real ``sbatch``/``squeue``/``scancel``;
``SimCluster`` (see :mod:`repro.core.simcluster`) is a deterministic
in-process simulator. Both expose the same surface, so — exactly as the
paper requires — every tool and test runs without Slurm installed.

Selection: ``$REPRO_BACKEND`` = ``slurm`` | ``sim``; default is ``slurm``
when ``sbatch`` is on PATH, else the shared simulator instance.
"""

from __future__ import annotations

import getpass
import os
import shutil
import subprocess
from typing import Protocol, runtime_checkable

from .queue import SQUEUE_FIELDS, SQUEUE_FORMAT

# sacct columns for the accounting layer (parsable2 = pipe-separated, no
# trailing delimiter). Raw variants give seconds/joules without pretty units.
SACCT_FIELDS = (
    "jobid", "name", "user", "partition", "cpus", "memory", "time_limit",
    "submitted_at", "started_at", "finished_at", "state", "elapsed_s",
    "consumed_energy", "node",
)
SACCT_FORMAT = (
    "JobID,JobName,User,Partition,AllocCPUS,ReqMem,Timelimit,"
    "Submit,Start,End,State,ElapsedRaw,ConsumedEnergyRaw,NodeList"
)


class BatchSubmitError(RuntimeError):
    """Some submissions in a batch failed.

    ``ids`` maps input index → job id for the submissions that DID go
    through (so callers can track or cancel them); ``errors`` maps input
    index → exception for the ones that did not.
    """

    def __init__(self, ids: dict, errors: dict):
        self.ids = ids
        self.errors = errors
        first = next(iter(errors.values()))
        super().__init__(
            f"{len(errors)} of {len(ids) + len(errors)} submissions failed "
            f"(first: {first}); {len(ids)} job(s) already submitted"
        )


@runtime_checkable
class Backend(Protocol):
    def submit(self, job) -> int:  # job: repro.core.job.Job (script written)
        ...

    def queue(self) -> list[dict]:  # records with SQUEUE_FIELDS keys
        ...

    def cancel(self, jobids: list) -> None:
        ...

    def nodes_info(self) -> list[dict]:  # {name, cpus, memory_mb, state}
        ...


class SlurmBackend:
    """Real SLURM via subprocess. Used on clusters; never in unit tests."""

    #: bounded worker pool for pipelined submissions (sbatch is I/O bound:
    #: each call is a fork + a controller RPC round-trip)
    max_workers: int = 8

    def submit(self, job) -> int:
        out = subprocess.run(
            ["sbatch", "--parsable", job.script_path],
            check=True,
            capture_output=True,
            text=True,
        ).stdout.strip()
        return int(out.split(";")[0])

    def submit_many(self, jobs: list) -> list[int]:
        """Pipeline N ``sbatch`` calls through a bounded thread pool.

        Returns job ids in input order. Serial below 2 jobs (no pool
        overhead for the common single-submission path). If any sbatch
        fails, raises :class:`BatchSubmitError` carrying the ids that DID
        submit — they are live on the cluster and must not be lost.
        """
        jobs = list(jobs)
        if len(jobs) < 2:
            return [self.submit(j) for j in jobs]
        from concurrent.futures import ThreadPoolExecutor

        workers = min(self.max_workers, len(jobs))
        ids: dict[int, int] = {}
        errors: dict[int, Exception] = {}

        def one(indexed):
            i, job = indexed
            try:
                ids[i] = self.submit(job)
            except Exception as e:  # noqa: BLE001 — collected, re-raised below
                errors[i] = e

        with ThreadPoolExecutor(max_workers=workers) as pool:
            list(pool.map(one, enumerate(jobs)))
        if errors:
            raise BatchSubmitError(ids, errors)
        return [ids[i] for i in range(len(jobs))]

    def queue(self) -> list[dict]:
        out = subprocess.run(
            ["squeue", "--noheader", "-o", SQUEUE_FORMAT],
            check=True,
            capture_output=True,
            text=True,
        ).stdout
        rows = []
        for line in out.splitlines():
            parts = [p.strip() for p in line.split("|")]
            if len(parts) == len(SQUEUE_FIELDS):
                rows.append(dict(zip(SQUEUE_FIELDS, parts)))
        return rows

    def cancel(self, jobids: list) -> None:
        if jobids:
            subprocess.run(["scancel", *[str(j) for j in jobids]], check=True)

    def release(self, jobids: list) -> None:
        """Release jobs submitted with ``--hold`` (eco hold-and-release)."""
        if jobids:
            subprocess.run(
                ["scontrol", "release", *[str(j) for j in jobids]], check=True
            )

    def accounting(self, *, since: str = "", user: str = "") -> list[dict]:
        """Completed-job history via ``sacct`` (normalised row dicts).

        Rows use :data:`SACCT_FIELDS` keys with seconds/MB/joule values
        normalised by :func:`parse_sacct_output`. ``since`` is passed to
        ``--starttime`` (sacct syntax, e.g. ``now-7days``); default scope
        is the calling user unless ``user`` (or ``-a`` via user='*') says
        otherwise.
        """
        cmd = ["sacct", "--noheader", "--parsable2", f"--format={SACCT_FORMAT}"]
        if since:
            cmd += ["--starttime", since]
        if user == "*":
            cmd.append("--allusers")
        elif user:
            cmd += ["--user", user]
        out = subprocess.run(
            cmd, check=True, capture_output=True, text=True
        ).stdout
        return parse_sacct_output(out)

    def nodes_info(self) -> list[dict]:
        out = subprocess.run(
            ["sinfo", "--noheader", "-N", "-o", "%N|%c|%m|%T"],
            check=True,
            capture_output=True,
            text=True,
        ).stdout
        rows = []
        for line in out.splitlines():
            parts = [p.strip() for p in line.split("|")]
            if len(parts) == 4:
                rows.append(
                    {
                        "name": parts[0],
                        "cpus": int(parts[1]),
                        "memory_mb": int(parts[2]),
                        "state": parts[3],
                    }
                )
        return rows


# ---------------------------------------------------------------------------
# sacct output parsing (pure functions — unit-tested without SLURM)
# ---------------------------------------------------------------------------


def parse_sacct_output(text: str) -> list[dict]:
    """``sacct --parsable2`` text → normalised row dicts.

    Job *steps* (``123.batch``, ``123.extern``, ``123.0``) are folded away:
    only whole-job rows survive, but a step's ``ConsumedEnergy`` backfills
    its parent when the parent reports none (common sacct layout — the
    energy plugin accounts on the batch step). Step order is not assumed:
    a step seen *before* its parent row is buffered and backfilled once
    the parent arrives, and an orphan step whose parent row never appears
    (filtered out by ``--user``/``--starttime``) is dropped without ever
    fabricating a job row.
    """
    rows: list[dict] = []
    by_base: dict[str, dict] = {}
    step_energy: dict[str, str] = {}  # steps seen before their parent row
    for line in text.splitlines():
        parts = line.split("|")
        if len(parts) != len(SACCT_FIELDS):
            continue
        raw = dict(zip(SACCT_FIELDS, (p.strip() for p in parts)))
        base, _, step = raw["jobid"].partition(".")
        if step:  # a job step: only mined for energy backfill
            if not _energy_j(raw["consumed_energy"]):
                continue
            parent = by_base.get(base)
            if parent is None:
                step_energy.setdefault(base, raw["consumed_energy"])
            elif not _energy_j(parent["consumed_energy"]):
                parent["consumed_energy"] = raw["consumed_energy"]
            continue
        row = _normalise_sacct_row(raw)
        if base in step_energy and not _energy_j(row["consumed_energy"]):
            row["consumed_energy"] = step_energy.pop(base)
        rows.append(row)
        by_base[base] = row
    return rows


def _normalise_sacct_row(raw: dict) -> dict:
    from .resources import parse_memory_mb, parse_time_s

    row = dict(raw)
    try:
        row["cpus"] = int(raw["cpus"] or 1)
    except ValueError:
        row["cpus"] = 1
    try:
        # old sacct suffixes ReqMem with n (per node) / c (per CPU); the
        # per-CPU form is a multiplier, not a total
        mem_raw = raw["memory"]
        per_cpu = mem_raw.endswith("c")
        mb = parse_memory_mb(mem_raw.rstrip("nc")) if mem_raw else 0
        row["memory_mb"] = mb * row["cpus"] if per_cpu else mb
    except ValueError:
        row["memory_mb"] = 0
    try:
        row["time_limit_s"] = parse_time_s(raw["time_limit"]) if raw["time_limit"] else 0
    except ValueError:
        row["time_limit_s"] = 0  # UNLIMITED / Partition_Limit
    try:
        row["elapsed_s"] = int(float(raw["elapsed_s"] or 0))
    except ValueError:
        row["elapsed_s"] = 0
    for key in ("submitted_at", "started_at", "finished_at"):
        if row[key] in ("Unknown", "None", "N/A"):
            row[key] = ""
    # sacct prints "None assigned" in NodeList for jobs that never started
    if row["node"] in ("None assigned", "None", "N/A"):
        row["node"] = ""
    return row


def _energy_j(s: str) -> float:
    from repro.accounting.energy import parse_consumed_energy

    return parse_consumed_energy(s)


_SHARED_SIM = None
_SHARED_FED = None

#: backend kinds ``$REPRO_BACKEND`` / ``get_backend(kind=)`` accept
VALID_BACKEND_KINDS = ("slurm", "sim", "federated")


def get_backend(kind: str | None = None):
    """Resolve the active backend.

    ``kind`` (or ``$REPRO_BACKEND``) picks explicitly: ``slurm`` shells out
    to sbatch/squeue, ``sim`` is the shared in-process simulator,
    ``federated`` builds a :class:`~repro.core.federation.FederatedBackend`
    from the config's ``[cluster.<name>]`` stanzas. Anything else raises a
    :class:`ValueError` naming the valid kinds.

    Unset, the default resolution order is: configured cluster stanzas →
    federation; ``sbatch`` on PATH → real SLURM; otherwise the simulator.
    """
    global _SHARED_SIM
    kind = (kind or os.environ.get("REPRO_BACKEND", "")).strip().lower()
    if kind and kind not in VALID_BACKEND_KINDS:
        raise ValueError(
            f"unknown backend kind {kind!r} (from $REPRO_BACKEND or the "
            f"kind= argument): valid kinds are "
            + ", ".join(repr(k) for k in VALID_BACKEND_KINDS)
        )
    if kind == "slurm":
        return SlurmBackend()
    if kind == "federated":
        return _shared_federation(required=True)
    if not kind:
        fed = _shared_federation(required=False)
        if fed is not None:
            return fed
        if shutil.which("sbatch"):
            return SlurmBackend()
    from .simcluster import SimCluster

    if _SHARED_SIM is None:
        _SHARED_SIM = SimCluster(default_user=_current_user())
    return _SHARED_SIM


def _shared_federation(*, required: bool):
    """The process-wide FederatedBackend for the current config stanzas.

    Rebuilt whenever the config contents change (tests point
    ``$NBISLURM_CONFIG`` at per-test files); ``None`` — or ValueError when
    ``required`` — with no stanzas configured.
    """
    global _SHARED_FED
    from .config import load_config

    cfg = load_config()
    if not cfg.cluster_names():
        if required:
            raise ValueError(
                "REPRO_BACKEND=federated but there are no [cluster.<name>] "
                f"stanzas in {cfg.path or 'the config file'}"
            )
        return None
    key = (cfg.path, tuple(sorted(cfg.values.items())))
    if _SHARED_FED is None or _SHARED_FED._config_key != key:
        from repro.accounting.predict import predictor_from_config

        from .federation import ClusterRegistry, FederatedBackend

        if _SHARED_FED is not None:
            # the shared QueueCache may be subscribed to the outgoing
            # federation's bus: detach it BEFORE closing, or it stays a
            # live subscriber of a dead backend until the next
            # get_queue_cache() call notices (stale-subscriber leak)
            _detach_shared_cache(_SHARED_FED)
            _SHARED_FED.close()
        _SHARED_FED = FederatedBackend(
            ClusterRegistry.from_config(cfg),
            predictor=predictor_from_config(cfg),
        )
        _SHARED_FED._config_key = key
    return _SHARED_FED


def _detach_shared_cache(backend) -> None:
    """Unbind the process-shared QueueCache if it fronts ``backend``.

    Must run *before* the backend is closed/dropped: a cache left
    subscribed to a dead backend's bus keeps receiving (and acting on)
    events from a world that no longer exists.
    """
    from . import engine

    cache = engine._SHARED_CACHE
    if cache is not None and cache.inner is backend:
        cache.unbind_bus()


def reset_shared_sim() -> None:
    """Forget the shared simulator/federation and the queue cache
    (test isolation)."""
    global _SHARED_SIM, _SHARED_FED
    _SHARED_SIM = None
    # detach the cache first: dropping a backend that still has the shared
    # cache subscribed to its bus leaks a stale subscriber
    from .engine import reset_queue_cache

    reset_queue_cache()
    if _SHARED_FED is not None:
        _SHARED_FED.close()
    _SHARED_FED = None


def reset_backend() -> None:
    """Public name for dropping every process-shared backend singleton
    (simulator, federation, queue cache) — what tests and a cycling
    gateway daemon call between worlds."""
    reset_shared_sim()


def _current_user() -> str:
    try:
        return getpass.getuser()
    except Exception:
        return os.environ.get("USER", "user")
