"""Workload-manager backends.

``SlurmBackend`` shells out to real ``sbatch``/``squeue``/``scancel``;
``SimCluster`` (see :mod:`repro.core.simcluster`) is a deterministic
in-process simulator. Both expose the same surface, so — exactly as the
paper requires — every tool and test runs without Slurm installed.

Selection: ``$REPRO_BACKEND`` = ``slurm`` | ``sim``; default is ``slurm``
when ``sbatch`` is on PATH, else the shared simulator instance.
"""

from __future__ import annotations

import getpass
import os
import shutil
import subprocess
from typing import Protocol, runtime_checkable

from .queue import SQUEUE_FIELDS, SQUEUE_FORMAT


class BatchSubmitError(RuntimeError):
    """Some submissions in a batch failed.

    ``ids`` maps input index → job id for the submissions that DID go
    through (so callers can track or cancel them); ``errors`` maps input
    index → exception for the ones that did not.
    """

    def __init__(self, ids: dict, errors: dict):
        self.ids = ids
        self.errors = errors
        first = next(iter(errors.values()))
        super().__init__(
            f"{len(errors)} of {len(ids) + len(errors)} submissions failed "
            f"(first: {first}); {len(ids)} job(s) already submitted"
        )


@runtime_checkable
class Backend(Protocol):
    def submit(self, job) -> int:  # job: repro.core.job.Job (script written)
        ...

    def queue(self) -> list[dict]:  # records with SQUEUE_FIELDS keys
        ...

    def cancel(self, jobids: list) -> None:
        ...

    def nodes_info(self) -> list[dict]:  # {name, cpus, memory_mb, state}
        ...


class SlurmBackend:
    """Real SLURM via subprocess. Used on clusters; never in unit tests."""

    #: bounded worker pool for pipelined submissions (sbatch is I/O bound:
    #: each call is a fork + a controller RPC round-trip)
    max_workers: int = 8

    def submit(self, job) -> int:
        out = subprocess.run(
            ["sbatch", "--parsable", job.script_path],
            check=True,
            capture_output=True,
            text=True,
        ).stdout.strip()
        return int(out.split(";")[0])

    def submit_many(self, jobs: list) -> list[int]:
        """Pipeline N ``sbatch`` calls through a bounded thread pool.

        Returns job ids in input order. Serial below 2 jobs (no pool
        overhead for the common single-submission path). If any sbatch
        fails, raises :class:`BatchSubmitError` carrying the ids that DID
        submit — they are live on the cluster and must not be lost.
        """
        jobs = list(jobs)
        if len(jobs) < 2:
            return [self.submit(j) for j in jobs]
        from concurrent.futures import ThreadPoolExecutor

        workers = min(self.max_workers, len(jobs))
        ids: dict[int, int] = {}
        errors: dict[int, Exception] = {}

        def one(indexed):
            i, job = indexed
            try:
                ids[i] = self.submit(job)
            except Exception as e:  # noqa: BLE001 — collected, re-raised below
                errors[i] = e

        with ThreadPoolExecutor(max_workers=workers) as pool:
            list(pool.map(one, enumerate(jobs)))
        if errors:
            raise BatchSubmitError(ids, errors)
        return [ids[i] for i in range(len(jobs))]

    def queue(self) -> list[dict]:
        out = subprocess.run(
            ["squeue", "--noheader", "-o", SQUEUE_FORMAT],
            check=True,
            capture_output=True,
            text=True,
        ).stdout
        rows = []
        for line in out.splitlines():
            parts = [p.strip() for p in line.split("|")]
            if len(parts) == len(SQUEUE_FIELDS):
                rows.append(dict(zip(SQUEUE_FIELDS, parts)))
        return rows

    def cancel(self, jobids: list) -> None:
        if jobids:
            subprocess.run(["scancel", *[str(j) for j in jobids]], check=True)

    def nodes_info(self) -> list[dict]:
        out = subprocess.run(
            ["sinfo", "--noheader", "-N", "-o", "%N|%c|%m|%T"],
            check=True,
            capture_output=True,
            text=True,
        ).stdout
        rows = []
        for line in out.splitlines():
            parts = [p.strip() for p in line.split("|")]
            if len(parts) == 4:
                rows.append(
                    {
                        "name": parts[0],
                        "cpus": int(parts[1]),
                        "memory_mb": int(parts[2]),
                        "state": parts[3],
                    }
                )
        return rows


_SHARED_SIM = None


def get_backend(kind: str | None = None):
    """Resolve the active backend (env-driven, simulator fallback)."""
    global _SHARED_SIM
    kind = kind or os.environ.get("REPRO_BACKEND", "")
    if kind == "slurm" or (not kind and shutil.which("sbatch")):
        return SlurmBackend()
    from .simcluster import SimCluster

    if _SHARED_SIM is None:
        _SHARED_SIM = SimCluster(default_user=_current_user())
    return _SHARED_SIM


def reset_shared_sim() -> None:
    """Forget the shared simulator and its queue cache (test isolation)."""
    global _SHARED_SIM
    _SHARED_SIM = None
    from .engine import reset_queue_cache

    reset_queue_cache()


def _current_user() -> str:
    try:
        return getpass.getuser()
    except Exception:
        return os.environ.get("USER", "user")
