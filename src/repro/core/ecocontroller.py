"""``EcoController`` — reactive eco hold-and-release (eco v2).

The static eco path freezes the deferral decision into a ``--begin``
directive at submit time: the job starts at the predicted window whether or
not the cluster is actually busy. The controller keeps the *same* decision
— the same :class:`~repro.core.eco.EcoScheduler` tier maths, pinned
bit-identical by ``tests/test_eco_properties.py`` — but turns it into a
**deadline** instead of a directive:

* tier-deferred jobs are submitted **HELD** (``sbatch --hold`` /
  ``SimJob.held``) with no ``--begin``;
* the controller observes the cluster through
  :class:`~repro.core.events.JobEvent` s (simulator bus, or a
  :class:`~repro.core.events.PollingEventAdapter` on real SLURM) and
  **releases early** when conditions are actually favourable — observed
  load at or below ``load_threshold``, inside an eco window, and the job's
  span still off-peak (the tier promise holds);
* at the decision's original ``begin`` — the deadline — the job is
  released unconditionally, so a held job starts **no later** than it
  would have under the static path.

With no controller attached nothing changes: the static ``--begin`` path
is untouched and decisions are bit-identical to before.

Deadlines survive process boundaries through the accounting
:class:`~repro.accounting.store.SubmitLog` journal; a long-running process
(``waitjobs --eco-release``, a cron loop) re-adopts held jobs with
:meth:`EcoController.adopt`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from datetime import datetime

from . import events as ev
from .eco import EcoDecision, EcoScheduler


@dataclass
class HeldJob:
    """One job the controller is holding back."""

    jobid: str  # base id, as the backend reports it
    deadline: datetime  # the static path's --begin: latest allowed start
    duration_s: int  # effective (predicted) duration used by the tier maths
    tier: int
    registered_at: datetime
    cluster: str = ""  # federation member ("" on a plain backend)


@dataclass
class ReleaseRecord:
    jobid: str
    at: datetime
    deadline: datetime
    early: bool  # released before the deadline (favourable conditions)

    @property
    def lead_s(self) -> float:
        """Seconds gained over the static path (0 for deadline releases)."""
        return max(0.0, (self.deadline - self.at).total_seconds())


class EcoController:
    """Hold tier-deferred jobs; release reactively, never past the deadline.

    Parameters
    ----------
    backend:
        Backend-protocol object with ``release()`` (``SlurmBackend`` or
        ``SimCluster``, optionally behind a ``QueueCache``). Default
        resolves via ``get_backend()``.
    scheduler:
        The :class:`EcoScheduler` whose decisions become deadlines.
        Defaults to one built from config (+ ``predictor``), exactly like
        the static path — that is what keeps detached behaviour
        bit-identical.
    load_threshold:
        Cluster CPU-occupancy fraction at or below which held jobs may be
        released early (default 0.25).

    Attaching: against a simulator the controller registers a tick hook
    (it runs at every ``advance()`` stop, including its own ``wake_at``
    deadlines). Against any other backend it subscribes to the event bus
    you wire in (``bind_bus``) and/or gets ``tick(now)`` called from a
    poll loop (``waitjobs --eco-release`` does both).
    """

    def __init__(
        self,
        backend=None,
        scheduler: EcoScheduler | None = None,
        *,
        predictor=None,
        load_threshold: float = 0.25,
        now: datetime | None = None,
        registry=None,
    ):
        if backend is None:
            from .backend import get_backend

            backend = get_backend()
        self.backend = backend
        #: federation registry (auto-detected from the backend): held jobs
        #: are then window- and load-checked against their OWN cluster
        self.registry = (
            registry if registry is not None
            else getattr(backend, "registry", None)
        )
        if scheduler is None:
            scheduler = EcoScheduler(predictor=predictor)
        self.scheduler = scheduler
        self.load_threshold = float(load_threshold)
        self._now = now  # injectable clock for deterministic tests
        self.held: dict[str, HeldJob] = {}
        self.released: list[ReleaseRecord] = []
        inner = getattr(backend, "inner", backend)
        self._hooked = None
        add_hook = getattr(inner, "add_tick_hook", None)
        if add_hook is not None:  # simulator: ride the event loop
            add_hook(self._tick_hook)
            self._hooked = inner
        self._bus_token: "tuple | None" = None

    @property
    def self_driving(self) -> bool:
        """True when releases happen without outside help — the controller
        rides an in-process event loop (simulator tick hooks). On real
        SLURM something must call ``tick()``/``adopt`` periodically."""
        return self._hooked is not None

    def detach(self) -> None:
        """Stop reacting: remove the tick hook / bus subscription. A
        detached controller keeps its held table but no longer releases —
        call before discarding a controller another one will replace."""
        if self._hooked is not None:
            self._hooked.remove_tick_hook(self._tick_hook)
            self._hooked = None
        if self._bus_token is not None:
            bus, token = self._bus_token
            bus.unsubscribe(token)
            self._bus_token = None

    # -- decision seam (property-pinned) ---------------------------------------

    def plan(
        self, duration_s: int, now: datetime, *, name: str = "", user: str = "",
        tool: str = "",
    ) -> EcoDecision:
        """The decision whose ``begin`` becomes the release deadline.

        Exactly ``scheduler.decide(...)`` — the property suite pins this
        equal to the static path's ``next_window`` for arbitrary windows,
        clocks and durations, which is what makes hold-and-release a pure
        *mechanism* swap: same decision, reactive execution.
        """
        return self.scheduler.decide(duration_s, now, name=name, user=user, tool=tool)

    # -- submission ------------------------------------------------------------

    @staticmethod
    def hold_meta(decision: EcoDecision, duration_s: int) -> dict:
        """The one journal/eco_meta shape for a held submission — every
        hold path (here, SubmitEngine, runjob) builds it through this so
        :meth:`adopt` always finds the fields it needs."""
        return {
            "tier": decision.tier,
            "deferred": decision.deferred,
            "hold": True,
            "deadline": decision.begin_directive,
            "duration_s": int(duration_s),
        }

    def submit(self, job, now: datetime | None = None) -> int:
        """Submit ``job``; deferred decisions go in held, others run now."""
        now = now or self._now or datetime.now()
        tool = getattr(job, "tool", "")
        decision = self.plan(job.opts.time_s, now, name=job.name, tool=tool)
        duration_s = self.scheduler.effective_duration(
            job.opts.time_s, job.name, "", tool
        )
        if decision.deferred:
            job.opts.hold = True
            eco_meta = self.hold_meta(decision, duration_s)
        else:
            eco_meta = {"tier": decision.tier, "deferred": decision.deferred}
        job.eco_meta = eco_meta
        jobid = job.run(self.backend)
        if decision.deferred:
            self.register(jobid, decision, now=now, duration_s=duration_s)
        from repro.accounting import log_submission

        log_submission(jobid, tool=tool, eco_meta=eco_meta)
        return jobid

    def register(
        self,
        jobid,
        decision: EcoDecision,
        *,
        now: datetime | None = None,
        duration_s: int | None = None,
    ) -> None:
        """Track an already-submitted held job (engine/CLI integration)."""
        if not decision.deferred:
            return
        jid = str(jobid)
        self.held[jid] = HeldJob(
            jobid=jid,
            deadline=decision.begin,
            duration_s=int(duration_s or 0) or 1,
            tier=decision.tier,
            registered_at=now or self._now or datetime.now(),
            cluster=_cluster_of(jid),
        )
        from repro.obs.metrics import get_registry

        reg = get_registry()
        reg.counter(
            "nbi_eco_held_total", "jobs submitted held for reactive release",
            labels=("tier",),
        ).labels(tier=str(decision.tier)).inc()
        reg.gauge("nbi_eco_held_open", "jobs currently held").set(len(self.held))
        self._wake(decision.begin, cluster=self.held[jid].cluster)

    # -- reaction --------------------------------------------------------------

    def tick(self, now: datetime) -> "list[str]":
        """Release whatever is due or favourable at ``now``; returns the ids.

        * deadline reached → release unconditionally (the no-later-than-
          static guarantee);
        * otherwise, with observed load ≤ threshold AND ``now`` inside an
          eco window AND the job's span off-peak → release early.

        On a federation, windows and load are those of the held job's OWN
        cluster — a quiet green member releases its jobs while a busy one
        keeps holding, each against its per-cluster eco windows.
        """
        if not self.held:
            return []
        due = [h for h in self.held.values() if now >= h.deadline]
        early: list[HeldJob] = []
        rest = [h for h in self.held.values() if now < h.deadline]
        loads: dict[str, float] = {}  # per-cluster load, computed once
        for h in rest:
            sched = self._sched_for(h.cluster)
            if not sched.in_eco_window(now):
                continue
            if h.cluster not in loads:
                loads[h.cluster] = self.load_fraction(cluster=h.cluster or None)
            if loads[h.cluster] > self.load_threshold:
                continue
            if not sched.span_overlaps_peak(now, h.duration_s):
                early.append(h)
        targets = due + early
        if not targets:
            return []
        ids = [h.jobid for h in targets]
        from repro.obs.metrics import get_registry

        reg = get_registry()
        releases = reg.counter(
            "nbi_eco_released_total",
            "held jobs released, early (favourable) vs at-deadline",
            labels=("kind",),
        )
        for h in targets:  # drop before release(): its events re-enter tick
            del self.held[h.jobid]
            early = now < h.deadline
            self.released.append(ReleaseRecord(
                jobid=h.jobid, at=now, deadline=h.deadline, early=early,
            ))
            releases.labels(kind="early" if early else "deadline").inc()
        reg.gauge("nbi_eco_held_open", "jobs currently held").set(len(self.held))
        self.backend.release(ids)
        return ids

    def _sched_for(self, cluster: str) -> EcoScheduler:
        """The scheduler whose windows govern one held job's early release."""
        if cluster and self.registry is not None and cluster in self.registry:
            sched = self.registry.get(cluster).scheduler
            if sched is not None:
                return sched
        return self.scheduler

    def load_fraction(self, *, cluster: str | None = None) -> float:
        """Observed CPU occupancy across UP nodes (0.0 idle … 1.0 full).

        ``cluster`` narrows the reading to one federation member (node
        records then carry a ``cluster`` field); None reads everything.
        """
        total = used = 0
        for n in self.backend.nodes_info():
            if cluster is not None and n.get("cluster", "") != cluster:
                continue
            state = str(n.get("state", "")).lower().rstrip("*")
            if state not in ("up", "idle", "mixed", "allocated", "alloc", ""):
                continue  # DOWN/DRAINED nodes contribute no capacity
            cpus = int(n.get("cpus", 0) or 0)
            total += cpus
            if "used_cpus" in n:  # simulator: exact
                used += int(n["used_cpus"])
            elif state in ("allocated", "alloc"):  # sinfo: approximate
                used += cpus
            elif state == "mixed":
                used += cpus // 2
        return used / total if total else 0.0

    # -- cross-process adoption --------------------------------------------------

    @classmethod
    def adopt(cls, backend=None, scheduler: EcoScheduler | None = None, **kw
              ) -> "EcoController":
        """Build a controller that re-adopts held jobs from the journal.

        Another process (``runjob --eco-hold``) submitted held jobs and
        journalled their deadlines in the accounting
        :class:`~repro.accounting.store.SubmitLog`; this picks up every
        job still sitting held in the queue and manages it to the same
        deadline. Held jobs with no journalled deadline are left alone —
        the user may have held them on purpose.
        """
        c = cls(backend, scheduler, **kw)
        c.adopt_held()
        return c

    def adopt_held(self) -> int:
        """Scan queue + journal for orphaned held jobs; returns how many."""
        from repro.accounting import HistoryStore

        journal = HistoryStore().submit_log().load()
        adopted = 0
        for row in self.backend.queue():
            if row.get("reason") != ev.HELD_REASON:
                continue
            jid = str(row.get("jobid", ""))
            if jid in self.held:
                continue
            from .federation import array_base_id

            entry = journal.get(jid) or journal.get(array_base_id(jid))
            deadline = _parse_iso((entry or {}).get("eco_deadline", ""))
            if deadline is None:
                continue
            self.held[jid] = HeldJob(
                jobid=jid,
                deadline=deadline,
                duration_s=int((entry or {}).get("eco_duration_s", 0) or 0) or 1,
                tier=int((entry or {}).get("eco_tier", 0) or 0),
                registered_at=self._now or datetime.now(),
                cluster=_cluster_of(jid),
            )
            self._wake(deadline, cluster=self.held[jid].cluster)
            adopted += 1
        return adopted

    # -- internals ---------------------------------------------------------------

    def _tick_hook(self, sim, now: datetime) -> None:
        self.tick(now)

    def _wake(self, t: datetime, cluster: str = "") -> None:
        inner = getattr(self.backend, "inner", self.backend)
        wake = getattr(inner, "wake_at", None)
        if wake is None:
            return
        if cluster:
            try:
                wake(t, cluster=cluster)
                return
            except TypeError:
                pass  # single-cluster backend: no cluster routing
        wake(t)

    def bind_bus(self, bus) -> None:
        """React to a :class:`PollingEventAdapter`'s synthetic events."""
        if self._bus_token is not None:
            old_bus, token = self._bus_token
            old_bus.unsubscribe(token)
        self._bus_token = (bus, bus.subscribe(lambda e: self.tick(e.at)))


def _cluster_of(jobid: str) -> str:
    from .federation import split_cluster_id

    return split_cluster_id(jobid)[0]


def _parse_iso(s: str) -> datetime | None:
    if not s:
        return None
    try:
        return datetime.fromisoformat(s)
    except ValueError:
        return None
