"""Shared gateway daemon — one poller, one placer, N thin clients.

Every CLI process used to build its own backend, QueueCache, Placer and
EcoController; at institutional scale that is N users × M tools
independently hammering ``squeue`` and re-deriving identical placement
state. :class:`GatewayServer` is a long-running per-host daemon that owns
exactly ONE of each — the cache, the event bus, the federation
placer/backlog tracker that ride the backend, and the eco
hold-and-release controller — and serves thin clients over a Unix domain
socket. One backend poll serves everyone, and held-job release / eco
deadlines keep firing after the submitting shell exits because the
*daemon*, not the CLI, owns the controller.

Protocol: length-prefixed JSON-RPC. Each frame is a 4-byte big-endian
payload length followed by that many bytes of UTF-8 JSON. Requests are
``{"id": n, "method": str, "params": {...}}``; responses are
``{"id": n, "ok": true, "result": ...}`` or ``{"id": n, "ok": false,
"error": str}``. ``events_subscribe`` is the one streaming method: after
the initial response the server keeps sending ``{"event": {...}}``
frames until the client disconnects (or the requested duration elapses,
closed by an ``{"end": true}`` frame).

Methods: ``ping``, ``queue``, ``nodes_info``, ``submit_batch``,
``cancel``, ``release``, ``wait``, ``events_subscribe``, ``stats``,
``advance`` (simulated backends only) and ``shutdown``.

Fair share: every request draws one token from the calling user's
token bucket (``rate`` tokens/s, ``burst`` capacity); an empty bucket
delays the request instead of rejecting it, so a flood from one user
slows that user down without starving the others.

Namespacing: job ids submitted through the daemon are recorded against
the submitting user; ``cancel``/``release`` refuse to touch another
user's daemon-submitted jobs (ids the daemon never saw are passed
through — it cannot know their owner).

The thin-client side lives in :mod:`repro.cli.session`
(``GatewayClient``): it speaks this protocol and transparently falls
back to the in-process path when no daemon socket is present, which is
what gives every existing CLI daemon mode without code churn.
"""

from __future__ import annotations

import json
import os
import socket
import struct
import threading
import time as _time
from datetime import datetime

from repro.obs.metrics import get_registry

from . import events as ev
from .engine import QueueCache

PROTOCOL_VERSION = 1

#: frames above this are refused — a corrupt length prefix must not make
#: the daemon try to allocate gigabytes
MAX_FRAME_BYTES = 32 * 1024 * 1024

_LEN = struct.Struct(">I")


class GatewayError(RuntimeError):
    """The daemon answered, but with an error (bad request, unknown id...)."""


class GatewayConnectionLost(ConnectionError):
    """The daemon went away mid-conversation (socket closed / refused)."""


# ---------------------------------------------------------------------------
# Framing (shared by server and client)
# ---------------------------------------------------------------------------


def send_frame(sock: socket.socket, obj) -> None:
    """Serialise ``obj`` as one length-prefixed JSON frame."""
    payload = json.dumps(obj, separators=(",", ":"), default=str).encode("utf-8")
    if len(payload) > MAX_FRAME_BYTES:
        raise GatewayError(f"frame too large ({len(payload)} bytes)")
    sock.sendall(_LEN.pack(len(payload)) + payload)


def recv_frame(sock: socket.socket):
    """Read one frame; returns the decoded object, or None on clean EOF."""
    header = _recv_exact(sock, _LEN.size)
    if header is None:
        return None
    (length,) = _LEN.unpack(header)
    if length > MAX_FRAME_BYTES:
        raise GatewayError(f"frame too large ({length} bytes)")
    payload = _recv_exact(sock, length)
    if payload is None:
        raise GatewayConnectionLost("connection closed mid-frame")
    return json.loads(payload.decode("utf-8"))


def _recv_exact(sock: socket.socket, n: int) -> "bytes | None":
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def default_socket_path() -> str:
    """Where clients look for the daemon: ``$NBI_GATEWAY_SOCKET``, else a
    per-user path under ``$XDG_RUNTIME_DIR`` (``/tmp`` fallback)."""
    explicit = os.environ.get("NBI_GATEWAY_SOCKET", "")
    if explicit:
        return explicit
    run = os.environ.get("XDG_RUNTIME_DIR", "")
    if run and os.path.isdir(run):
        return os.path.join(run, "nbi-gateway.sock")
    return f"/tmp/nbi-gateway-{os.getuid()}.sock"


# ---------------------------------------------------------------------------
# Fair-share rate limiting
# ---------------------------------------------------------------------------


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s, ``burst`` capacity.

    :meth:`reserve` always grants the token but returns how long the
    caller should wait before acting on it (0.0 while the bucket has
    credit) — delaying instead of rejecting is what makes the gateway's
    fair share a throttle, not an error path.
    """

    def __init__(self, rate: float, burst: float, clock=_time.monotonic):
        self.rate = max(1e-9, float(rate))
        self.burst = max(1.0, float(burst))
        self._clock = clock
        self._tokens = self.burst
        self._at = clock()
        self._lock = threading.Lock()

    def reserve(self, n: float = 1.0) -> float:
        """Take ``n`` tokens; returns seconds to wait before proceeding."""
        with self._lock:
            now = self._clock()
            self._tokens = min(self.burst, self._tokens + (now - self._at) * self.rate)
            self._at = now
            self._tokens -= n
            if self._tokens >= 0:
                return 0.0
            return -self._tokens / self.rate


# ---------------------------------------------------------------------------
# Job wire format (client serialises, daemon reconstructs)
# ---------------------------------------------------------------------------

_OPTS_FIELDS = None


def job_to_wire(job) -> dict:
    """A :class:`~repro.core.job.Job` as a JSON-safe dict."""
    from dataclasses import asdict

    return {
        "name": job.name,
        "commands": list(job.commands),
        "task_commands": list(job.task_commands) if job.task_commands else None,
        "files": list(job.files),
        "workdir": job.workdir,
        "sim_duration_s": job.sim_duration_s,
        "tool": getattr(job, "tool", ""),
        "cluster": getattr(job, "cluster", ""),
        "eco_meta": getattr(job, "eco_meta", None),
        "prelude": list(job.prelude),
        "trailer": list(job.trailer),
        "opts": asdict(job.opts),
    }


def job_from_wire(wire: dict):
    """Rebuild a submittable Job from :func:`job_to_wire` output.

    Unknown ``opts`` keys are dropped (a newer client talking to an older
    daemon must not crash it).
    """
    import dataclasses

    from .job import Job
    from .resources import Opts

    global _OPTS_FIELDS
    if _OPTS_FIELDS is None:
        _OPTS_FIELDS = {f.name for f in dataclasses.fields(Opts)}
    optsd = {k: v for k, v in dict(wire.get("opts") or {}).items()
             if k in _OPTS_FIELDS}
    job = Job(
        name=str(wire.get("name", "job")),
        command=list(wire.get("commands") or []),
        opts=Opts(**optsd),
        workdir=str(wire.get("workdir", "")),
        sim_duration_s=wire.get("sim_duration_s"),
    )
    job.files = [str(f) for f in wire.get("files") or []]
    tc = wire.get("task_commands")
    job.task_commands = [str(c) for c in tc] if tc else None
    job.prelude = [str(p) for p in wire.get("prelude") or []]
    job.trailer = [str(t) for t in wire.get("trailer") or []]
    job.tool = str(wire.get("tool", ""))
    eco_meta = wire.get("eco_meta")
    job.eco_meta = dict(eco_meta) if isinstance(eco_meta, dict) else None
    cluster = str(wire.get("cluster", ""))
    if cluster:
        job.cluster = cluster
    return job


def event_to_wire(event) -> dict:
    return {
        "type": event.type,
        "jobid": event.jobid,
        "at": event.at.isoformat() if hasattr(event.at, "isoformat") else str(event.at),
        "name": event.name,
        "user": event.user,
        "state": event.state,
        "node": event.node,
        "reason": event.reason,
        "cluster": event.cluster,
    }


def event_from_wire(wire: dict):
    at = wire.get("at", "")
    try:
        at = datetime.fromisoformat(at)
    except (TypeError, ValueError):
        at = datetime.now()
    return ev.JobEvent(
        type=str(wire.get("type", "")),
        jobid=str(wire.get("jobid", "")),
        at=at,
        name=str(wire.get("name", "")),
        user=str(wire.get("user", "")),
        state=str(wire.get("state", "")),
        node=str(wire.get("node", "")),
        reason=str(wire.get("reason", "")),
        cluster=str(wire.get("cluster", "")),
    )


# ---------------------------------------------------------------------------
# The daemon
# ---------------------------------------------------------------------------


class GatewayServer:
    """The per-host daemon: one cache, one bus, one controller; N clients.

    Parameters
    ----------
    backend:
        Backend-protocol object; default resolves via ``get_backend()``
        (federated when stanzas are configured — the Placer and
        BacklogTracker then ride along and are shared by every client).
    socket_path:
        Unix socket to listen on (default :func:`default_socket_path`).
    ttl_s:
        QueueCache TTL. Event invalidation makes staleness event-driven;
        the TTL is only the fallback for eventless backends.
    eco:
        Build an :class:`~repro.core.ecocontroller.EcoController` owned
        by the daemon: ``submit_batch(eco=True)`` submissions are held
        and released reactively even after the submitting shell exits.
    rate / burst:
        Per-user token-bucket fair share (tokens/s, bucket capacity).
    poll_s:
        Background pump cadence against non-simulated backends (the
        PollingEventAdapter poll / controller tick interval).
    """

    def __init__(
        self,
        backend=None,
        socket_path: str | None = None,
        *,
        ttl_s: float = 2.0,
        eco: bool = True,
        rate: float = 50.0,
        burst: float = 100.0,
        max_throttle_s: float = 2.0,
        poll_s: float = 15.0,
        clock=_time.monotonic,
    ):
        if backend is None:
            from .backend import get_backend

            backend = get_backend()
        inner = backend.inner if isinstance(backend, QueueCache) else backend
        self.backend = inner
        self.cache = (
            backend if isinstance(backend, QueueCache)
            else QueueCache(inner, ttl_s=ttl_s)
        )
        self.socket_path = socket_path or default_socket_path()
        self._clock = clock
        self.rate = float(rate)
        self.burst = float(burst)
        self.max_throttle_s = float(max_throttle_s)
        self.poll_s = float(poll_s)
        #: one advance()/poll-capable lock serialising every backend touch
        #: from the per-connection threads (the simulator is not
        #: thread-safe; real squeue/sbatch calls gain nothing from racing)
        self._lock = threading.RLock()
        self._sim_like = hasattr(inner, "advance")
        self._adapter = None
        bus = getattr(inner, "bus", None)
        if bus is None:
            # pushless backend (real SLURM): the daemon owns the single
            # polling adapter; its bus is the daemon bus
            self._adapter = ev.PollingEventAdapter(self.cache)
            bus = self._adapter.bus
        self.bus = bus
        self.controller = None
        if eco:
            from .ecocontroller import EcoController

            self.controller = EcoController(self.cache)
        from .config import load_config

        cfg = load_config()
        self._eco_default = cfg.get_bool("economy_mode")
        try:
            from repro.accounting import predictor_from_config

            self.predictor = predictor_from_config(cfg)
        except Exception:  # noqa: BLE001 — predictor is an optional refinement
            self.predictor = None
        #: base job id (str) → submitting user (per-user namespacing)
        self.owners: dict[str, str] = {}
        self._buckets: dict[str, TokenBucket] = {}
        self._buckets_lock = threading.Lock()
        # plain-int daemon stats (exact even with metrics disabled)
        self.started_at = _time.time()
        self.connections = 0
        self.inflight = 0
        self.requests: dict[str, int] = {}
        self.throttled = 0
        self._stop = threading.Event()
        self._listener: socket.socket | None = None
        self._threads: list[threading.Thread] = []
        self._pump_thread: threading.Thread | None = None
        self._wait_wakeup = threading.Event()

    # -- lifecycle -------------------------------------------------------------

    def bind(self) -> "GatewayServer":
        """Create and bind the listening socket (idempotent)."""
        if self._listener is not None:
            return self
        path = self.socket_path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if os.path.exists(path):
            # leftover from a crashed daemon? refuse only if it's live
            probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                probe.settimeout(0.25)
                probe.connect(path)
                probe.close()
                raise GatewayError(f"another gateway is live on {path}")
            except (ConnectionRefusedError, socket.timeout, FileNotFoundError, OSError) as e:
                if isinstance(e, GatewayError):
                    raise
                probe.close()
                os.unlink(path)
        listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        listener.bind(path)
        # login-node usage: other users' thin clients must be able to
        # connect (requests carry the user; ids are namespaced per user)
        try:
            os.chmod(path, 0o666)
        except OSError:
            pass
        listener.listen(64)
        listener.settimeout(0.2)  # periodic stop-flag checks
        self._listener = listener
        return self

    def start(self) -> threading.Thread:
        """Serve in a daemon thread (tests, benchmarks, embedded use)."""
        self.bind()
        t = threading.Thread(target=self.serve_forever, daemon=True,
                             name="nbi-gateway-accept")
        t.start()
        return t

    def serve_forever(self) -> None:
        """Accept loop; returns after :meth:`close` (or ``shutdown`` RPC)."""
        self.bind()
        if not self._sim_like and self._pump_thread is None:
            self._pump_thread = threading.Thread(
                target=self._pump_loop, daemon=True, name="nbi-gateway-pump"
            )
            self._pump_thread.start()
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break  # listener closed under us (close())
            self.connections += 1
            t = threading.Thread(
                target=self._serve_conn, args=(conn,), daemon=True,
                name=f"nbi-gateway-conn-{self.connections}",
            )
            t.start()
            self._threads.append(t)
            self._threads = [x for x in self._threads if x.is_alive()]

    def close(self) -> None:
        """Stop serving and detach everything the daemon subscribed.

        A closed daemon must leave the backend exactly as it found it:
        cache unbound from the bus, controller hooks removed — cycling
        daemons in one process (tests) must not accumulate stale
        subscribers.
        """
        self._stop.set()
        self._wait_wakeup.set()
        if self._listener is not None:
            try:
                self._listener.close()
            finally:
                self._listener = None
        try:
            if os.path.exists(self.socket_path):
                os.unlink(self.socket_path)
        except OSError:
            pass
        if self.controller is not None:
            self.controller.detach()
        self.cache.unbind_bus()

    # -- connection handling -----------------------------------------------------

    def _serve_conn(self, conn: socket.socket) -> None:
        reg = get_registry()
        self.inflight += 1
        if reg.enabled:
            reg.gauge(
                "nbi_gateway_inflight_connections", "open client connections"
            ).set(self.inflight)
            reg.counter(
                "nbi_gateway_connections_total", "client connections accepted"
            ).inc()
        try:
            while not self._stop.is_set():
                try:
                    req = recv_frame(conn)
                except (GatewayError, GatewayConnectionLost, OSError,
                        json.JSONDecodeError):
                    break
                if req is None:
                    break
                self._handle(conn, req if isinstance(req, dict) else {})
                if isinstance(req, dict) and req.get("method") == "shutdown":
                    break
        finally:
            self.inflight -= 1
            if reg.enabled:
                reg.gauge(
                    "nbi_gateway_inflight_connections", "open client connections"
                ).set(self.inflight)
            try:
                conn.close()
            except OSError:
                pass

    def _handle(self, conn: socket.socket, req: dict) -> None:
        method = str(req.get("method", ""))
        params = req.get("params") or {}
        if not isinstance(params, dict):
            params = {}
        user = str(params.get("user", "") or "") or "anonymous"
        rid = req.get("id")
        self.requests[method] = self.requests.get(method, 0) + 1
        delay = self._bucket(user).reserve()
        if delay > 0:
            self.throttled += 1
            reg = get_registry()
            if reg.enabled:
                reg.counter(
                    "nbi_gateway_throttled_total",
                    "requests delayed by fair-share rate limiting",
                ).inc()
            self._stop.wait(min(delay, self.max_throttle_s))
        t0 = _time.perf_counter()
        try:
            handler = getattr(self, f"_rpc_{method}", None)
            if handler is None:
                raise GatewayError(f"unknown method {method!r}")
            if method == "events_subscribe":
                handler(conn, rid, user, params)  # streaming: owns the reply
                return
            result = handler(user, params)
            send_frame(conn, {"id": rid, "ok": True, "result": result})
        except (GatewayError, ValueError, KeyError, TypeError) as e:
            try:
                send_frame(conn, {"id": rid, "ok": False, "error": str(e)})
            except OSError:
                pass
        except OSError:
            pass  # client went away mid-reply
        finally:
            reg = get_registry()
            if reg.enabled:
                reg.counter(
                    "nbi_gateway_requests_total", "gateway RPCs served",
                    labels=("method",),
                ).labels(method=method or "?").inc()
                reg.histogram(
                    "nbi_gateway_request_seconds", "gateway RPC latency",
                    labels=("method",),
                ).labels(method=method or "?").observe(_time.perf_counter() - t0)

    def _bucket(self, user: str) -> TokenBucket:
        with self._buckets_lock:
            b = self._buckets.get(user)
            if b is None:
                b = self._buckets[user] = TokenBucket(
                    self.rate, self.burst, clock=self._clock
                )
            return b

    # -- pump (shared clock/event driver) -----------------------------------------

    def _pump_once(self, step_s: float) -> None:
        """One event-delivery step: advance the simulator, or take one
        adapter poll + controller tick against a real backend."""
        with self._lock:
            if self._sim_like:
                self.cache.advance(step_s)  # mutator wrapper invalidates
            elif self._adapter is not None:
                self.cache.invalidate()  # the adapter must see fresh rows
                self._adapter.poll()
                if self.controller is not None:
                    self.controller.tick(datetime.now())
        self._wait_wakeup.set()
        self._wait_wakeup.clear()

    def _pump_loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            try:
                self._pump_once(self.poll_s)
            except Exception:  # noqa: BLE001 — the pump must survive squeue hiccups
                pass

    # -- RPC handlers --------------------------------------------------------------

    def _rpc_ping(self, user: str, params: dict) -> dict:
        return {
            "pong": True,
            "pid": os.getpid(),
            "protocol": PROTOCOL_VERSION,
            "backend": type(self.backend).__name__,
        }

    def _rpc_queue(self, user: str, params: dict) -> list:
        with self._lock:
            return self.cache.queue()

    def _rpc_nodes_info(self, user: str, params: dict) -> list:
        with self._lock:
            return self.cache.nodes_info()

    def _rpc_submit_batch(self, user: str, params: dict) -> dict:
        wires = params.get("jobs")
        if not isinstance(wires, list) or not wires:
            raise GatewayError("submit_batch needs a non-empty jobs list")
        jobs = [job_from_wire(w) for w in wires]
        eco = params.get("eco")
        eco = self._eco_default if eco is None else bool(eco)
        from .engine import SubmitEngine

        with self._lock:
            engine = SubmitEngine(
                self.cache,
                coalesce=bool(params.get("coalesce", True)),
                eco=eco,
                controller=self.controller if eco else None,
                predictor=self.predictor,
            )
            result = engine.submit_many(jobs)
        from .federation import array_base_id

        for base in result.base_ids:
            self.owners[array_base_id(str(base))] = user
        return {
            "ids": list(result.ids),
            "base_ids": [str(b) for b in result.base_ids],
            "sbatch_calls": result.sbatch_calls,
            "coalesced": result.coalesced,
            "eco_deferred": result.eco_deferred,
            "placements": sorted(p for p in result.placements if p),
        }

    def _partition_owned(self, user: str, ids: list) -> "tuple[list, list]":
        """Split requested ids into (allowed, denied-by-namespacing)."""
        from .federation import array_base_id

        allowed, denied = [], []
        for jid in ids:
            owner = self.owners.get(array_base_id(str(jid)))
            if owner is not None and owner != user:
                denied.append(str(jid))
            else:
                allowed.append(str(jid))
        return allowed, denied

    def _rpc_cancel(self, user: str, params: dict) -> dict:
        ids = list(params.get("ids") or [])
        allowed, denied = self._partition_owned(user, ids)
        if allowed:
            with self._lock:
                self.cache.cancel(allowed)
        return {"cancelled": allowed, "denied": denied}

    def _rpc_release(self, user: str, params: dict) -> dict:
        ids = list(params.get("ids") or [])
        allowed, denied = self._partition_owned(user, ids)
        if allowed:
            with self._lock:
                self.cache.release(allowed)
        return {"released": allowed, "denied": denied}

    def _rpc_advance(self, user: str, params: dict) -> dict:
        if not self._sim_like:
            raise GatewayError("advance is only available on simulated backends")
        seconds = float(params.get("seconds", 0.0))
        self._pump_once(seconds)
        now = getattr(self.backend, "now", None)
        return {"now": now.isoformat() if now is not None else ""}

    def _rpc_wait(self, user: str, params: dict) -> dict:
        """Block until the watch set drains; returns per-job final states.

        The daemon waits on its own bus — one subscription serves the
        request regardless of how many jobs are watched, and against a
        simulated backend the wait itself advances simulated time (the
        RPC is the clock, exactly like ``waitjobs`` in-process).
        """
        from repro.cli.waitjobs import _final_states, _id_matches, _norm_state

        ids = params.get("ids") or None
        watch_user = params.get("watch_user") or None
        name = params.get("name") or None
        poll_s = float(params.get("poll_s", self.poll_s) or self.poll_s)
        timeout_s = float(params.get("timeout_s", 0.0) or 0.0)

        from .queue import Queue

        with self._lock:
            q = Queue(user=watch_user, name=name, backend=self.cache)
            if ids:
                want = {str(i) for i in ids}
                watched = {j.jobid for j in q
                           if any(_id_matches(j.jobid, req) for req in want)}
            else:
                watched = set(q.ids())
        states: dict[str, str] = {}
        snapshots = 1
        if ids:
            gone = [req for req in {str(i) for i in ids}
                    if not any(_id_matches(w, req) for w in watched)]
            if gone:
                with self._lock:
                    states.update(_final_states(self.backend, gone))
        remaining = set(watched)
        ok = True
        if remaining:
            done_evt = threading.Event()

            def on_event(event):
                if event.jobid in remaining:
                    states[event.jobid] = _norm_state(event.state) or event.type
                    remaining.discard(event.jobid)
                    if not remaining:
                        done_evt.set()

            token = self.bus.subscribe(on_event, types=ev.TERMINAL_EVENTS)
            start = _time.monotonic()
            try:
                while remaining and not self._stop.is_set():
                    if timeout_s and _time.monotonic() - start > timeout_s:
                        ok = False
                        break
                    if self._sim_like:
                        # native events: advancing IS the wait; no snapshots
                        self._pump_once(poll_s)
                        _time.sleep(0.001)  # yield; bounded CPU on long waits
                    else:
                        done_evt.wait(min(poll_s, 1.0))
            finally:
                self.bus.unsubscribe(token)
            if ok and remaining:
                with self._lock:
                    states.update(_final_states(self.backend, remaining))
        return {
            "ok": ok,
            "states": dict(sorted(states.items())),
            "snapshots": snapshots,
        }

    def _rpc_events_subscribe(self, conn, rid, user: str, params: dict) -> None:
        """Stream the daemon's aggregated event ticker to this client."""
        import queue as _queue

        poll_s = float(params.get("poll_s", 2.0) or 2.0)
        duration_s = float(params.get("duration_s", 0.0) or 0.0)
        max_events = int(params.get("max_events", 0) or 0)
        pending: _queue.Queue = _queue.Queue()
        token = self.bus.subscribe(pending.put)
        sent = 0
        try:
            send_frame(conn, {"id": rid, "ok": True, "result": {"subscribed": True}})
            start = _time.monotonic()
            while not self._stop.is_set():
                if duration_s and _time.monotonic() - start >= duration_s:
                    break
                if self._sim_like:
                    self._pump_once(poll_s)
                else:
                    _time.sleep(min(poll_s, 0.5))
                while True:
                    try:
                        event = pending.get_nowait()
                    except _queue.Empty:
                        break
                    send_frame(conn, {"event": event_to_wire(event)})
                    sent += 1
                    if max_events and sent >= max_events:
                        raise _StreamDone
                if max_events and sent >= max_events:
                    break
                if self._sim_like and not self._any_active():
                    break  # simulated queue drained — nothing left to stream
        except (_StreamDone, OSError, BrokenPipeError):
            pass
        finally:
            self.bus.unsubscribe(token)
            try:
                send_frame(conn, {"end": True, "events": sent})
            except OSError:
                pass

    def _any_active(self) -> bool:
        with self._lock:
            return bool(self.cache.queue())

    def _rpc_stats(self, user: str, params: dict) -> dict:
        out = {
            "daemon": {
                "pid": os.getpid(),
                "protocol": PROTOCOL_VERSION,
                "socket": self.socket_path,
                "backend": type(self.backend).__name__,
                "uptime_s": _time.time() - self.started_at,
                "connections": self.connections,
                "inflight": self.inflight,
                "requests": dict(sorted(self.requests.items())),
                "throttled": self.throttled,
                "rate": self.rate,
                "burst": self.burst,
                "owners": len(self.owners),
            },
            "queue_cache": {
                "polls": self.cache.polls,
                "hits": self.cache.hits,
                "event_invalidations": self.cache.event_invalidations,
            },
        }
        if self.controller is not None:
            out["eco"] = {
                "held": len(self.controller.held),
                "released": len(self.controller.released),
            }
        reg = get_registry()
        if getattr(reg, "enabled", False):
            from repro.obs.export import snapshot

            out["metrics"] = snapshot(reg)["metrics"]
        return out

    def _rpc_shutdown(self, user: str, params: dict) -> dict:
        self._stop.set()
        return {"stopping": True}


class _StreamDone(Exception):
    pass
