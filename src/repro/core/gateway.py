"""Shared gateway daemon — one poller, one placer, N thin clients.

Every CLI process used to build its own backend, QueueCache, Placer and
EcoController; at institutional scale that is N users × M tools
independently hammering ``squeue`` and re-deriving identical placement
state. :class:`GatewayServer` is a long-running per-host daemon that owns
exactly ONE of each — the cache, the event bus, the federation
placer/backlog tracker that ride the backend, and the eco
hold-and-release controller — and serves thin clients over a Unix domain
socket. One backend poll serves everyone, and held-job release / eco
deadlines keep firing after the submitting shell exits because the
*daemon*, not the CLI, owns the controller.

Protocol: length-prefixed JSON-RPC. Each frame is a 4-byte big-endian
payload length followed by that many bytes of UTF-8 JSON. Requests are
``{"id": n, "method": str, "params": {...}}``; responses are
``{"id": n, "ok": true, "result": ...}`` or ``{"id": n, "ok": false,
"error": str}``. ``events_subscribe`` is the one streaming method: after
the initial response the server keeps sending ``{"event": {...}}``
frames until the client disconnects (or the requested duration elapses,
closed by an ``{"end": true}`` frame).

Methods: ``ping``, ``queue``, ``nodes_info``, ``submit_batch``,
``cancel``, ``release``, ``wait``, ``events_subscribe``, ``stats``,
``advance`` (simulated backends only) and ``shutdown``.

**Protocol v2 — the read hot path.** The daemon's serve loop is a
single-threaded non-blocking ``selectors`` reactor. Read-only RPCs
(``ping``/``queue``/``nodes_info``/``stats``) are answered from
*immutable pre-encoded frames* without taking the backend lock: a
:class:`SnapshotEncoder` serialises the QueueCache snapshot to wire
bytes once per cache **generation** (invalidated off the EventBus — the
same hook the cache already uses) and every client gets a spliced copy
of the cached bytes. On top of that, v2 clients can

* push **filters** down (``user``/``cluster``/``ids``/``states``) so a
  watcher of one user's jobs never ships the other 100k rows — filtered
  encodings are memoised per (generation, filter);
* send their last seen generation (``since``) and receive
  ``{"unchanged": true}`` or a per-job **add/update/remove delta**
  instead of the full snapshot.

v1 clients (no ``v``/``since``/``filters`` markers in the ``queue``
params) receive the plain row-list result, byte-identical to the PR-9
protocol. Mutating RPCs (``submit_batch``/``cancel``/``release``/
``advance``) and simulated-time pumping keep their serialized semantics
behind the backend lock; ``wait`` blocks in a per-request worker thread;
``events_subscribe`` fanout goes through per-subscriber bounded queues
drained by the serve loop, so a slow subscriber can never block the
bus callback.

Fair share: every request draws one token from the calling user's
token bucket (``rate`` tokens/s, ``burst`` capacity); an empty bucket
delays the request instead of rejecting it, so a flood from one user
slows that user down without starving the others. Delays are scheduled
on the reactor — a throttled user's requests wait in a heap, everyone
else keeps being served.

Namespacing: job ids submitted through the daemon are recorded against
the submitting user; ``cancel``/``release`` refuse to touch another
user's daemon-submitted jobs (ids the daemon never saw are passed
through — it cannot know their owner).

The thin-client side lives in :mod:`repro.cli.session`
(``GatewayClient``): it speaks this protocol and transparently falls
back to the in-process path when no daemon socket is present, which is
what gives every existing CLI daemon mode without code churn.
"""

from __future__ import annotations

import heapq
import json
import os
import selectors
import socket
import struct
import threading
import time as _time
from collections import OrderedDict, deque
from datetime import datetime

from repro.obs.metrics import get_registry

from . import events as ev
from .engine import QueueCache

PROTOCOL_VERSION = 2

#: frames above this are refused — a corrupt length prefix must not make
#: the daemon try to allocate gigabytes
MAX_FRAME_BYTES = 32 * 1024 * 1024

#: per-subscriber bounded event queue: a slow ``events_subscribe`` client
#: drops its oldest undelivered events instead of backing up the bus
EVENT_QUEUE_CAP = 4096

#: stop copying events into a subscriber's write buffer past this point —
#: they stay in the bounded queue until the socket drains
WRITE_BUFFER_SOFT_CAP = 1 << 20

#: how many past generations the snapshot encoder keeps for delta
#: computation; clients further behind transparently get a full snapshot
DELTA_HISTORY = 4

#: memoised encodings per generation (distinct filter keys); beyond this
#: frames are computed per-request rather than cached
ENCODER_MEMO_CAP = 128

_LEN = struct.Struct(">I")


class GatewayError(RuntimeError):
    """The daemon answered, but with an error (bad request, unknown id...)."""


class GatewayConnectionLost(ConnectionError):
    """The daemon went away mid-conversation (socket closed / refused)."""


# ---------------------------------------------------------------------------
# Framing (shared by server and client)
# ---------------------------------------------------------------------------


def dumps_wire(obj) -> bytes:
    """Canonical wire serialisation: compact separators, strict types.

    Non-JSON values raise :class:`GatewayError` naming the offender —
    the codec must fail loudly, not ``default=str`` a datetime into a
    string the other side silently misparses.
    """
    try:
        return json.dumps(obj, separators=(",", ":"), allow_nan=False).encode("utf-8")
    except (TypeError, ValueError) as e:
        raise GatewayError(f"unserializable value on the wire: {e}") from e


def encode_frame(obj) -> bytes:
    """``obj`` as one length-prefixed wire frame (bytes)."""
    payload = dumps_wire(obj)
    if len(payload) > MAX_FRAME_BYTES:
        raise GatewayError(f"frame too large ({len(payload)} bytes)")
    return _LEN.pack(len(payload)) + payload


def send_frame(sock: socket.socket, obj) -> None:
    """Serialise ``obj`` as one length-prefixed JSON frame."""
    sock.sendall(encode_frame(obj))


def recv_frame(sock: socket.socket):
    """Read one frame; returns the decoded object, or None on clean EOF.

    An oversized length prefix is rejected *before* any allocation —
    a corrupt or malicious peer cannot make the reader reserve
    gigabytes of buffer.
    """
    header = _recv_exact(sock, _LEN.size)
    if header is None:
        return None
    (length,) = _LEN.unpack(header)
    if length > MAX_FRAME_BYTES:
        raise GatewayError(f"frame too large ({length} bytes)")
    payload = _recv_exact(sock, length)
    if payload is None:
        raise GatewayConnectionLost("connection closed mid-frame")
    return json.loads(payload.decode("utf-8"))


def _recv_exact(sock: socket.socket, n: int) -> "bytes | None":
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def default_socket_path() -> str:
    """Where clients look for the daemon: ``$NBI_GATEWAY_SOCKET``, else a
    per-user path under ``$XDG_RUNTIME_DIR`` (``/tmp`` fallback)."""
    explicit = os.environ.get("NBI_GATEWAY_SOCKET", "")
    if explicit:
        return explicit
    run = os.environ.get("XDG_RUNTIME_DIR", "")
    if run and os.path.isdir(run):
        return os.path.join(run, "nbi-gateway.sock")
    return f"/tmp/nbi-gateway-{os.getuid()}.sock"


# ---------------------------------------------------------------------------
# Filter pushdown (shared by server-side pushdown and client-side fallback)
# ---------------------------------------------------------------------------

#: the canonical "no filters" key (full snapshot)
EMPTY_FILTER_KEY = (None, None, (), ())


def canonical_filter_key(filters) -> tuple:
    """Normalise a wire ``filters`` dict to a hashable memoisation key.

    ``(user, cluster, ids, states)`` — ``None`` means "not filtered on
    this dimension" (distinct from ``cluster=""``, which matches plain
    single-cluster rows). Ids and states are sorted tuples so the same
    logical filter always produces the same key.
    """
    if not isinstance(filters, dict) or not filters:
        return EMPTY_FILTER_KEY
    user = filters.get("user")
    user = str(user) if user not in (None, "") else None
    cluster = filters.get("cluster")
    cluster = None if cluster is None else str(cluster)
    ids = filters.get("ids")
    ids = tuple(sorted({str(i) for i in ids})) if ids else ()
    states = filters.get("states")
    states = tuple(sorted({str(s).upper() for s in states})) if states else ()
    return (user, cluster, ids, states)


def row_filter(key: tuple):
    """Predicate over squeue-shaped row dicts for a canonical filter key.

    One implementation shared by the daemon's pushdown, the thin
    client's local fallback against a v1 daemon, and the tests — every
    path must select the same rows.
    """
    user, cluster, ids, states = key
    state_set = set(states)
    if ids:
        from .federation import id_covers

    def pred(row: dict) -> bool:
        if user is not None and str(row.get("user", "")) != user:
            return False
        if cluster is not None and str(row.get("cluster", "")) != cluster:
            return False
        if state_set and str(row.get("state", "")) not in state_set:
            return False
        if ids:
            jid = row.get("jobid", "")
            if not any(id_covers(jid, req) for req in ids):
                return False
        return True

    return pred


# ---------------------------------------------------------------------------
# Fair-share rate limiting
# ---------------------------------------------------------------------------


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s, ``burst`` capacity.

    :meth:`reserve` always grants the token but returns how long the
    caller should wait before acting on it (0.0 while the bucket has
    credit) — delaying instead of rejecting is what makes the gateway's
    fair share a throttle, not an error path.
    """

    def __init__(self, rate: float, burst: float, clock=_time.monotonic):
        self.rate = max(1e-9, float(rate))
        self.burst = max(1.0, float(burst))
        self._clock = clock
        self._tokens = self.burst
        self._at = clock()
        self._lock = threading.Lock()

    def reserve(self, n: float = 1.0) -> float:
        """Take ``n`` tokens; returns seconds to wait before proceeding."""
        with self._lock:
            now = self._clock()
            self._tokens = min(self.burst, self._tokens + (now - self._at) * self.rate)
            self._at = now
            self._tokens -= n
            if self._tokens >= 0:
                return 0.0
            return -self._tokens / self.rate


# ---------------------------------------------------------------------------
# Job wire format (client serialises, daemon reconstructs)
# ---------------------------------------------------------------------------

_OPTS_FIELDS = None


def job_to_wire(job) -> dict:
    """A :class:`~repro.core.job.Job` as a JSON-safe dict."""
    from dataclasses import asdict

    return {
        "name": job.name,
        "commands": list(job.commands),
        "task_commands": list(job.task_commands) if job.task_commands else None,
        "files": list(job.files),
        "workdir": job.workdir,
        "sim_duration_s": job.sim_duration_s,
        "tool": getattr(job, "tool", ""),
        "cluster": getattr(job, "cluster", ""),
        "eco_meta": getattr(job, "eco_meta", None),
        "prelude": list(job.prelude),
        "trailer": list(job.trailer),
        "opts": asdict(job.opts),
    }


def job_from_wire(wire: dict):
    """Rebuild a submittable Job from :func:`job_to_wire` output.

    Unknown ``opts`` keys are dropped (a newer client talking to an older
    daemon must not crash it).
    """
    import dataclasses

    from .job import Job
    from .resources import Opts

    global _OPTS_FIELDS
    if _OPTS_FIELDS is None:
        _OPTS_FIELDS = {f.name for f in dataclasses.fields(Opts)}
    optsd = {k: v for k, v in dict(wire.get("opts") or {}).items()
             if k in _OPTS_FIELDS}
    job = Job(
        name=str(wire.get("name", "job")),
        command=list(wire.get("commands") or []),
        opts=Opts(**optsd),
        workdir=str(wire.get("workdir", "")),
        sim_duration_s=wire.get("sim_duration_s"),
    )
    job.files = [str(f) for f in wire.get("files") or []]
    tc = wire.get("task_commands")
    job.task_commands = [str(c) for c in tc] if tc else None
    job.prelude = [str(p) for p in wire.get("prelude") or []]
    job.trailer = [str(t) for t in wire.get("trailer") or []]
    job.tool = str(wire.get("tool", ""))
    eco_meta = wire.get("eco_meta")
    job.eco_meta = dict(eco_meta) if isinstance(eco_meta, dict) else None
    cluster = str(wire.get("cluster", ""))
    if cluster:
        job.cluster = cluster
    return job


def event_to_wire(event) -> dict:
    return {
        "type": event.type,
        "jobid": event.jobid,
        "at": event.at.isoformat() if hasattr(event.at, "isoformat") else str(event.at),
        "name": event.name,
        "user": event.user,
        "state": event.state,
        "node": event.node,
        "reason": event.reason,
        "cluster": event.cluster,
    }


def event_from_wire(wire: dict):
    at = wire.get("at", "")
    try:
        at = datetime.fromisoformat(at)
    except (TypeError, ValueError):
        at = datetime.now()
    return ev.JobEvent(
        type=str(wire.get("type", "")),
        jobid=str(wire.get("jobid", "")),
        at=at,
        name=str(wire.get("name", "")),
        user=str(wire.get("user", "")),
        state=str(wire.get("state", "")),
        node=str(wire.get("node", "")),
        reason=str(wire.get("reason", "")),
        cluster=str(wire.get("cluster", "")),
    )


# ---------------------------------------------------------------------------
# Snapshot encoder — serialize once per generation, serve everyone
# ---------------------------------------------------------------------------


class SnapshotEncoder:
    """Generation-tagged pre-encoded queue frames.

    The QueueCache bumps its ``generation`` whenever its snapshot
    changes identity (event invalidation, TTL refresh, mutator
    invalidation). The encoder serialises the snapshot — full, filtered,
    and as deltas against recent generations — to wire bytes **once**
    per (generation, view) and serves every subsequent request the
    cached bytes. On a 100k-job day that turns O(clients × jobs) JSON
    encoding into O(changes).

    Single-writer: all mutation happens on the daemon's serve-loop
    thread; only plain-int stats are read cross-thread.
    """

    def __init__(self, cache: QueueCache, lock: threading.RLock, *,
                 history: int = DELTA_HISTORY, memo_cap: int = ENCODER_MEMO_CAP):
        self.cache = cache
        self._lock = lock  # the daemon's backend lock, taken only to refresh
        self.history = int(history)
        self.memo_cap = int(memo_cap)
        self.generation: "int | None" = None
        self._rows: list = []
        self._by_id: dict = {}
        self._order: list = []
        #: filter key → (ordered jobids, encoded row-list bytes)
        self._full: dict = {}
        #: filter key → v2 full-result bytes ({"generation":g,"jobs":[...]})
        self._v2full: dict = {}
        #: (since, filter key) → delta-result bytes (None = delta not worth it)
        self._delta: dict = {}
        #: generation → (by_id, ordered jobids) for recent snapshots
        self._history: OrderedDict = OrderedDict()
        self._nodes_gen: "int | None" = None
        self._nodes_bytes: bytes = b"[]"
        # plain-int stats (exact even with metrics disabled)
        self.refreshes = 0      # snapshot re-materialisations (gen changes seen)
        self.encodes = 0        # JSON serialisations actually performed
        self.frame_hits = 0     # requests served from a cached encoding
        self.delta_hits = 0     # requests answered with a delta
        self.unchanged_hits = 0  # requests answered {"unchanged": true}
        self.full_serves = 0    # v2 requests answered with a full snapshot

    # -- snapshot currency -----------------------------------------------------

    def ensure_current(self) -> None:
        """Bring the encoder to the cache's current generation.

        The fast path is lock-free: while the cached frame generation
        matches the cache's valid snapshot generation, nothing happens.
        Only a stale snapshot takes the backend lock for the one
        single-flight refresh of this generation.
        """
        gen = self.cache.snapshot_generation()
        if gen is not None and gen == self.generation:
            return
        with self._lock:
            rows, gen = self.cache.queue_with_generation()
        if gen == self.generation:
            return
        if self.generation is not None:
            self._history[self.generation] = (self._by_id, self._order)
            while len(self._history) > self.history:
                self._history.popitem(last=False)
        self._rows = rows
        self._by_id = {str(r.get("jobid", "")): r for r in rows}
        self._order = list(self._by_id)
        self.generation = gen
        self._full.clear()
        self._v2full.clear()
        self._delta.clear()
        self.refreshes += 1

    def any_rows(self) -> bool:
        self.ensure_current()
        return bool(self._rows)

    # -- encodings -------------------------------------------------------------

    def _full_entry(self, key: tuple) -> "tuple[list, bytes]":
        entry = self._full.get(key)
        if entry is not None:
            self.frame_hits += 1
            return entry
        if key == EMPTY_FILTER_KEY:
            ids, rows = self._order, self._rows
        else:
            pred = row_filter(key)
            rows = [r for r in self._rows if pred(r)]
            ids = [str(r.get("jobid", "")) for r in rows]
        entry = (ids, dumps_wire(rows))
        self.encodes += 1
        reg = get_registry()
        if reg.enabled:
            reg.counter(
                "nbi_gateway_snapshot_encodes_total",
                "queue snapshot JSON serialisations (once per generation+filter)",
            ).inc()
        if len(self._full) < self.memo_cap:
            self._full[key] = entry
        return entry

    def result_v1(self, key: tuple) -> bytes:
        """The PR-9 wire result: the plain (filtered) row list."""
        return self._full_entry(key)[1]

    def result_v2(self, key: tuple, since) -> bytes:
        """Generation-wrapped result: unchanged / delta / full snapshot."""
        gen = self.generation
        if since is not None and since == gen:
            self.unchanged_hits += 1
            return b'{"generation":%d,"unchanged":true}' % gen
        if since is not None:
            delta = self._delta_bytes(int(since), key)
            if delta is not None:
                self.delta_hits += 1
                reg = get_registry()
                if reg.enabled:
                    reg.counter(
                        "nbi_gateway_delta_hits_total",
                        "queue RPCs answered with a generation delta",
                    ).inc()
                return delta
        buf = self._v2full.get(key)
        if buf is None:
            buf = b'{"generation":%d,"jobs":' % gen + self._full_entry(key)[1] + b"}"
            if len(self._v2full) < self.memo_cap:
                self._v2full[key] = buf
        else:
            self.frame_hits += 1
        self.full_serves += 1
        return buf

    def _delta_bytes(self, since: int, key: tuple) -> "bytes | None":
        memo_key = (since, key)
        if memo_key in self._delta:
            hit = self._delta[memo_key]
            if hit is not None:
                self.frame_hits += 1
            return hit
        hist = self._history.get(since)
        if hist is None:
            return None  # too far behind: fall back to a full snapshot
        old_by_id, old_order = hist
        if key == EMPTY_FILTER_KEY:
            old_ids = old_order
            new_ids = self._order
        else:
            pred = row_filter(key)
            old_ids = [i for i in old_order if pred(old_by_id[i])]
            new_ids = self._full_entry(key)[0]
        old_set = set(old_ids)
        new_set = set(new_ids)
        add = [self._by_id[i] for i in new_ids if i not in old_set]
        update = [
            self._by_id[i] for i in new_ids
            if i in old_set and self._by_id[i] != old_by_id[i]
        ]
        remove = [i for i in old_ids if i not in new_set]
        payload = {
            "generation": self.generation,
            "since": since,
            "delta": {"add": add, "update": update, "remove": remove},
        }
        # the client reconstructs order as survivors-then-adds; when the
        # true order differs (rare: priority reshuffles), ship it
        survivors = [i for i in old_ids if i in new_set]
        survivors += [i for i in new_ids if i not in old_set]
        if survivors != new_ids:
            payload["order"] = new_ids
        buf = dumps_wire(payload)
        self.encodes += 1
        if len(buf) >= len(self._full_entry(key)[1]):
            buf = None  # delta bigger than the snapshot: not worth it
        if len(self._delta) < self.memo_cap * 2:
            self._delta[memo_key] = buf
        return buf

    def nodes_result(self) -> bytes:
        """Node info, re-encoded once per generation (node occupancy only
        changes on job transitions, which bump the generation)."""
        self.ensure_current()
        if self._nodes_gen == self.generation:
            self.frame_hits += 1
            return self._nodes_bytes
        with self._lock:
            rows = self.cache.nodes_info()
        self._nodes_bytes = dumps_wire(rows)
        self._nodes_gen = self.generation
        self.encodes += 1
        return self._nodes_bytes

    def stats(self) -> dict:
        return {
            "generation": self.generation,
            "refreshes": self.refreshes,
            "encodes": self.encodes,
            "frame_hits": self.frame_hits,
            "delta_hits": self.delta_hits,
            "unchanged_hits": self.unchanged_hits,
            "full_serves": self.full_serves,
            "cached_filters": len(self._full),
            "delta_history": len(self._history),
        }


# ---------------------------------------------------------------------------
# Serve-loop plumbing
# ---------------------------------------------------------------------------


class _Conn:
    """One client connection in the reactor: buffers + optional sub."""

    __slots__ = ("sock", "rbuf", "wbuf", "alive", "close_after_flush", "sub")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.rbuf = bytearray()
        self.wbuf = bytearray()
        self.alive = True
        self.close_after_flush = False
        self.sub: "_EventSub | None" = None


class _EventSub:
    """An ``events_subscribe`` stream: bounded queue drained by the loop."""

    __slots__ = ("conn", "poll_s", "duration_s", "max_events",
                 "started", "sent", "queue", "dropped")

    def __init__(self, conn: _Conn, poll_s: float, duration_s: float,
                 max_events: int):
        self.conn = conn
        self.poll_s = poll_s
        self.duration_s = duration_s
        self.max_events = max_events
        self.started = _time.monotonic()
        self.sent = 0
        self.queue: deque = deque(maxlen=EVENT_QUEUE_CAP)
        self.dropped = 0


class _EventItem:
    """One bus event, wire-encoded once and shared across subscribers."""

    __slots__ = ("wire", "frame")

    def __init__(self, wire: dict):
        self.wire = wire
        self.frame: "bytes | None" = None

    def encoded(self) -> bytes:
        if self.frame is None:
            self.frame = encode_frame({"event": self.wire})
        return self.frame


# ---------------------------------------------------------------------------
# The daemon
# ---------------------------------------------------------------------------


class GatewayServer:
    """The per-host daemon: one cache, one bus, one controller; N clients.

    Parameters
    ----------
    backend:
        Backend-protocol object; default resolves via ``get_backend()``
        (federated when stanzas are configured — the Placer and
        BacklogTracker then ride along and are shared by every client).
    socket_path:
        Unix socket to listen on (default :func:`default_socket_path`).
    ttl_s:
        QueueCache TTL. Event invalidation makes staleness event-driven;
        the TTL is only the fallback for eventless backends.
    eco:
        Build an :class:`~repro.core.ecocontroller.EcoController` owned
        by the daemon: ``submit_batch(eco=True)`` submissions are held
        and released reactively even after the submitting shell exits.
    rate / burst:
        Per-user token-bucket fair share (tokens/s, bucket capacity).
    poll_s:
        Background pump cadence against non-simulated backends (the
        PollingEventAdapter poll / controller tick interval).
    """

    #: read-only RPCs answered from immutable cached frames on the serve
    #: loop, never behind the backend lock
    _READONLY = frozenset({"ping", "queue", "nodes_info", "stats"})
    #: RPCs that mutate cluster state (or simulated time): serialized
    #: behind the backend lock, exactly the PR-9 semantics
    _MUTATING = frozenset({"submit_batch", "cancel", "release", "advance"})

    def __init__(
        self,
        backend=None,
        socket_path: str | None = None,
        *,
        ttl_s: float = 2.0,
        eco: bool = True,
        rate: float = 50.0,
        burst: float = 100.0,
        max_throttle_s: float = 2.0,
        poll_s: float = 15.0,
        clock=_time.monotonic,
    ):
        if backend is None:
            from .backend import get_backend

            backend = get_backend()
        inner = backend.inner if isinstance(backend, QueueCache) else backend
        self.backend = inner
        self.cache = (
            backend if isinstance(backend, QueueCache)
            else QueueCache(inner, ttl_s=ttl_s)
        )
        self.socket_path = socket_path or default_socket_path()
        self._clock = clock
        self.rate = float(rate)
        self.burst = float(burst)
        self.max_throttle_s = float(max_throttle_s)
        self.poll_s = float(poll_s)
        #: serialises every backend mutation — from the serve loop's
        #: mutating RPCs, wait workers and the background pump (the
        #: simulator is not thread-safe; real squeue/sbatch calls gain
        #: nothing from racing). Read RPCs never take it.
        self._lock = threading.RLock()
        self._sim_like = hasattr(inner, "advance")
        self._adapter = None
        bus = getattr(inner, "bus", None)
        if bus is None:
            # pushless backend (real SLURM): the daemon owns the single
            # polling adapter; its bus is the daemon bus
            self._adapter = ev.PollingEventAdapter(self.cache)
            bus = self._adapter.bus
        self.bus = bus
        self.controller = None
        if eco:
            from .ecocontroller import EcoController

            self.controller = EcoController(self.cache)
        from .config import load_config

        cfg = load_config()
        self._eco_default = cfg.get_bool("economy_mode")
        try:
            from repro.accounting import predictor_from_config

            self.predictor = predictor_from_config(cfg)
        except Exception:  # noqa: BLE001 — predictor is an optional refinement
            self.predictor = None
        #: base job id (str) → submitting user (per-user namespacing)
        self.owners: dict[str, str] = {}
        self._buckets: dict[str, TokenBucket] = {}
        self._buckets_lock = threading.Lock()
        self.snapshots = SnapshotEncoder(self.cache, self._lock)
        # plain-int daemon stats (exact even with metrics disabled)
        self.started_at = _time.time()
        self.connections = 0
        self.inflight = 0
        self.requests: dict[str, int] = {}
        self.throttled = 0
        self.events_dropped = 0
        self._stop = threading.Event()
        self._listener: socket.socket | None = None
        self._pump_thread: threading.Thread | None = None
        self._wait_wakeup = threading.Event()
        # reactor state (owned by the serve-loop thread)
        self._sel: "selectors.BaseSelector | None" = None
        self._conns: dict[int, _Conn] = {}
        self._delayed: list = []  # (due, seq, conn, req) throttled requests
        self._delay_seq = 0
        #: wait-RPC worker threads, pruned every loop pass (the PR-9
        #: ``_threads`` list was only pruned when a NEW client connected,
        #: so long-lived wait/subscribe connections accumulated forever)
        self._workers: list[threading.Thread] = []
        self._outbox: deque = deque()  # (conn, obj) replies from workers
        self._subs: list[_EventSub] = []
        self._fanout_token = None
        self._wake_r: socket.socket | None = None
        self._wake_w: socket.socket | None = None

    # -- lifecycle -------------------------------------------------------------

    def bind(self) -> "GatewayServer":
        """Create and bind the listening socket (idempotent)."""
        if self._listener is not None:
            return self
        path = self.socket_path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if os.path.exists(path):
            # leftover from a crashed daemon? refuse only if it's live
            probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                probe.settimeout(0.25)
                probe.connect(path)
                probe.close()
                raise GatewayError(f"another gateway is live on {path}")
            except (ConnectionRefusedError, socket.timeout, FileNotFoundError, OSError) as e:
                if isinstance(e, GatewayError):
                    raise
                probe.close()
                os.unlink(path)
        listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        listener.bind(path)
        # login-node usage: other users' thin clients must be able to
        # connect (requests carry the user; ids are namespaced per user)
        try:
            os.chmod(path, 0o666)
        except OSError:
            pass
        listener.listen(128)
        listener.setblocking(False)
        self._listener = listener
        return self

    def start(self) -> threading.Thread:
        """Serve in a daemon thread (tests, benchmarks, embedded use)."""
        self.bind()
        t = threading.Thread(target=self.serve_forever, daemon=True,
                             name="nbi-gateway-serve")
        t.start()
        return t

    def serve_forever(self) -> None:
        """The reactor: accept, read, dispatch, write — one thread,
        no blocking syscalls. Returns after :meth:`close` (or the
        ``shutdown`` RPC)."""
        self.bind()
        if not self._sim_like and self._pump_thread is None:
            self._pump_thread = threading.Thread(
                target=self._pump_loop, daemon=True, name="nbi-gateway-pump"
            )
            self._pump_thread.start()
        sel = selectors.DefaultSelector()
        self._sel = sel
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        sel.register(self._listener, selectors.EVENT_READ, "accept")
        sel.register(self._wake_r, selectors.EVENT_READ, "wake")
        try:
            while not self._stop.is_set():
                try:
                    events = sel.select(self._loop_timeout())
                except OSError:
                    break  # listener closed under us (close())
                for key, mask in events:
                    if key.data == "accept":
                        self._accept_ready()
                    elif key.data == "wake":
                        try:
                            while self._wake_r.recv(4096):
                                pass
                        except (BlockingIOError, OSError):
                            pass
                    else:
                        conn = key.data
                        if mask & selectors.EVENT_READ:
                            self._on_readable(conn)
                        if mask & selectors.EVENT_WRITE and conn.alive:
                            self._flush_conn(conn)
                self._drain_outbox()
                self._run_due_throttled()
                self._pump_subscribers()
                if self._workers:
                    self._workers = [t for t in self._workers if t.is_alive()]
        finally:
            self._teardown_reactor()

    def _loop_timeout(self) -> float:
        if self._subs and self._sim_like:
            return 0.0  # simulated time only moves when we pump
        timeout = 0.2
        if self._delayed:
            timeout = min(timeout, max(0.0, self._delayed[0][0] - self._clock()))
        return timeout

    def _teardown_reactor(self) -> None:
        for sub in list(self._subs):
            self._end_sub(sub)  # stream clients get their {"end": ...} frame
        if self._fanout_token is not None:
            self.bus.unsubscribe(self._fanout_token)
            self._fanout_token = None
        self._subs.clear()
        for conn in list(self._conns.values()):
            self._close_conn(conn)
        self._conns.clear()
        for s in (self._wake_r, self._wake_w):
            if s is not None:
                try:
                    s.close()
                except OSError:
                    pass
        self._wake_r = self._wake_w = None
        if self._sel is not None:
            try:
                self._sel.close()
            except OSError:
                pass
            self._sel = None

    def _wake(self) -> None:
        """Nudge the reactor out of ``select`` (cross-thread safe)."""
        w = self._wake_w
        if w is not None:
            try:
                w.send(b"x")
            except (BlockingIOError, OSError):
                pass

    def close(self) -> None:
        """Stop serving and detach everything the daemon subscribed.

        A closed daemon must leave the backend exactly as it found it:
        cache unbound from the bus, controller hooks removed — cycling
        daemons in one process (tests) must not accumulate stale
        subscribers.
        """
        self._stop.set()
        self._wait_wakeup.set()
        self._wake()
        if self._listener is not None:
            try:
                self._listener.close()
            finally:
                self._listener = None
        try:
            if os.path.exists(self.socket_path):
                os.unlink(self.socket_path)
        except OSError:
            pass
        if self.controller is not None:
            self.controller.detach()
        self.cache.unbind_bus()

    # -- reactor: connections --------------------------------------------------

    def _accept_ready(self) -> None:
        while True:
            try:
                sock, _ = self._listener.accept()
            except (BlockingIOError, OSError):
                return
            sock.setblocking(False)
            conn = _Conn(sock)
            self._conns[sock.fileno()] = conn
            self._sel.register(sock, selectors.EVENT_READ, conn)
            self.connections += 1
            self.inflight += 1
            reg = get_registry()
            if reg.enabled:
                reg.gauge(
                    "nbi_gateway_inflight_connections", "open client connections"
                ).set(self.inflight)
                reg.counter(
                    "nbi_gateway_connections_total", "client connections accepted"
                ).inc()

    def _close_conn(self, conn: _Conn) -> None:
        if not conn.alive:
            return
        conn.alive = False
        if conn.sub is not None:
            if conn.sub in self._subs:
                self._subs.remove(conn.sub)
            conn.sub = None
            self._maybe_drop_fanout()
        try:
            fd = conn.sock.fileno()
        except OSError:
            fd = -1
        self._conns.pop(fd, None)
        if self._sel is not None:
            try:
                self._sel.unregister(conn.sock)
            except (KeyError, ValueError, OSError):
                pass
        try:
            conn.sock.close()
        except OSError:
            pass
        self.inflight -= 1
        reg = get_registry()
        if reg.enabled:
            reg.gauge(
                "nbi_gateway_inflight_connections", "open client connections"
            ).set(self.inflight)

    def _on_readable(self, conn: _Conn) -> None:
        try:
            while True:
                chunk = conn.sock.recv(65536)
                if not chunk:
                    self._close_conn(conn)
                    return
                conn.rbuf += chunk
                if len(chunk) < 65536:
                    break
        except BlockingIOError:
            pass
        except OSError:
            self._close_conn(conn)
            return
        self._parse_frames(conn)

    def _parse_frames(self, conn: _Conn) -> None:
        while conn.alive and not conn.close_after_flush:
            if len(conn.rbuf) < _LEN.size:
                return
            (length,) = _LEN.unpack_from(conn.rbuf, 0)
            if length > MAX_FRAME_BYTES:
                # structured refusal BEFORE any allocation, then hang up —
                # the stream is unrecoverable once framing is suspect
                self._send_obj(conn, {
                    "id": None, "ok": False,
                    "error": f"frame too large ({length} bytes, "
                             f"cap {MAX_FRAME_BYTES})",
                })
                conn.close_after_flush = True
                self._flush_conn(conn)
                return
            if len(conn.rbuf) < _LEN.size + length:
                return
            payload = bytes(conn.rbuf[_LEN.size:_LEN.size + length])
            del conn.rbuf[:_LEN.size + length]
            try:
                req = json.loads(payload.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                self._send_obj(conn, {
                    "id": None, "ok": False, "error": f"invalid frame: {e}",
                })
                conn.close_after_flush = True
                self._flush_conn(conn)
                return
            self._dispatch(conn, req if isinstance(req, dict) else {})

    # -- reactor: writes -------------------------------------------------------

    def _send_obj(self, conn: _Conn, obj) -> None:
        try:
            self._send_bytes(conn, encode_frame(obj))
        except GatewayError:
            # the RESULT was unserializable; tell the client loudly
            rid = obj.get("id") if isinstance(obj, dict) else None
            self._send_bytes(conn, encode_frame({
                "id": rid, "ok": False, "error": "unserializable result",
            }))

    def _send_bytes(self, conn: _Conn, data: bytes) -> None:
        if not conn.alive:
            return
        conn.wbuf += data
        self._flush_conn(conn)

    def _flush_conn(self, conn: _Conn) -> None:
        if not conn.alive:
            return
        try:
            while conn.wbuf:
                sent = conn.sock.send(conn.wbuf)
                if sent <= 0:
                    break
                del conn.wbuf[:sent]
        except BlockingIOError:
            pass
        except OSError:
            self._close_conn(conn)
            return
        want = selectors.EVENT_READ
        if conn.wbuf:
            want |= selectors.EVENT_WRITE
        elif conn.close_after_flush:
            self._close_conn(conn)
            return
        if self._sel is None:
            return
        try:
            self._sel.modify(conn.sock, want, conn)
        except (KeyError, ValueError, OSError):
            pass

    # -- reactor: dispatch -----------------------------------------------------

    def _dispatch(self, conn: _Conn, req: dict) -> None:
        params = req.get("params") or {}
        if not isinstance(params, dict):
            params = {}
        user = str(params.get("user", "") or "") or "anonymous"
        delay = self._bucket(user).reserve()
        if delay > 0:
            self.throttled += 1
            reg = get_registry()
            if reg.enabled:
                reg.counter(
                    "nbi_gateway_throttled_total",
                    "requests delayed by fair-share rate limiting",
                ).inc()
            due = self._clock() + min(delay, self.max_throttle_s)
            if due > self._clock():
                self._delay_seq += 1
                heapq.heappush(self._delayed, (due, self._delay_seq, conn, req))
                return
        self._process(conn, req)

    def _run_due_throttled(self) -> None:
        now = self._clock()
        while self._delayed and self._delayed[0][0] <= now:
            _, _, conn, req = heapq.heappop(self._delayed)
            if conn.alive:
                self._process(conn, req)

    def _process(self, conn: _Conn, req: dict) -> None:
        method = str(req.get("method", ""))
        params = req.get("params") or {}
        if not isinstance(params, dict):
            params = {}
        user = str(params.get("user", "") or "") or "anonymous"
        rid = req.get("id")
        self.requests[method] = self.requests.get(method, 0) + 1
        t0 = _time.perf_counter()
        try:
            if method == "queue":
                self._handle_queue(conn, rid, params)
            elif method == "nodes_info":
                self._send_result_bytes(conn, rid, self.snapshots.nodes_result())
            elif method == "ping":
                self._send_obj(conn, {"id": rid, "ok": True,
                                      "result": self._rpc_ping(user, params)})
            elif method == "stats":
                self._send_obj(conn, {"id": rid, "ok": True,
                                      "result": self._rpc_stats(user, params)})
            elif method == "wait":
                self._spawn_wait_worker(conn, rid, user, params)
            elif method == "events_subscribe":
                self._subscribe(conn, rid, user, params)
            elif method == "shutdown":
                self._send_obj(conn, {"id": rid, "ok": True,
                                      "result": {"stopping": True}})
                self._flush_blocking(conn)
                self._stop.set()
            elif method in self._MUTATING:
                handler = getattr(self, f"_rpc_{method}")
                with self._lock:
                    result = handler(user, params)
                self._send_obj(conn, {"id": rid, "ok": True, "result": result})
            else:
                raise GatewayError(f"unknown method {method!r}")
        except (GatewayError, ValueError, KeyError, TypeError) as e:
            self._send_obj(conn, {"id": rid, "ok": False, "error": str(e)})
        except Exception as e:  # noqa: BLE001 — a backend hiccup must not
            # take down the reactor (there is only one serve thread now)
            self._send_obj(conn, {"id": rid, "ok": False, "error": str(e)})
        finally:
            reg = get_registry()
            if reg.enabled:
                reg.counter(
                    "nbi_gateway_requests_total", "gateway RPCs served",
                    labels=("method",),
                ).labels(method=method or "?").inc()
                reg.histogram(
                    "nbi_gateway_request_seconds", "gateway RPC latency",
                    labels=("method",),
                ).labels(method=method or "?").observe(_time.perf_counter() - t0)

    def _flush_blocking(self, conn: _Conn) -> None:
        """Best-effort synchronous drain (shutdown reply must land)."""
        if not conn.alive or not conn.wbuf:
            return
        try:
            conn.sock.setblocking(True)
            conn.sock.settimeout(1.0)
            conn.sock.sendall(bytes(conn.wbuf))
            conn.wbuf.clear()
        except OSError:
            pass
        finally:
            try:
                conn.sock.setblocking(False)
            except OSError:
                pass

    def _send_result_bytes(self, conn: _Conn, rid, result: bytes) -> None:
        """Splice pre-encoded result bytes into a response frame.

        Byte-identical to ``send_frame(conn, {"id": rid, "ok": True,
        "result": <decoded>})`` — same key order, same separators — so a
        v1 client cannot tell cached frames from per-request encoding.
        """
        body = b'{"id":' + dumps_wire(rid) + b',"ok":true,"result":' + result + b"}"
        if len(body) > MAX_FRAME_BYTES:
            self._send_obj(conn, {
                "id": rid, "ok": False,
                "error": f"result too large ({len(body)} bytes)",
            })
            return
        self._send_bytes(conn, _LEN.pack(len(body)) + body)

    def _handle_queue(self, conn: _Conn, rid, params: dict) -> None:
        enc = self.snapshots
        enc.ensure_current()
        v2 = bool(params.get("v")) or "since" in params or "filters" in params
        key = canonical_filter_key(params.get("filters"))
        if not v2:
            self._send_result_bytes(conn, rid, enc.result_v1(key))
            return
        since = params.get("since")
        since = int(since) if isinstance(since, (int, float)) else None
        self._send_result_bytes(conn, rid, enc.result_v2(key, since))

    def _bucket(self, user: str) -> TokenBucket:
        with self._buckets_lock:
            b = self._buckets.get(user)
            if b is None:
                b = self._buckets[user] = TokenBucket(
                    self.rate, self.burst, clock=self._clock
                )
            return b

    # -- pump (shared clock/event driver) -----------------------------------------

    def _pump_once(self, step_s: float) -> None:
        """One event-delivery step: advance the simulator, or take one
        adapter poll + controller tick against a real backend."""
        with self._lock:
            if self._sim_like:
                self.cache.advance(step_s)  # mutator wrapper invalidates
            elif self._adapter is not None:
                self.cache.invalidate()  # the adapter must see fresh rows
                self._adapter.poll()
                if self.controller is not None:
                    self.controller.tick(datetime.now())
        self._wait_wakeup.set()
        self._wait_wakeup.clear()

    def _pump_loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            try:
                self._pump_once(self.poll_s)
            except Exception:  # noqa: BLE001 — the pump must survive squeue hiccups
                pass

    # -- event fanout (bounded per-subscriber queues) ------------------------------

    def _subscribe(self, conn: _Conn, rid, user: str, params: dict) -> None:
        if conn.sub is not None:
            raise GatewayError("connection already has an event subscription")
        sub = _EventSub(
            conn,
            poll_s=float(params.get("poll_s", 2.0) or 2.0),
            duration_s=float(params.get("duration_s", 0.0) or 0.0),
            max_events=int(params.get("max_events", 0) or 0),
        )
        conn.sub = sub
        self._subs.append(sub)
        if self._fanout_token is None:
            self._fanout_token = self.bus.subscribe(self._on_bus_event)
        self._send_obj(conn, {"id": rid, "ok": True,
                              "result": {"subscribed": True}})

    def _on_bus_event(self, event) -> None:
        """Bus callback: append to every subscriber's bounded queue and
        return — never encodes into sockets, never blocks on a slow
        client. May run on any thread (pump, wait worker, serve loop)."""
        subs = self._subs
        if not subs:
            return
        item = _EventItem(event_to_wire(event))
        for sub in list(subs):
            if len(sub.queue) == sub.queue.maxlen:
                sub.dropped += 1
                self.events_dropped += 1
                reg = get_registry()
                if reg.enabled:
                    reg.counter(
                        "nbi_gateway_events_dropped_total",
                        "events dropped at full subscriber queues",
                    ).inc()
            sub.queue.append(item)
        self._wake()

    def _maybe_drop_fanout(self) -> None:
        if not self._subs and self._fanout_token is not None:
            self.bus.unsubscribe(self._fanout_token)
            self._fanout_token = None

    def _pump_subscribers(self) -> None:
        """Serve-loop stage: advance simulated time for streaming clients,
        drain subscriber queues into write buffers, retire finished
        streams."""
        if not self._subs:
            return
        if self._sim_like:
            self._pump_once(min(s.poll_s for s in self._subs))
        now = _time.monotonic()
        drained = self._sim_like and not self.snapshots.any_rows()
        for sub in list(self._subs):
            conn = sub.conn
            done = False
            while sub.queue:
                if len(conn.wbuf) > WRITE_BUFFER_SOFT_CAP:
                    break  # back-pressure: keep events queued, not buffered
                item = sub.queue.popleft()
                self._send_bytes(conn, item.encoded())
                sub.sent += 1
                if sub.max_events and sub.sent >= sub.max_events:
                    done = True
                    break
            if not done and sub.duration_s and now - sub.started >= sub.duration_s:
                done = True
            if not done and drained and not sub.queue:
                done = True  # simulated queue empty: nothing left to stream
            if done:
                self._end_sub(sub)

    def _end_sub(self, sub: _EventSub) -> None:
        conn = sub.conn
        if sub in self._subs:
            self._subs.remove(sub)
        conn.sub = None
        self._maybe_drop_fanout()
        self._send_obj(conn, {"end": True, "events": sub.sent})

    # -- wait workers --------------------------------------------------------------

    def _spawn_wait_worker(self, conn: _Conn, rid, user: str,
                           params: dict) -> None:
        """``wait`` legitimately blocks for minutes; it gets a worker
        thread and posts its reply back through the reactor's outbox."""

        def run():
            try:
                result = self._rpc_wait(user, params)
                reply = {"id": rid, "ok": True, "result": result}
            except (GatewayError, ValueError, KeyError, TypeError) as e:
                reply = {"id": rid, "ok": False, "error": str(e)}
            self._outbox.append((conn, reply))
            self._wake()

        t = threading.Thread(target=run, daemon=True,
                             name=f"nbi-gateway-wait-{rid}")
        self._workers.append(t)
        t.start()

    def _drain_outbox(self) -> None:
        while self._outbox:
            conn, obj = self._outbox.popleft()
            if conn.alive:
                self._send_obj(conn, obj)

    # -- RPC handlers --------------------------------------------------------------

    def _rpc_ping(self, user: str, params: dict) -> dict:
        return {
            "pong": True,
            "pid": os.getpid(),
            "protocol": PROTOCOL_VERSION,
            "backend": type(self.backend).__name__,
        }

    def _rpc_submit_batch(self, user: str, params: dict) -> dict:
        wires = params.get("jobs")
        if not isinstance(wires, list) or not wires:
            raise GatewayError("submit_batch needs a non-empty jobs list")
        jobs = [job_from_wire(w) for w in wires]
        eco = params.get("eco")
        eco = self._eco_default if eco is None else bool(eco)
        from .engine import SubmitEngine

        engine = SubmitEngine(
            self.cache,
            coalesce=bool(params.get("coalesce", True)),
            eco=eco,
            controller=self.controller if eco else None,
            predictor=self.predictor,
        )
        result = engine.submit_many(jobs)
        from .federation import array_base_id

        for base in result.base_ids:
            self.owners[array_base_id(str(base))] = user
        return {
            "ids": list(result.ids),
            "base_ids": [str(b) for b in result.base_ids],
            "sbatch_calls": result.sbatch_calls,
            "coalesced": result.coalesced,
            "eco_deferred": result.eco_deferred,
            "placements": sorted(p for p in result.placements if p),
        }

    def _partition_owned(self, user: str, ids: list) -> "tuple[list, list]":
        """Split requested ids into (allowed, denied-by-namespacing)."""
        from .federation import array_base_id

        allowed, denied = [], []
        for jid in ids:
            owner = self.owners.get(array_base_id(str(jid)))
            if owner is not None and owner != user:
                denied.append(str(jid))
            else:
                allowed.append(str(jid))
        return allowed, denied

    def _rpc_cancel(self, user: str, params: dict) -> dict:
        ids = list(params.get("ids") or [])
        allowed, denied = self._partition_owned(user, ids)
        if allowed:
            self.cache.cancel(allowed)
        return {"cancelled": allowed, "denied": denied}

    def _rpc_release(self, user: str, params: dict) -> dict:
        ids = list(params.get("ids") or [])
        allowed, denied = self._partition_owned(user, ids)
        if allowed:
            self.cache.release(allowed)
        return {"released": allowed, "denied": denied}

    def _rpc_advance(self, user: str, params: dict) -> dict:
        if not self._sim_like:
            raise GatewayError("advance is only available on simulated backends")
        seconds = float(params.get("seconds", 0.0))
        self._pump_once(seconds)
        now = getattr(self.backend, "now", None)
        return {"now": now.isoformat() if now is not None else ""}

    def _rpc_wait(self, user: str, params: dict) -> dict:
        """Block until the watch set drains; returns per-job final states.

        The daemon waits on its own bus — one subscription serves the
        request regardless of how many jobs are watched, and against a
        simulated backend the wait itself advances simulated time (the
        RPC is the clock, exactly like ``waitjobs`` in-process).
        """
        from repro.cli.waitjobs import _final_states, _id_matches, _norm_state

        ids = params.get("ids") or None
        watch_user = params.get("watch_user") or None
        name = params.get("name") or None
        poll_s = float(params.get("poll_s", self.poll_s) or self.poll_s)
        timeout_s = float(params.get("timeout_s", 0.0) or 0.0)

        from .queue import Queue

        with self._lock:
            q = Queue(user=watch_user, name=name, backend=self.cache)
            if ids:
                want = {str(i) for i in ids}
                watched = {j.jobid for j in q
                           if any(_id_matches(j.jobid, req) for req in want)}
            else:
                watched = set(q.ids())
        states: dict[str, str] = {}
        snapshots = 1
        if ids:
            gone = [req for req in {str(i) for i in ids}
                    if not any(_id_matches(w, req) for w in watched)]
            if gone:
                with self._lock:
                    states.update(_final_states(self.backend, gone))
        remaining = set(watched)
        ok = True
        if remaining:
            done_evt = threading.Event()

            def on_event(event):
                if event.jobid in remaining:
                    states[event.jobid] = _norm_state(event.state) or event.type
                    remaining.discard(event.jobid)
                    if not remaining:
                        done_evt.set()

            token = self.bus.subscribe(on_event, types=ev.TERMINAL_EVENTS)
            start = _time.monotonic()
            try:
                while remaining and not self._stop.is_set():
                    if timeout_s and _time.monotonic() - start > timeout_s:
                        ok = False
                        break
                    if self._sim_like:
                        # native events: advancing IS the wait; no snapshots
                        self._pump_once(poll_s)
                        _time.sleep(0.001)  # yield; bounded CPU on long waits
                    else:
                        done_evt.wait(min(poll_s, 1.0))
            finally:
                self.bus.unsubscribe(token)
            if ok and remaining:
                with self._lock:
                    states.update(_final_states(self.backend, remaining))
        return {
            "ok": ok,
            "states": dict(sorted(states.items())),
            "snapshots": snapshots,
        }

    def _rpc_stats(self, user: str, params: dict) -> dict:
        out = {
            "daemon": {
                "pid": os.getpid(),
                "protocol": PROTOCOL_VERSION,
                "socket": self.socket_path,
                "backend": type(self.backend).__name__,
                "uptime_s": _time.time() - self.started_at,
                "connections": self.connections,
                "inflight": self.inflight,
                "requests": dict(sorted(self.requests.items())),
                "throttled": self.throttled,
                "rate": self.rate,
                "burst": self.burst,
                "owners": len(self.owners),
                "subscribers": len(self._subs),
                "wait_workers": len(self._workers),
                "events_dropped": self.events_dropped,
            },
            "queue_cache": {
                "polls": self.cache.polls,
                "hits": self.cache.hits,
                "event_invalidations": self.cache.event_invalidations,
                "generation": self.cache.generation,
            },
            "snapshot": self.snapshots.stats(),
        }
        if self.controller is not None:
            out["eco"] = {
                "held": len(self.controller.held),
                "released": len(self.controller.released),
            }
        reg = get_registry()
        if getattr(reg, "enabled", False):
            from repro.obs.export import snapshot

            out["metrics"] = snapshot(reg)["metrics"]
        return out

    def _rpc_shutdown(self, user: str, params: dict) -> dict:
        self._stop.set()
        return {"stopping": True}
