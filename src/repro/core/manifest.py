"""``Manifest`` — JSON provenance records (port of ``NBI::Manifest``).

Serialises all resolved inputs, parameters, outputs and SLURM resources to a
JSON file written alongside the results at submission time, then *patched
in-place by the job script itself* upon completion or failure — with no
dependency on external tools such as ``jq`` (the patch trailer uses only
``python3 -c`` with the standard library, the Python analogue of the Perl
original patching with its own interpreter).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

SCHEMA_VERSION = 2


class Manifest:
    """Provenance record for one submitted analysis/training job."""

    def __init__(
        self,
        path: str,
        *,
        tool: str = "",
        version: str = "",
        inputs: dict | None = None,
        params: dict | None = None,
        outputs: dict | None = None,
        resources: dict | None = None,
    ):
        self.path = str(Path(path))
        self.record = {
            "schema_version": SCHEMA_VERSION,
            "tool": tool,
            "tool_version": version,
            "inputs": inputs or {},
            "params": params or {},
            "outputs": outputs or {},
            "resources": resources or {},
            "status": "created",
            "jobid": None,
            "submitted_at": None,
            "finished_at": None,
            "exit_status": None,
        }

    # -- lifecycle -------------------------------------------------------------

    def write_submitted(self, jobid: "int | None" = None) -> str:
        """Write the manifest at submission time."""
        self.record["status"] = "submitted"
        self.record["jobid"] = jobid
        self.record["submitted_at"] = _now_iso()
        Path(self.path).parent.mkdir(parents=True, exist_ok=True)
        Path(self.path).write_text(json.dumps(self.record, indent=2, sort_keys=True) + "\n")
        return self.path

    @staticmethod
    def load(path: str) -> dict:
        return json.loads(Path(path).read_text())

    @staticmethod
    def patch(path: str, **updates) -> dict:
        """In-place JSON patch (what the job trailer does at completion)."""
        rec = Manifest.load(path)
        rec.update(updates)
        Path(path).write_text(json.dumps(rec, indent=2, sort_keys=True) + "\n")
        return rec

    # -- script integration ------------------------------------------------------

    def trailer_lines(self) -> list[str]:
        """Shell lines appended to the job script: patch the manifest with the
        job's outcome. Uses a shell EXIT trap so failures are recorded too."""
        patcher = (
            "python3 -c \"import json,sys,datetime;"
            "p=sys.argv[1];rec=json.load(open(p));"
            "rec['status']='completed' if sys.argv[2]=='0' else 'failed';"
            "rec['exit_status']=int(sys.argv[2]);"
            "rec['finished_at']=datetime.datetime.now().isoformat(timespec='seconds');"
            "json.dump(rec,open(p,'w'),indent=2,sort_keys=True)\""
            f" {_shq(self.path)} \"$NBI_RC\""
        )
        return [
            "# --- NBI manifest patch-on-exit (stdlib only, no external JSON tool) ---",
            f"nbi_manifest_patch() {{ NBI_RC=$?; {patcher}; }}",
            "trap nbi_manifest_patch EXIT",
        ]


def _now_iso() -> str:
    return time.strftime("%Y-%m-%dT%H:%M:%S")


def _shq(s: str) -> str:
    return "'" + s.replace("'", "'\"'\"'") + "'"
