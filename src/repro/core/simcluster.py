"""``SimCluster`` — a deterministic, in-process SLURM simulator.

The paper requires that "all tests will be able to check functions even
without Slurm". The simulator goes further: a discrete-event model of a
cluster (nodes, partitions, FIFO scheduling, ``--begin`` eligibility,
``afterok`` dependencies, time limits, requeue-on-node-failure and job
arrays) so that queue tools, eco deferral, pipelines and fault-tolerance
drills are all *integration-tested* — deterministically, on any machine.

With ``execute=True`` the simulator actually runs each job's script through
``bash`` at (simulated) completion time, which lets tests verify end-to-end
behaviour such as the manifest being patched in place by the job itself.

Energy telemetry: every job that consumed CPU time is charged a
deterministic ``energy_j = watts_per_cpu × cpus × elapsed_seconds`` when it
reaches a terminal state — the simulator's analogue of sacct's
``ConsumedEnergy``, which :func:`repro.accounting.collect` harvests into
the job archive.

Events: every state transition is announced on :attr:`SimCluster.bus` as a
typed :class:`~repro.core.events.JobEvent` at the exact simulated instant
it happens — callers subscribe instead of diffing ``queue()`` snapshots.
``tick_hooks`` and ``wake_at()`` let reactive controllers (the eco
hold-and-release daemon) run at every event boundary and at their own
deadlines inside ``advance()``.

Scaling: the simulator keeps a single ``heapq`` **event calendar**
(completion times pushed at start, ``--begin`` eligibility at submit,
scheduled node failures, ``wake_at`` deadlines) with lazy invalidation,
so finding the next stop is O(log n) instead of a full active-set scan.
Scheduling works off **incrementally maintained eligibility sets**: a
FIFO runnable deque (ids are monotonic, so insertion order is priority
order) plus implicit parking for held / begin-gated / dependency-blocked
jobs — dependency waiters are woken by terminal events on their
dependency's base id, begin-gated jobs by the calendar — so a pass
touches only eligible work, with a max-free-capacity early exit when a
failed requirement dominates everything still runnable. The schedule is
pinned bit-identical to the straightforward reference implementation in
:mod:`repro.core.simref` by ``tests/test_sim_equivalence.py``.
"""

from __future__ import annotations

import heapq
import os
import subprocess
from collections import deque
from dataclasses import dataclass, field
from datetime import datetime, timedelta

from repro.obs.metrics import get_registry

from . import events as ev
from .events import EventBus, JobEvent
from .resources import format_slurm_time

_TERMINAL = ("COMPLETED", "FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL")

# calendar entry kinds — the tuple shape establishes a total heap order at
# equal instants: node failures are processed before completions (matching
# the reference's failures-then-completions pass), completions/begins in
# numeric (base_id, array_task_id) order, wakeups carry no payload
_EV_FAIL = 0  # (at, 0, node_name)
_EV_FINISH = 1  # (at, 1, (base, task), jobid, epoch)
_EV_BEGIN = 2  # (at, 2, (base, task), jobid)
_EV_WAKE = 3  # (at, 3)

_INF = float("inf")


def _jkey(j: "SimJob") -> tuple:
    return (j.base_id, j.array_task_id or 0)


@dataclass
class SimNode:
    name: str
    cpus: int = 64
    memory_mb: int = 262144
    state: str = "UP"  # UP | DOWN
    used_cpus: int = 0
    used_mem: int = 0

    def fits(self, cpus: int, mem: int) -> bool:
        return (
            self.state == "UP"
            and self.cpus - self.used_cpus >= cpus
            and self.memory_mb - self.used_mem >= mem
        )


@dataclass
class SimJob:
    jobid: str
    name: str
    user: str
    partition: str
    cpus: int
    memory_mb: int
    time_limit_s: int
    duration_s: int
    submitted_at: datetime
    begin: datetime | None = None
    dependencies: list = field(default_factory=list)
    dependency_type: str = "afterok"
    requeue: bool = True
    script_path: str | None = None
    state: str = "PENDING"
    reason: str = ""
    node: str | None = None
    started_at: datetime | None = None
    finished_at: datetime | None = None
    array_task_id: int | None = None
    held: bool = False  # submitted --hold; stays PENDING until release()
    restarts: int = 0
    tool: str = ""  # launcher/tool name (predictor key); "" for plain jobs
    eco_deferred: bool = False  # eco mode injected a --begin on this job
    eco_tier: int = 0  # tier of the eco decision (0 = none/not eco)
    energy_j: float = 0.0  # deterministic consumed energy, charged at finish

    @property
    def base_id(self) -> int:
        return int(self.jobid.split("_")[0])


class SimCluster:
    """Event-driven single-partition-per-job SLURM model."""

    def __init__(
        self,
        nodes: "list[SimNode] | None" = None,
        now: datetime | None = None,
        default_user: str = "user",
        default_duration_s: int = 60,
        execute: bool = False,
        watts_per_cpu: float = 12.0,
        bus: EventBus | None = None,
        name: str = "",
    ):
        #: federation member name ("" for a standalone simulator); the
        #: FederatedBackend namespaces ids/events with it at its boundary
        self.name = name
        self.nodes = nodes or [SimNode(f"n{i:03d}") for i in range(4)]
        self.now = now or datetime(2026, 3, 18, 10, 0, 0)
        self.default_user = default_user
        self.default_duration_s = default_duration_s
        self.execute = execute
        self.watts_per_cpu = watts_per_cpu
        self.jobs: dict[str, SimJob] = {}
        #: non-terminal jobs only — insertion order is (base_id, task)
        #: order because ids are handed out monotonically and entries are
        #: only ever appended (never re-inserted), which is what lets
        #: queue()/accounting() skip their per-call sorts
        self._active: dict[str, SimJob] = {}
        #: str(base_id) → tasks in submission order (dependency lookups,
        #: base-id cancel/release/get without a full-table scan)
        self._by_base: dict[str, list[SimJob]] = {}
        #: bumped whenever node capacity may have *increased* mid-pass
        #: (job released, node restored) — invalidates the scheduling
        #: pass's failed-requirement dominance cache
        self._cap_bump = 0
        self._next_id = 1000001
        self._defer_schedule = False
        self.events_log: list[tuple[datetime, str]] = []
        #: typed event stream; one JobEvent per state transition
        self.bus = bus if bus is not None else EventBus()
        #: reactive controllers: fn(sim, now) at every event boundary
        self.tick_hooks: list = []
        # -- event calendar -------------------------------------------------
        #: the unified heap: completions, begin times, node failures and
        #: wake_at deadlines, invalidated lazily on pop
        self._calendar: list[tuple] = []
        #: entries that came due at the *current* instant but must be
        #: processed at the next stop (a 0-duration job started at stop t
        #: finishes at the following stop, exactly like the reference's
        #: strict now < t next-event filter)
        self._due_buffer: list[tuple] = []
        #: jobid → start count; a FINISH entry is only valid if the job is
        #: still RUNNING *and* its epoch matches (requeue+restart safety)
        self._epoch: dict[str, int] = {}
        #: wake_at dedup (the heap itself may not be scanned cheaply)
        self._wake_set: set[datetime] = set()
        # -- eligibility sets ----------------------------------------------
        #: PENDING jobs known runnable but blocked on capacity, in
        #: (base, task) order; every entry already carries reason
        #: "Resources" from the pass that parked it
        self._runnable: deque[SimJob] = deque()
        #: newly eligible jobs awaiting classification (fresh submits,
        #: released holds, fired begins, woken dependency waiters,
        #: requeues), with a set guard against duplicate enqueues
        self._fresh: list[SimJob] = []
        self._fresh_set: set[str] = set()
        #: str(dep base_id) → {jobid: waiter}; woken (popped) whenever any
        #: task of that base reaches a terminal state
        self._dep_waiters: dict[str, dict[str, SimJob]] = {}
        #: active jobs parked forever with DependencyNeverSatisfied —
        #: run_until_idle's idleness test is then two len() calls
        self._never: set[str] = set()
        #: conservative minima over every job in _runnable (plus any
        #: runnable fresh of the current pass): if a failed requirement
        #: (fc, fm) has fc <= min_cpus and fm <= min_mem it dominates the
        #: whole queue and the pass can stop walking (max-free-capacity
        #: early exit); recomputed exactly on every full walk
        self._run_min_cpus: float = _INF
        self._run_min_mem: float = _INF
        self._nodes_by_name: dict[str, SimNode] = {n.name: n for n in self.nodes}
        # -- observability (plain ints on the hot path; flushed to the
        #    metrics registry once per advance() and only when enabled) ----
        self.sched_passes = 0
        self.sched_considered = 0
        self._obs_passes = 0
        self._obs_considered = 0

    # ------------------------------------------------------------------ submit

    def submit(self, job) -> int:
        """Submit a :class:`repro.core.job.Job`; returns the base job id."""
        opts = job.opts
        base = self._next_id
        self._next_id += 1
        begin = None
        if opts.begin:
            begin = datetime.fromisoformat(opts.begin)
        duration = job.sim_duration_s
        if duration is None:
            duration = self.default_duration_s
        # eco metadata stamped by the submission path (engine/launcher/runjob)
        eco_meta = getattr(job, "eco_meta", None) or {}
        held = bool(getattr(opts, "hold", False))
        n_tasks = max(1, opts.array_size)
        for t in range(n_tasks):
            jid = f"{base}_{t}" if opts.array_size > 0 else str(base)
            j = SimJob(
                jobid=jid,
                name=job.name,
                user=self.default_user,
                partition=opts.queue or "main",
                cpus=opts.threads,
                memory_mb=opts.memory_mb,
                time_limit_s=opts.time_s,
                duration_s=int(duration),
                submitted_at=self.now,
                begin=begin,
                dependencies=[str(d) for d in opts.dependencies],
                dependency_type=opts.dependency_type,
                requeue=opts.requeue,
                script_path=job.script_path,
                array_task_id=t if opts.array_size > 0 else None,
                held=held,
                tool=getattr(job, "tool", "") or "",
                eco_deferred=bool(eco_meta.get("deferred", False)),
                eco_tier=int(eco_meta.get("tier", 0) or 0),
            )
            if held:
                j.reason = ev.HELD_REASON
            self.jobs[jid] = j
            self._active[jid] = j
            self._by_base.setdefault(str(base), []).append(j)
            if begin is not None and begin > self.now:
                heapq.heappush(
                    self._calendar, (begin, _EV_BEGIN, (base, t), jid)
                )
            self._enqueue_fresh(j)
            self._emit(ev.SUBMITTED, j)
        self._log(f"submit {base} name={job.name} tasks={n_tasks}")
        self._try_schedule()
        return base

    def submit_many(self, jobs: list) -> list[int]:
        """Batched submit: insert every job, then one scheduling pass.

        The per-submit scheduling sweep is O(pending × nodes); deferring it
        turns an N-job batch from O(N²) into O(N) without changing the
        resulting schedule (FIFO order is preserved).
        """
        ids = []
        self._defer_schedule = True
        try:
            for job in jobs:
                ids.append(self.submit(job))
        finally:
            self._defer_schedule = False
        self._try_schedule()
        return ids

    # ------------------------------------------------------------------ queries

    def queue(self) -> list[dict]:
        rows = []
        for j in self._active.values():  # insertion order == id order
            if j.state in _TERMINAL:
                continue  # defensive: state set directly, not via a transition
            used = int((self.now - j.started_at).total_seconds()) if j.started_at else 0
            left = max(0, j.time_limit_s - used) if j.state == "RUNNING" else 0
            rows.append(
                {
                    "jobid": j.jobid,
                    "user": j.user,
                    "queue": j.partition,
                    "name": j.name,
                    "state": j.state,
                    "time_used": format_slurm_time(used),
                    "time_left": format_slurm_time(left),
                    "time_limit": format_slurm_time(j.time_limit_s),
                    "nodelist": j.node or "",
                    "reason": j.reason,
                    "cpus": str(j.cpus),
                    "memory": str(j.memory_mb),
                }
            )
        return rows

    def accounting(self) -> list[SimJob]:
        """All jobs ever seen (sacct analogue), in id order."""
        return list(self.jobs.values())  # insertion order == id order

    def get(self, jobid) -> SimJob | None:
        jid = str(jobid)
        if jid in self.jobs:
            return self.jobs[jid]
        # base id of an array: return first task
        for j in self._by_base.get(jid, ()):
            return j
        return None

    def states_of(self, base_id: int) -> list[str]:
        return [j.state for j in self._by_base.get(str(int(base_id)), ())]

    def nodes_info(self) -> list[dict]:
        return [
            {"name": n.name, "cpus": n.cpus, "memory_mb": n.memory_mb,
             "state": n.state, "used_cpus": n.used_cpus}
            for n in self.nodes
        ]

    # ------------------------------------------------------------------ control

    def cancel(self, jobids: list) -> None:
        targets = set()
        for jid in jobids:
            jid = str(jid)
            if jid in self.jobs:
                targets.add(jid)
            for j in self._by_base.get(jid, ()):
                targets.add(j.jobid)
        for jid in targets:
            j = self.jobs[jid]
            if j.state in _TERMINAL:
                continue
            if j.state == "RUNNING":
                self._release(j)
                self._charge(j, (self.now - j.started_at).total_seconds())
            j.state = "CANCELLED"
            j.finished_at = self.now
            self._retire(j)
            self._log(f"cancel {jid}")
            self._emit(ev.CANCELLED, j)
            self._wake_dependents(j)
        self._try_schedule()

    def release(self, jobids: list) -> None:
        """Release jobs submitted with ``--hold`` (scontrol-release analogue).

        Accepts task ids or base ids, like :meth:`cancel`. Non-held or
        terminal jobs are left untouched, so releasing is idempotent.
        """
        released = False
        for jid in jobids:
            jid = str(jid)
            exact = self.jobs.get(jid)
            cands = ([exact] if exact is not None else []) + [
                j for j in self._by_base.get(jid, ()) if j is not exact
            ]
            for j in cands:
                if not j.held or j.state in _TERMINAL:
                    continue
                j.held = False
                if j.reason == ev.HELD_REASON:
                    j.reason = ""
                released = True
                self._enqueue_fresh(j)
                self._log(f"release {j.jobid}")
                self._emit(ev.RELEASED, j)
        if released:
            self._try_schedule()

    def fail_node(self, name: str, at: datetime | None = None) -> None:
        """Fail a node now, or schedule a failure at a future (sim) time."""
        if at is not None and at > self.now:
            heapq.heappush(self._calendar, (at, _EV_FAIL, name))
            return
        node = self._node(name)
        node.state = "DOWN"
        self._log(f"node_fail {name}")
        for j in list(self._active.values()):
            if j.state == "RUNNING" and j.node == name:
                self._release(j, node_down=True)
                self._charge(j, (self.now - j.started_at).total_seconds())
                if j.requeue:
                    j.state = "PENDING"
                    j.reason = "BeginTime" if j.begin and j.begin > self.now else "Resources"
                    j.node = None
                    j.started_at = None
                    j.restarts += 1
                    self._enqueue_fresh(j)
                    self._log(f"requeue {j.jobid}")
                    self._emit(ev.REQUEUED, j)
                else:
                    j.state = "NODE_FAIL"
                    j.finished_at = self.now
                    self._retire(j)
                    self._emit(ev.NODE_FAIL, j)
                    self._wake_dependents(j)
        self._try_schedule()

    def restore_node(self, name: str) -> None:
        self._node(name).state = "UP"
        self._cap_bump += 1
        self._log(f"node_up {name}")
        self._try_schedule()

    # ------------------------------------------------------------------ clock

    def advance(self, seconds: float = 0, *, to: datetime | None = None) -> "SimCluster":
        """Advance simulated time, processing every event in order.

        Registered ``tick_hooks`` run at every stop (scheduled event, wakeup,
        final target) — the reactive analogue of a controller daemon's loop.
        """
        target = to if to is not None else self.now + timedelta(seconds=seconds)
        while True:
            t = self._next_event_time(target)
            if t is None:
                break
            self.now = t
            self._process_due_events()
            self._try_schedule()
            self._tick()
        self.now = max(self.now, target)
        self._process_due_events()
        self._try_schedule()
        self._tick()
        self._flush_obs()
        return self

    def wake_at(self, t: datetime) -> None:
        """Ask ``advance()`` to stop (and tick hooks to run) at ``t``.

        Controllers use this for deadlines the job table knows nothing
        about — e.g. an eco hold-and-release deadline on a held job, which
        carries no ``--begin`` of its own. Past times are ignored;
        duplicates are coalesced into a single calendar entry.
        """
        if t > self.now and t not in self._wake_set:
            self._wake_set.add(t)
            heapq.heappush(self._calendar, (t, _EV_WAKE))

    def add_tick_hook(self, fn) -> None:
        """Register ``fn(sim, now)`` to run at every ``advance()`` stop."""
        if fn not in self.tick_hooks:
            self.tick_hooks.append(fn)

    def remove_tick_hook(self, fn) -> None:
        if fn in self.tick_hooks:
            self.tick_hooks.remove(fn)

    def _tick(self) -> None:
        for fn in list(self.tick_hooks):
            fn(self, self.now)

    def run_until_idle(self, max_days: int = 30) -> "SimCluster":
        """Advance until no active jobs remain (bounded)."""
        deadline = self.now + timedelta(days=max_days)
        while self.now < deadline:
            # active jobs that can still make progress: everything live
            # except the permanently dependency-stuck
            if len(self._active) - len(self._never) <= 0:
                break
            t = self._next_event_time(deadline)
            if t is None:
                break
            self.advance(to=t)
        return self

    # ------------------------------------------------------------------ internals

    def _node(self, name: str) -> SimNode:
        n = self._nodes_by_name.get(name)
        if n is None:
            # callers may swap/extend self.nodes directly; rebuild once
            self._nodes_by_name = {n.name: n for n in self.nodes}
            n = self._nodes_by_name.get(name)
            if n is None:
                raise KeyError(name)
        return n

    def _entry_stale(self, entry: tuple) -> bool:
        kind = entry[1]
        if kind == _EV_FINISH:
            j = self._active.get(entry[3])
            return (
                j is None
                or j.state != "RUNNING"
                or self._epoch.get(entry[3], 0) != entry[4]
            )
        if kind == _EV_BEGIN:
            j = self._active.get(entry[3])
            return j is None or j.state != "PENDING"
        return False  # FAIL / WAKE entries never go stale

    def _next_event_time(self, target: datetime) -> datetime | None:
        """Earliest calendar instant in ``(now, target]``, or None.

        Stale entries (cancelled/requeued jobs, fired begins) are discarded
        as they surface; entries already due (``t <= now`` — a 0-duration
        job started at this very stop) are buffered for the *next*
        ``_process_due_events``, preserving the reference's strict
        ``now < t`` stop semantics.
        """
        cal = self._calendar
        while cal:
            entry = cal[0]
            if self._entry_stale(entry):
                heapq.heappop(cal)
                continue
            t = entry[0]
            if t <= self.now:
                heapq.heappop(cal)
                if entry[1] == _EV_WAKE:
                    self._wake_set.discard(t)
                else:
                    self._due_buffer.append(entry)
                continue
            if t <= target:
                return t
            return None
        return None

    def _process_due_events(self) -> None:
        """Apply every calendar entry with ``at <= now``.

        Node failures first (in time order), then completions in numeric
        ``(base_id, array_task_id)`` order regardless of their instants —
        exactly the reference's two-phase pass. A failure's requeue
        side-effects may start 0-duration work that also completes *now*;
        the re-drain loop picks those up in the same call, as the
        reference's post-failure completion sweep does.
        """
        due = self._due_buffer
        self._due_buffer = []
        cal = self._calendar
        finishes: list[tuple] = []
        fails: list[tuple] = []
        while True:
            while cal and cal[0][0] <= self.now:
                due.append(heapq.heappop(cal))
            if not due:
                break
            for entry in due:
                kind = entry[1]
                if kind == _EV_FAIL:
                    fails.append(entry)
                elif kind == _EV_FINISH:
                    finishes.append(entry)
                elif kind == _EV_BEGIN:
                    j = self._active.get(entry[3])
                    if j is not None and j.state == "PENDING":
                        self._enqueue_fresh(j)
                elif kind == _EV_WAKE:
                    self._wake_set.discard(entry[0])
            due = []
            if not fails:
                break
            for entry in sorted(fails):
                self.fail_node(entry[2])
            fails = []
            # fail_node reschedules; newly started 0-duration jobs have
            # completions due at this same instant — drain again
        for entry in sorted(finishes, key=lambda e: e[2]):
            if not self._entry_stale(entry):
                self._finish(self._active[entry[3]])

    def _finish(self, j: SimJob) -> None:
        self._release(j)
        j.finished_at = self.now
        self._charge(j, min(j.duration_s, j.time_limit_s))
        if j.duration_s > j.time_limit_s:
            j.state = "TIMEOUT"
            self._retire(j)
            self._log(f"timeout {j.jobid}")
            self._emit(ev.TIMEOUT, j)
            self._wake_dependents(j)
            return
        if self.execute and j.script_path and os.path.exists(j.script_path):
            env = dict(os.environ)
            env["SLURM_JOB_ID"] = str(j.base_id)
            env["SLURM_CPUS_PER_TASK"] = str(j.cpus)
            if j.array_task_id is not None:
                env["SLURM_ARRAY_TASK_ID"] = str(j.array_task_id)
                env["SLURM_ARRAY_JOB_ID"] = str(j.base_id)
            proc = subprocess.run(
                ["bash", j.script_path],
                env=env,
                capture_output=True,
                text=True,
            )
            j.state = "COMPLETED" if proc.returncode == 0 else "FAILED"
            if proc.returncode != 0:
                j.reason = f"NonZeroExitCode({proc.returncode})"
        else:
            j.state = "COMPLETED"
        self._retire(j)
        self._log(f"finish {j.jobid} state={j.state}")
        self._emit(ev.COMPLETED if j.state == "COMPLETED" else ev.FAILED, j)
        self._wake_dependents(j)

    def _charge(self, j: SimJob, seconds: float) -> None:
        """Accumulate consumed energy for ``seconds`` of occupancy (requeued
        jobs are charged per attempt — the wasted partial run is real)."""
        j.energy_j += self.watts_per_cpu * j.cpus * max(0.0, seconds)

    def _retire(self, j: SimJob) -> None:
        """Drop a job that just went terminal from the active indexes."""
        self._active.pop(j.jobid, None)
        self._epoch.pop(j.jobid, None)
        self._never.discard(j.jobid)
        if j.dependencies:
            self._unregister_waiter(j)

    def _release(self, j: SimJob, node_down: bool = False) -> None:
        self._cap_bump += 1
        if j.node:
            node = self._node(j.node)
            if not node_down or node.state == "UP":
                node.used_cpus -= j.cpus
                node.used_mem -= j.memory_mb
            else:
                node.used_cpus = max(0, node.used_cpus - j.cpus)
                node.used_mem = max(0, node.used_mem - j.memory_mb)

    def _deps_state(self, j: SimJob) -> str:
        """'ok' | 'wait' | 'never' for afterok semantics."""
        for dep in j.dependencies:
            dep_jobs = self._by_base.get(str(dep), [])
            if not dep_jobs:
                return "wait"
            for d in dep_jobs:
                if d.state in ("FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL"):
                    return "never"
                if d.state != "COMPLETED":
                    return "wait"
        return "ok"

    # -- eligibility maintenance -------------------------------------------

    def _enqueue_fresh(self, j: SimJob) -> None:
        """Queue a job for (re)classification at the next scheduling pass."""
        if j.jobid not in self._fresh_set:
            self._fresh_set.add(j.jobid)
            self._fresh.append(j)

    def _wake_dependents(self, j: SimJob) -> None:
        """A task of base ``j`` went terminal: reclassify its waiters."""
        waiters = self._dep_waiters.pop(str(j.base_id), None)
        if waiters:
            for w in waiters.values():
                if w.state == "PENDING" and w.jobid in self._active:
                    self._enqueue_fresh(w)

    def _register_waiter(self, j: SimJob) -> None:
        for dep in j.dependencies:
            self._dep_waiters.setdefault(str(dep), {})[j.jobid] = j

    def _unregister_waiter(self, j: SimJob) -> None:
        for dep in j.dependencies:
            waiters = self._dep_waiters.get(str(dep))
            if waiters is not None:
                waiters.pop(j.jobid, None)
                if not waiters:
                    del self._dep_waiters[str(dep)]

    def _classify_fresh(self) -> list[SimJob]:
        """Sort newly eligible jobs into parked buckets or the runnable set.

        Returns this pass's runnable newcomers in (base, task) order.
        Parked jobs get the same reason strings, at the same observable
        instants, as the reference's full-sweep reclassification: held and
        begin-gated jobs wait for their release/calendar events,
        dependency waiters are indexed under every dependency so the
        dependency's own terminal event re-enqueues them.
        """
        fresh, self._fresh = self._fresh, []
        self._fresh_set.clear()
        runnable: list[SimJob] = []
        for j in fresh:
            if j.state != "PENDING" or j.jobid not in self._active:
                continue  # transitioned (cancelled, placed…) since enqueue
            if j.held:
                j.reason = ev.HELD_REASON
                continue
            if j.begin and self.now < j.begin:
                j.reason = "BeginTime"
                continue
            if j.dependencies:
                deps = self._deps_state(j)
                if deps == "never":
                    j.reason = "DependencyNeverSatisfied"
                    self._never.add(j.jobid)
                    self._unregister_waiter(j)
                    continue
                if deps == "wait":
                    j.reason = "Dependency"
                    self._register_waiter(j)
                    continue
                self._unregister_waiter(j)
            runnable.append(j)
            if j.cpus < self._run_min_cpus:
                self._run_min_cpus = j.cpus
            if j.memory_mb < self._run_min_mem:
                self._run_min_mem = j.memory_mb
        runnable.sort(key=_jkey)
        return runnable

    def _try_schedule(self) -> None:
        if self._defer_schedule:
            return
        self.sched_passes += 1
        fresh_run = self._classify_fresh()
        rq = self._runnable
        if not rq and not fresh_run:
            return
        # requirement sizes that already failed this pass: capacity only
        # shrinks as jobs place, so anything at least as big must fail
        # too — unless capacity came back (release/restore mid-pass via
        # an event subscriber), which _cap_bump detects
        failed: list[tuple[int, int]] = []
        bump0 = self._cap_bump
        survivors: list[SimJob] = []
        fi = 0
        nfresh = len(fresh_run)
        early_exit = False
        # merged walk over the standing runnable deque and this pass's
        # newcomers, in (base, task) order — FIFO priority, exactly the
        # order the reference's sort-everything sweep visits runnable work
        while True:
            if rq and (fi >= nfresh or _jkey(rq[0]) < _jkey(fresh_run[fi])):
                j = rq.popleft()
                if j.state != "PENDING" or j.jobid not in self._active:
                    continue  # tombstone: cancelled while parked
            elif fi < nfresh:
                j = fresh_run[fi]
                fi += 1
                if j.state != "PENDING":
                    continue  # an event subscriber already transitioned it
            else:
                break
            self.sched_considered += 1
            if self._cap_bump != bump0:
                failed.clear()
                bump0 = self._cap_bump
            if any(fc <= j.cpus and fm <= j.memory_mb for fc, fm in failed):
                j.reason = "Resources"
                survivors.append(j)
                continue
            placed = False
            for node in self.nodes:
                if node.fits(j.cpus, j.memory_mb):
                    node.used_cpus += j.cpus
                    node.used_mem += j.memory_mb
                    j.node = node.name
                    j.state = "RUNNING"
                    j.reason = ""
                    j.started_at = self.now
                    placed = True
                    epoch = self._epoch.get(j.jobid, 0) + 1
                    self._epoch[j.jobid] = epoch
                    end = self.now + timedelta(
                        seconds=min(j.duration_s, j.time_limit_s)
                    )
                    heapq.heappush(
                        self._calendar,
                        (end, _EV_FINISH, _jkey(j), j.jobid, epoch),
                    )
                    self._log(f"start {j.jobid} on {node.name}")
                    self._emit(ev.STARTED, j)
                    break
            if not placed:
                j.reason = "Resources"
                survivors.append(j)
                if len(failed) < 32:  # bound the dominance scan itself
                    failed.append((j.cpus, j.memory_mb))
                # max-free-capacity early exit: this requirement dominates
                # every job still queued (it is no larger than the
                # conservative minima), so each of them would take the
                # dominance branch above — and between here and the end of
                # the reference's sweep nothing emits, so capacity cannot
                # come back mid-tail. Deque entries already carry reason
                # "Resources"; unprocessed newcomers get it below.
                if (
                    j.cpus <= self._run_min_cpus
                    and j.memory_mb <= self._run_min_mem
                ):
                    early_exit = True
                    break
        if early_exit:
            # unwalked newcomers: stamp the reason the reference would and
            # file them straight into the deque — they never need
            # reclassifying (their hold/begin/dependency gates are all
            # permanently open), only capacity. Monotonic ids make plain
            # append the common case; a woken dependency waiter with an
            # old id falls back to a two-pointer merge.
            leftovers = []
            while fi < nfresh:
                j = fresh_run[fi]
                fi += 1
                if j.state == "PENDING":
                    j.reason = "Resources"
                    leftovers.append(j)
            if leftovers:
                if not rq or _jkey(rq[-1]) < _jkey(leftovers[0]):
                    rq.extend(leftovers)
                else:
                    old = list(rq)
                    rq.clear()
                    oi, li = 0, 0
                    while oi < len(old) and li < len(leftovers):
                        if _jkey(old[oi]) < _jkey(leftovers[li]):
                            rq.append(old[oi])
                            oi += 1
                        else:
                            rq.append(leftovers[li])
                            li += 1
                    rq.extend(old[oi:])
                    rq.extend(leftovers[li:])
            rq.extendleft(reversed(survivors))
        else:
            # full walk: the survivors ARE the runnable set; recompute the
            # minima exactly so the early exit stays as sharp as possible
            rq.extend(survivors)
            mc, mm = _INF, _INF
            for j in survivors:
                if j.cpus < mc:
                    mc = j.cpus
                if j.memory_mb < mm:
                    mm = j.memory_mb
            self._run_min_cpus = mc
            self._run_min_mem = mm

    def _flush_obs(self) -> None:
        reg = get_registry()
        if not reg.enabled:
            return
        dp = self.sched_passes - self._obs_passes
        dc = self.sched_considered - self._obs_considered
        self._obs_passes = self.sched_passes
        self._obs_considered = self.sched_considered
        if dp:
            reg.counter(
                "nbi_sim_schedule_passes_total",
                "SimCluster scheduling passes",
            ).inc(dp)
        if dc:
            reg.counter(
                "nbi_sim_schedule_considered_total",
                "Jobs examined by SimCluster scheduling passes",
            ).inc(dc)

    def _log(self, msg: str) -> None:
        self.events_log.append((self.now, msg))

    def _emit(self, type_: str, j: SimJob) -> None:
        self.bus.emit(JobEvent(
            type=type_, jobid=j.jobid, at=self.now, name=j.name,
            user=j.user, state=j.state, node=j.node or "", reason=j.reason,
        ))
