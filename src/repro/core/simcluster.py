"""``SimCluster`` — a deterministic, in-process SLURM simulator.

The paper requires that "all tests will be able to check functions even
without Slurm". The simulator goes further: a discrete-event model of a
cluster (nodes, partitions, FIFO scheduling, ``--begin`` eligibility,
``afterok`` dependencies, time limits, requeue-on-node-failure and job
arrays) so that queue tools, eco deferral, pipelines and fault-tolerance
drills are all *integration-tested* — deterministically, on any machine.

With ``execute=True`` the simulator actually runs each job's script through
``bash`` at (simulated) completion time, which lets tests verify end-to-end
behaviour such as the manifest being patched in place by the job itself.

Energy telemetry: every job that consumed CPU time is charged a
deterministic ``energy_j = watts_per_cpu × cpus × elapsed_seconds`` when it
reaches a terminal state — the simulator's analogue of sacct's
``ConsumedEnergy``, which :func:`repro.accounting.collect` harvests into
the job archive.

Events: every state transition is announced on :attr:`SimCluster.bus` as a
typed :class:`~repro.core.events.JobEvent` at the exact simulated instant
it happens — callers subscribe instead of diffing ``queue()`` snapshots.
``tick_hooks`` and ``wake_at()`` let reactive controllers (the eco
hold-and-release daemon) run at every event boundary and at their own
deadlines inside ``advance()``.
"""

from __future__ import annotations

import os
import subprocess
from dataclasses import dataclass, field
from datetime import datetime, timedelta

from . import events as ev
from .events import EventBus, JobEvent
from .resources import format_slurm_time

_TERMINAL = ("COMPLETED", "FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL")


@dataclass
class SimNode:
    name: str
    cpus: int = 64
    memory_mb: int = 262144
    state: str = "UP"  # UP | DOWN
    used_cpus: int = 0
    used_mem: int = 0

    def fits(self, cpus: int, mem: int) -> bool:
        return (
            self.state == "UP"
            and self.cpus - self.used_cpus >= cpus
            and self.memory_mb - self.used_mem >= mem
        )


@dataclass
class SimJob:
    jobid: str
    name: str
    user: str
    partition: str
    cpus: int
    memory_mb: int
    time_limit_s: int
    duration_s: int
    submitted_at: datetime
    begin: datetime | None = None
    dependencies: list = field(default_factory=list)
    dependency_type: str = "afterok"
    requeue: bool = True
    script_path: str | None = None
    state: str = "PENDING"
    reason: str = ""
    node: str | None = None
    started_at: datetime | None = None
    finished_at: datetime | None = None
    array_task_id: int | None = None
    held: bool = False  # submitted --hold; stays PENDING until release()
    restarts: int = 0
    tool: str = ""  # launcher/tool name (predictor key); "" for plain jobs
    eco_deferred: bool = False  # eco mode injected a --begin on this job
    eco_tier: int = 0  # tier of the eco decision (0 = none/not eco)
    energy_j: float = 0.0  # deterministic consumed energy, charged at finish

    @property
    def base_id(self) -> int:
        return int(self.jobid.split("_")[0])


class SimCluster:
    """Event-driven single-partition-per-job SLURM model."""

    def __init__(
        self,
        nodes: "list[SimNode] | None" = None,
        now: datetime | None = None,
        default_user: str = "user",
        default_duration_s: int = 60,
        execute: bool = False,
        watts_per_cpu: float = 12.0,
        bus: EventBus | None = None,
        name: str = "",
    ):
        #: federation member name ("" for a standalone simulator); the
        #: FederatedBackend namespaces ids/events with it at its boundary
        self.name = name
        self.nodes = nodes or [SimNode(f"n{i:03d}") for i in range(4)]
        self.now = now or datetime(2026, 3, 18, 10, 0, 0)
        self.default_user = default_user
        self.default_duration_s = default_duration_s
        self.execute = execute
        self.watts_per_cpu = watts_per_cpu
        self.jobs: dict[str, SimJob] = {}
        #: non-terminal jobs only — the hot-path iterations (queue(),
        #: scheduling passes, next-event scans) walk this instead of the
        #: ever-growing full job table; entries are retired at the same
        #: three sites that set a terminal state
        self._active: dict[str, SimJob] = {}
        #: str(base_id) → tasks in submission order (dependency lookups,
        #: base-id cancel/release/get without a full-table scan)
        self._by_base: dict[str, list[SimJob]] = {}
        #: bumped whenever node capacity may have *increased* mid-pass
        #: (job released, node restored) — invalidates the scheduling
        #: pass's failed-requirement dominance cache
        self._cap_bump = 0
        self._next_id = 1000001
        self._defer_schedule = False
        self._failures: list[tuple[datetime, str]] = []  # scheduled node failures
        self.events_log: list[tuple[datetime, str]] = []
        #: typed event stream; one JobEvent per state transition
        self.bus = bus if bus is not None else EventBus()
        #: reactive controllers: fn(sim, now) at every event boundary
        self.tick_hooks: list = []
        self._wakeups: list[datetime] = []  # extra advance() stops (sorted)

    # ------------------------------------------------------------------ submit

    def submit(self, job) -> int:
        """Submit a :class:`repro.core.job.Job`; returns the base job id."""
        opts = job.opts
        base = self._next_id
        self._next_id += 1
        begin = None
        if opts.begin:
            begin = datetime.fromisoformat(opts.begin)
        duration = job.sim_duration_s
        if duration is None:
            duration = self.default_duration_s
        # eco metadata stamped by the submission path (engine/launcher/runjob)
        eco_meta = getattr(job, "eco_meta", None) or {}
        held = bool(getattr(opts, "hold", False))
        n_tasks = max(1, opts.array_size)
        for t in range(n_tasks):
            jid = f"{base}_{t}" if opts.array_size > 0 else str(base)
            j = SimJob(
                jobid=jid,
                name=job.name,
                user=self.default_user,
                partition=opts.queue or "main",
                cpus=opts.threads,
                memory_mb=opts.memory_mb,
                time_limit_s=opts.time_s,
                duration_s=int(duration),
                submitted_at=self.now,
                begin=begin,
                dependencies=[str(d) for d in opts.dependencies],
                dependency_type=opts.dependency_type,
                requeue=opts.requeue,
                script_path=job.script_path,
                array_task_id=t if opts.array_size > 0 else None,
                held=held,
                tool=getattr(job, "tool", "") or "",
                eco_deferred=bool(eco_meta.get("deferred", False)),
                eco_tier=int(eco_meta.get("tier", 0) or 0),
            )
            if held:
                j.reason = ev.HELD_REASON
            self.jobs[jid] = j
            self._active[jid] = j
            self._by_base.setdefault(str(base), []).append(j)
            self._emit(ev.SUBMITTED, j)
        self._log(f"submit {base} name={job.name} tasks={n_tasks}")
        self._try_schedule()
        return base

    def submit_many(self, jobs: list) -> list[int]:
        """Batched submit: insert every job, then one scheduling pass.

        The per-submit scheduling sweep is O(pending × nodes); deferring it
        turns an N-job batch from O(N²) into O(N) without changing the
        resulting schedule (FIFO order is preserved).
        """
        ids = []
        self._defer_schedule = True
        try:
            for job in jobs:
                ids.append(self.submit(job))
        finally:
            self._defer_schedule = False
        self._try_schedule()
        return ids

    # ------------------------------------------------------------------ queries

    def queue(self) -> list[dict]:
        rows = []
        for j in sorted(self._active.values(), key=lambda j: (j.base_id, j.array_task_id or 0)):
            if j.state in _TERMINAL:
                continue  # defensive: state set directly, not via a transition
            used = int((self.now - j.started_at).total_seconds()) if j.started_at else 0
            left = max(0, j.time_limit_s - used) if j.state == "RUNNING" else 0
            rows.append(
                {
                    "jobid": j.jobid,
                    "user": j.user,
                    "queue": j.partition,
                    "name": j.name,
                    "state": j.state,
                    "time_used": format_slurm_time(used),
                    "time_left": format_slurm_time(left),
                    "time_limit": format_slurm_time(j.time_limit_s),
                    "nodelist": j.node or "",
                    "reason": j.reason,
                    "cpus": str(j.cpus),
                    "memory": str(j.memory_mb),
                }
            )
        return rows

    def accounting(self) -> list[SimJob]:
        """All jobs ever seen (sacct analogue)."""
        return sorted(self.jobs.values(), key=lambda j: (j.base_id, j.array_task_id or 0))

    def get(self, jobid) -> SimJob | None:
        jid = str(jobid)
        if jid in self.jobs:
            return self.jobs[jid]
        # base id of an array: return first task
        for j in self._by_base.get(jid, ()):
            return j
        return None

    def states_of(self, base_id: int) -> list[str]:
        return [j.state for j in self._by_base.get(str(int(base_id)), ())]

    def nodes_info(self) -> list[dict]:
        return [
            {"name": n.name, "cpus": n.cpus, "memory_mb": n.memory_mb,
             "state": n.state, "used_cpus": n.used_cpus}
            for n in self.nodes
        ]

    # ------------------------------------------------------------------ control

    def cancel(self, jobids: list) -> None:
        targets = set()
        for jid in jobids:
            jid = str(jid)
            if jid in self.jobs:
                targets.add(jid)
            for j in self._by_base.get(jid, ()):
                targets.add(j.jobid)
        for jid in targets:
            j = self.jobs[jid]
            if j.state in _TERMINAL:
                continue
            if j.state == "RUNNING":
                self._release(j)
                self._charge(j, (self.now - j.started_at).total_seconds())
            j.state = "CANCELLED"
            j.finished_at = self.now
            self._retire(j)
            self._log(f"cancel {jid}")
            self._emit(ev.CANCELLED, j)
        self._try_schedule()

    def release(self, jobids: list) -> None:
        """Release jobs submitted with ``--hold`` (scontrol-release analogue).

        Accepts task ids or base ids, like :meth:`cancel`. Non-held or
        terminal jobs are left untouched, so releasing is idempotent.
        """
        released = False
        for jid in jobids:
            jid = str(jid)
            exact = self.jobs.get(jid)
            cands = ([exact] if exact is not None else []) + [
                j for j in self._by_base.get(jid, ()) if j is not exact
            ]
            for j in cands:
                if not j.held or j.state in _TERMINAL:
                    continue
                j.held = False
                if j.reason == ev.HELD_REASON:
                    j.reason = ""
                released = True
                self._log(f"release {j.jobid}")
                self._emit(ev.RELEASED, j)
        if released:
            self._try_schedule()

    def fail_node(self, name: str, at: datetime | None = None) -> None:
        """Fail a node now, or schedule a failure at a future (sim) time."""
        if at is not None and at > self.now:
            self._failures.append((at, name))
            self._failures.sort()
            return
        node = self._node(name)
        node.state = "DOWN"
        self._log(f"node_fail {name}")
        for j in list(self._active.values()):
            if j.state == "RUNNING" and j.node == name:
                self._release(j, node_down=True)
                self._charge(j, (self.now - j.started_at).total_seconds())
                if j.requeue:
                    j.state = "PENDING"
                    j.reason = "BeginTime" if j.begin and j.begin > self.now else "Resources"
                    j.node = None
                    j.started_at = None
                    j.restarts += 1
                    self._log(f"requeue {j.jobid}")
                    self._emit(ev.REQUEUED, j)
                else:
                    j.state = "NODE_FAIL"
                    j.finished_at = self.now
                    self._retire(j)
                    self._emit(ev.NODE_FAIL, j)
        self._try_schedule()

    def restore_node(self, name: str) -> None:
        self._node(name).state = "UP"
        self._cap_bump += 1
        self._log(f"node_up {name}")
        self._try_schedule()

    # ------------------------------------------------------------------ clock

    def advance(self, seconds: float = 0, *, to: datetime | None = None) -> "SimCluster":
        """Advance simulated time, processing every event in order.

        Registered ``tick_hooks`` run at every stop (scheduled event, wakeup,
        final target) — the reactive analogue of a controller daemon's loop.
        """
        target = to if to is not None else self.now + timedelta(seconds=seconds)
        while True:
            t = self._next_event_time(target)
            if t is None:
                break
            self.now = t
            self._process_due_events()
            self._try_schedule()
            self._tick()
        self.now = max(self.now, target)
        self._process_due_events()
        self._try_schedule()
        self._tick()
        return self

    def wake_at(self, t: datetime) -> None:
        """Ask ``advance()`` to stop (and tick hooks to run) at ``t``.

        Controllers use this for deadlines the job table knows nothing
        about — e.g. an eco hold-and-release deadline on a held job, which
        carries no ``--begin`` of its own. Past times are ignored.
        """
        if t > self.now and t not in self._wakeups:
            self._wakeups.append(t)
            self._wakeups.sort()

    def add_tick_hook(self, fn) -> None:
        """Register ``fn(sim, now)`` to run at every ``advance()`` stop."""
        if fn not in self.tick_hooks:
            self.tick_hooks.append(fn)

    def remove_tick_hook(self, fn) -> None:
        if fn in self.tick_hooks:
            self.tick_hooks.remove(fn)

    def _tick(self) -> None:
        self._wakeups = [t for t in self._wakeups if t > self.now]
        for fn in list(self.tick_hooks):
            fn(self, self.now)

    def run_until_idle(self, max_days: int = 30) -> "SimCluster":
        """Advance until no active jobs remain (bounded)."""
        deadline = self.now + timedelta(days=max_days)
        while self.now < deadline:
            active = [j for j in self._active.values() if j.state not in _TERMINAL
                      and j.reason != "DependencyNeverSatisfied"]
            if not active:
                break
            t = self._next_event_time(deadline)
            if t is None:
                break
            self.advance(to=t)
        return self

    # ------------------------------------------------------------------ internals

    def _node(self, name: str) -> SimNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def _next_event_time(self, target: datetime) -> datetime | None:
        times = []
        for j in self._active.values():
            if j.state == "RUNNING":
                end = j.started_at + timedelta(
                    seconds=min(j.duration_s, j.time_limit_s)
                )
                times.append(end)
            elif j.state == "PENDING" and j.begin and j.begin > self.now:
                times.append(j.begin)
        times += [t for t, _ in self._failures]
        times += self._wakeups  # controller deadlines (wake_at)
        future = [t for t in times if self.now < t <= target]
        return min(future) if future else None

    def _process_due_events(self) -> None:
        # node failures scheduled for <= now
        due = [(t, n) for t, n in self._failures if t <= self.now]
        self._failures = [(t, n) for t, n in self._failures if t > self.now]
        for _, name in due:
            self.fail_node(name)
        # completions
        for j in sorted(self._active.values(), key=lambda j: j.jobid):
            if j.state != "RUNNING":
                continue
            runtime = min(j.duration_s, j.time_limit_s)
            end = j.started_at + timedelta(seconds=runtime)
            if end <= self.now:
                self._finish(j)

    def _finish(self, j: SimJob) -> None:
        self._release(j)
        j.finished_at = self.now
        self._charge(j, min(j.duration_s, j.time_limit_s))
        if j.duration_s > j.time_limit_s:
            j.state = "TIMEOUT"
            self._retire(j)
            self._log(f"timeout {j.jobid}")
            self._emit(ev.TIMEOUT, j)
            return
        if self.execute and j.script_path and os.path.exists(j.script_path):
            env = dict(os.environ)
            env["SLURM_JOB_ID"] = str(j.base_id)
            env["SLURM_CPUS_PER_TASK"] = str(j.cpus)
            if j.array_task_id is not None:
                env["SLURM_ARRAY_TASK_ID"] = str(j.array_task_id)
                env["SLURM_ARRAY_JOB_ID"] = str(j.base_id)
            proc = subprocess.run(
                ["bash", j.script_path],
                env=env,
                capture_output=True,
                text=True,
            )
            j.state = "COMPLETED" if proc.returncode == 0 else "FAILED"
            if proc.returncode != 0:
                j.reason = f"NonZeroExitCode({proc.returncode})"
        else:
            j.state = "COMPLETED"
        self._retire(j)
        self._log(f"finish {j.jobid} state={j.state}")
        self._emit(ev.COMPLETED if j.state == "COMPLETED" else ev.FAILED, j)

    def _charge(self, j: SimJob, seconds: float) -> None:
        """Accumulate consumed energy for ``seconds`` of occupancy (requeued
        jobs are charged per attempt — the wasted partial run is real)."""
        j.energy_j += self.watts_per_cpu * j.cpus * max(0.0, seconds)

    def _retire(self, j: SimJob) -> None:
        """Drop a job that just went terminal from the active index."""
        self._active.pop(j.jobid, None)

    def _release(self, j: SimJob, node_down: bool = False) -> None:
        self._cap_bump += 1
        if j.node:
            node = self._node(j.node)
            if not node_down or node.state == "UP":
                node.used_cpus -= j.cpus
                node.used_mem -= j.memory_mb
            else:
                node.used_cpus = max(0, node.used_cpus - j.cpus)
                node.used_mem = max(0, node.used_mem - j.memory_mb)

    def _deps_state(self, j: SimJob) -> str:
        """'ok' | 'wait' | 'never' for afterok semantics."""
        for dep in j.dependencies:
            dep_jobs = self._by_base.get(str(dep), [])
            if not dep_jobs:
                return "wait"
            for d in dep_jobs:
                if d.state in ("FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL"):
                    return "never"
                if d.state != "COMPLETED":
                    return "wait"
        return "ok"

    def _try_schedule(self) -> None:
        if self._defer_schedule:
            return
        pending = sorted(
            (j for j in self._active.values() if j.state == "PENDING"),
            key=lambda j: (j.base_id, j.array_task_id or 0),
        )
        # requirement sizes that already failed this pass: capacity only
        # shrinks as jobs place, so anything at least as big must fail
        # too — unless capacity came back (release/restore mid-pass via
        # an event subscriber), which _cap_bump detects
        failed: list[tuple[int, int]] = []
        bump0 = self._cap_bump
        for j in pending:
            if j.state != "PENDING":
                continue  # an event subscriber already transitioned it
            if j.held:
                j.reason = ev.HELD_REASON
                continue
            if j.begin and self.now < j.begin:
                j.reason = "BeginTime"
                continue
            deps = self._deps_state(j)
            if deps == "never":
                j.reason = "DependencyNeverSatisfied"
                continue
            if deps == "wait":
                j.reason = "Dependency"
                continue
            if self._cap_bump != bump0:
                failed.clear()
                bump0 = self._cap_bump
            if any(fc <= j.cpus and fm <= j.memory_mb for fc, fm in failed):
                j.reason = "Resources"
                continue
            placed = False
            for node in self.nodes:
                if node.fits(j.cpus, j.memory_mb):
                    node.used_cpus += j.cpus
                    node.used_mem += j.memory_mb
                    j.node = node.name
                    j.state = "RUNNING"
                    j.reason = ""
                    j.started_at = self.now
                    placed = True
                    self._log(f"start {j.jobid} on {node.name}")
                    self._emit(ev.STARTED, j)
                    break
            if not placed:
                j.reason = "Resources"
                if len(failed) < 32:  # bound the dominance scan itself
                    failed.append((j.cpus, j.memory_mb))

    def _log(self, msg: str) -> None:
        self.events_log.append((self.now, msg))

    def _emit(self, type_: str, j: SimJob) -> None:
        self.bus.emit(JobEvent(
            type=type_, jobid=j.jobid, at=self.now, name=j.name,
            user=j.user, state=j.state, node=j.node or "", reason=j.reason,
        ))
