"""``Launcher`` — declarative tool wrappers (port of ``NBI::Launcher``).

A wrapper is a small class that subclasses :class:`Launcher` and describes a
tool — its inputs, parameters, outputs, activation method (HPC module, conda
environment, or Singularity image) and SLURM resource defaults — in a single
constructor call. The only method subclasses typically override is
``make_command()``; the base class handles input validation, scratch-directory
setup, shell-script generation, manifest writing and job submission.

Two bundled wrappers illustrate the pattern:

* :class:`Kraken2` — the paper's own example: measures the database folder
  size at submission time and inflates the memory request accordingly
  (40% headroom plus a 100 GB fixed overhead).
* :class:`TrainLauncher` (in :mod:`repro.launch.submit`) — the TPU-era
  analogue: wraps ``python -m repro.launch.train`` and inflates host memory /
  chip count from the model configuration.

Third-party wrappers dropped into ``~/.nbi/launchers/`` are discovered
automatically by the ``nbilaunch`` command-line tool.
"""

from __future__ import annotations

import importlib.util
import os
import sys
from dataclasses import dataclass, field
from pathlib import Path

from .eco import EcoScheduler
from .job import Job
from .manifest import Manifest
from .resources import Opts

GB = 1024  # MB per GB


@dataclass
class InputSpec:
    """One declared input of a wrapped tool."""

    name: str
    required: bool = True
    kind: str = "file"  # file | dir | str | int | float | flag
    default: object = None
    default_env: str = ""  # environment variable supplying the default
    help: str = ""


class LauncherError(ValueError):
    pass


class Launcher:
    """Base class for declarative tool wrappers."""

    #: subclasses override these class attributes (or pass to __init__)
    tool_name: str = "tool"
    tool_version: str = ""
    inputs_spec: list[InputSpec] = []
    params_spec: list[InputSpec] = []
    #: activation: ("module", "kraken2/2.1.2") | ("conda", "env") |
    #: ("singularity", "img.sif") | ("none", "")
    activation: tuple = ("none", "")

    def __init__(self, *, outdir: str = ".", opts: Opts | None = None,
                 eco: bool | None = None, now=None, backend=None, **kwargs):
        self.outdir = outdir
        self.opts = opts if opts is not None else self.default_opts()
        self.backend = backend
        self._now = now  # injectable clock for deterministic tests
        self.eco = eco
        self.inputs: dict = {}
        self.params: dict = {}
        self._resolve(self.inputs_spec, self.inputs, kwargs)
        self._resolve(self.params_spec, self.params, kwargs)
        unknown = set(kwargs) - {s.name for s in self.inputs_spec + self.params_spec}
        if unknown:
            raise LauncherError(f"{self.tool_name}: unknown arguments {sorted(unknown)}")
        self.build()

    # -- override points --------------------------------------------------------

    def default_opts(self) -> Opts:
        return Opts.new(threads=4, memory="8GB", time="8h")

    def make_command(self) -> str:
        """Return the tool invocation string. Subclasses must override."""
        raise NotImplementedError

    def build(self) -> None:
        """Hook for resource inflation / derived parameters. Optional."""

    def outputs(self) -> dict:
        """Declared output artefacts (paths relative to outdir)."""
        return {}

    # -- machinery ---------------------------------------------------------------

    def _resolve(self, spec: list[InputSpec], into: dict, kwargs: dict) -> None:
        for s in spec:
            if s.name in kwargs:
                into[s.name] = kwargs.pop(s.name)
            elif s.default_env and os.environ.get(s.default_env):
                into[s.name] = os.environ[s.default_env]
            elif s.default is not None:
                into[s.name] = s.default
            elif s.required:
                raise LauncherError(
                    f"{self.tool_name}: missing required input {s.name!r}"
                    + (f" (or set ${s.default_env})" if s.default_env else "")
                )

    def activation_lines(self) -> list[str]:
        kind, what = self.activation
        if kind == "module":
            return [f"module load {what}"]
        if kind == "conda":
            return [f"conda activate {what}"]
        if kind == "singularity":
            return [f"# tool runs inside {what}"]
        return []

    def scratch_lines(self) -> list[str]:
        return [
            'NBI_SCRATCH="${TMPDIR:-/tmp}/nbi-$SLURM_JOB_ID"',
            'mkdir -p "$NBI_SCRATCH"',
            f"mkdir -p {self.outdir}",
        ]

    def manifest_path(self) -> str:
        return str(Path(self.outdir) / f"{self.tool_name}.manifest.json")

    def command_with_activation(self) -> str:
        kind, what = self.activation
        cmd = self.make_command()
        if kind == "singularity":
            return f"singularity exec {what} {cmd}"
        return cmd

    def to_job(self) -> Job:
        """Materialise the wrapper as a submittable Job (script incl. manifest
        patch trailer and scratch setup)."""
        job = Job(
            name=self.tool_name,
            command=self.command_with_activation(),
            opts=self.opts,
            backend=self.backend,
        )
        job.tool = self.tool_name  # accounting/predictor key
        manifest = Manifest(
            self.manifest_path(),
            tool=self.tool_name,
            version=self.tool_version,
            inputs=self.inputs,
            params=self.params,
            outputs=self.outputs(),
            resources={
                "queue": self.opts.queue,
                "threads": self.opts.threads,
                "memory_mb": self.opts.memory_mb,
                "time": self.opts.slurm_time,
                "begin": self.opts.begin,
            },
        )
        job._manifest = manifest  # kept for submit()
        # the patch-on-exit trap must be installed BEFORE any command can
        # fail (the script runs `set -e`), so it leads the prelude
        job.prelude = (
            manifest.trailer_lines()
            + self.scratch_lines()
            + self.activation_lines()
        )
        return job

    def submit(self, *, now=None, eco: bool | None = None) -> int:
        """Validate, apply eco deferral, write the manifest, submit.

        Eco mode is ON by default (paper: enabled unless ``--no-eco`` or
        ``economy_mode=0``); launchers may override per instance.
        """
        from .config import load_config

        cfg = load_config()
        use_eco = self.eco if self.eco is not None else cfg.get_bool("economy_mode")
        if eco is not None:
            use_eco = eco
        eco_meta = None
        if use_eco and not self.opts.begin:
            from datetime import datetime

            from repro.accounting import predictor_from_config

            clock = now or self._now or datetime.now()
            # history-driven duration: a wrapper whose runs habitually finish
            # early is priced at its observed runtime, not the padded limit
            sched = EcoScheduler(cfg, predictor=predictor_from_config(cfg))
            # tool= matches the archive's tool column verbatim
            decision = sched.decide(self.opts.time_s, clock, tool=self.tool_name)
            eco_meta = {"tier": decision.tier, "deferred": decision.deferred}
            if decision.deferred:
                self.opts.set_begin(decision.begin_directive)
        job = self.to_job()
        job.eco_meta = eco_meta
        jobid = job.run(self.backend)
        from repro.accounting import log_submission

        log_submission(jobid, tool=self.tool_name, eco_meta=eco_meta)
        job._manifest.record["resources"]["begin"] = self.opts.begin
        job._manifest.write_submitted(jobid)
        self.last_job = job
        return jobid


# -----------------------------------------------------------------------------
# The paper's bundled example wrapper
# -----------------------------------------------------------------------------


class Kraken2(Launcher):
    """Taxonomic classification — the paper's reference wrapper.

    Declares paired- or single-end FASTQ inputs, a database directory that
    defaults to ``$KRAKEN2_DB``, and a ``threads`` parameter automatically
    synchronised from the ``--cpus`` SLURM flag. ``build()`` measures the
    database folder size at submission time and inflates the memory request:
    40% headroom plus a 100 GB fixed overhead.
    """

    tool_name = "kraken2"
    tool_version = "2.1.3"
    activation = ("module", "kraken2")
    inputs_spec = [
        InputSpec("reads1", required=True, kind="file", help="FASTQ R1 / single-end"),
        InputSpec("reads2", required=False, kind="file", help="FASTQ R2 (paired)"),
        InputSpec("db", required=True, kind="dir", default_env="KRAKEN2_DB"),
    ]
    params_spec = [
        InputSpec("threads", required=False, kind="int", default=0),
        InputSpec("confidence", required=False, kind="float", default=0.0),
    ]

    MEM_HEADROOM = 1.4
    MEM_OVERHEAD_GB = 100

    def default_opts(self) -> Opts:
        return Opts.new(threads=8, memory="16GB", time="6h")

    def build(self) -> None:
        # threads synchronised from the SLURM --cpus flag unless given
        if not self.params.get("threads"):
            self.params["threads"] = self.opts.threads
        db = self.inputs.get("db", "")
        size_gb = dir_size_bytes(db) / 1e9 if db and os.path.isdir(db) else 0.0
        mem_gb = size_gb * self.MEM_HEADROOM + self.MEM_OVERHEAD_GB
        self.opts.memory_mb = max(self.opts.memory_mb, int(mem_gb * GB))

    def outputs(self) -> dict:
        return {
            "report": f"{self.outdir}/kraken2.report.txt",
            "assignments": f"{self.outdir}/kraken2.out",
        }

    def make_command(self) -> str:
        r1 = self.inputs["reads1"]
        r2 = self.inputs.get("reads2")
        reads = f"--paired {r1} {r2}" if r2 else str(r1)
        return (
            f"kraken2 --db {self.inputs['db']} --threads {self.params['threads']} "
            f"--confidence {self.params['confidence']} "
            f"--report {self.outputs()['report']} "
            f"--output {self.outputs()['assignments']} {reads}"
        )


def dir_size_bytes(path: str) -> int:
    total = 0
    for root, _dirs, files in os.walk(path):
        for f in files:
            try:
                total += os.path.getsize(os.path.join(root, f))
            except OSError:
                pass
    return total


# -----------------------------------------------------------------------------
# Third-party wrapper discovery (~/.nbi/launchers/)
# -----------------------------------------------------------------------------

LAUNCHER_DIR = "~/.nbi/launchers"


def discover_launchers(extra_dir: str | None = None) -> dict[str, type]:
    """Find Launcher subclasses: built-ins + ``~/.nbi/launchers/*.py``."""
    found: dict[str, type] = {"kraken2": Kraken2}
    try:
        from repro.launch.submit import TrainLauncher, ServeLauncher

        found["train"] = TrainLauncher
        found["serve"] = ServeLauncher
    except Exception:
        pass
    search = Path(extra_dir or LAUNCHER_DIR).expanduser()
    if search.is_dir():
        for py in sorted(search.glob("*.py")):
            spec = importlib.util.spec_from_file_location(f"nbi_launchers.{py.stem}", py)
            mod = importlib.util.module_from_spec(spec)
            sys.modules[spec.name] = mod
            try:
                spec.loader.exec_module(mod)
            except Exception:
                continue
            for obj in vars(mod).values():
                if (
                    isinstance(obj, type)
                    and issubclass(obj, Launcher)
                    and obj is not Launcher
                ):
                    found[obj.tool_name] = obj
    return found
