"""``ReferenceSimCluster`` — the pre-event-heap scheduler, kept as the spec.

:class:`~repro.core.simcluster.SimCluster` rebuilt its three hot paths
around a single ``heapq`` event calendar and incrementally maintained
eligibility sets (see ``docs/architecture.md`` → *The event calendar*).
This module preserves the simple implementation it replaced — full
active-table scans in ``_next_event_time``, a sort-everything sweep in
``_try_schedule`` — as the executable specification, exactly like the
scalar ``Placer.place_spec`` loop remains the spec for the vectorized
``place_many``.

``tests/test_sim_equivalence.py`` drives randomized workloads (arrays,
dependencies, holds/releases, node churn, timeouts, requeues, cancels,
controller wakeups) through both implementations and asserts identical
``(at, type, jobid)`` event streams, ``events_log`` lines, energy charges
and final job states. ``benchmarks/bench_sim.py`` runs the same day
head-to-head to publish the speedup the calendar buys.

One deliberate difference from the historical code: completions due at the
same instant are ordered by ``(base_id, array_task_id)`` — numeric — not
by the jobid *string*. The string sort diverges from submission order once
ids pass 9,999,999 (``"10000000" < "9999999"``), which at 1M-job scale is
a real workload; both implementations carry the fix, and
``tests/test_simcluster.py`` pins the boundary.

Nothing imports this module at runtime; it exists for the equivalence
suite and the benchmark. Do not grow features here — change the
production class and extend the equivalence suite instead.
"""

from __future__ import annotations

import os
import subprocess
from datetime import datetime, timedelta

from . import events as ev
from .events import EventBus, JobEvent
from .resources import format_slurm_time
from .simcluster import SimJob, SimNode, _TERMINAL


class ReferenceSimCluster:
    """O(active)-per-event SLURM model: the equivalence suite's oracle."""

    def __init__(
        self,
        nodes: "list[SimNode] | None" = None,
        now: datetime | None = None,
        default_user: str = "user",
        default_duration_s: int = 60,
        execute: bool = False,
        watts_per_cpu: float = 12.0,
        bus: EventBus | None = None,
        name: str = "",
    ):
        self.name = name
        self.nodes = nodes or [SimNode(f"n{i:03d}") for i in range(4)]
        self.now = now or datetime(2026, 3, 18, 10, 0, 0)
        self.default_user = default_user
        self.default_duration_s = default_duration_s
        self.execute = execute
        self.watts_per_cpu = watts_per_cpu
        self.jobs: dict[str, SimJob] = {}
        self._active: dict[str, SimJob] = {}
        self._by_base: dict[str, list[SimJob]] = {}
        self._cap_bump = 0
        self._next_id = 1000001
        self._defer_schedule = False
        self._failures: list[tuple[datetime, str]] = []
        self.events_log: list[tuple[datetime, str]] = []
        self.bus = bus if bus is not None else EventBus()
        self.tick_hooks: list = []
        self._wakeups: list[datetime] = []

    # ------------------------------------------------------------------ submit

    def submit(self, job) -> int:
        opts = job.opts
        base = self._next_id
        self._next_id += 1
        begin = None
        if opts.begin:
            begin = datetime.fromisoformat(opts.begin)
        duration = job.sim_duration_s
        if duration is None:
            duration = self.default_duration_s
        eco_meta = getattr(job, "eco_meta", None) or {}
        held = bool(getattr(opts, "hold", False))
        n_tasks = max(1, opts.array_size)
        for t in range(n_tasks):
            jid = f"{base}_{t}" if opts.array_size > 0 else str(base)
            j = SimJob(
                jobid=jid,
                name=job.name,
                user=self.default_user,
                partition=opts.queue or "main",
                cpus=opts.threads,
                memory_mb=opts.memory_mb,
                time_limit_s=opts.time_s,
                duration_s=int(duration),
                submitted_at=self.now,
                begin=begin,
                dependencies=[str(d) for d in opts.dependencies],
                dependency_type=opts.dependency_type,
                requeue=opts.requeue,
                script_path=job.script_path,
                array_task_id=t if opts.array_size > 0 else None,
                held=held,
                tool=getattr(job, "tool", "") or "",
                eco_deferred=bool(eco_meta.get("deferred", False)),
                eco_tier=int(eco_meta.get("tier", 0) or 0),
            )
            if held:
                j.reason = ev.HELD_REASON
            self.jobs[jid] = j
            self._active[jid] = j
            self._by_base.setdefault(str(base), []).append(j)
            self._emit(ev.SUBMITTED, j)
        self._log(f"submit {base} name={job.name} tasks={n_tasks}")
        self._try_schedule()
        return base

    def submit_many(self, jobs: list) -> list[int]:
        ids = []
        self._defer_schedule = True
        try:
            for job in jobs:
                ids.append(self.submit(job))
        finally:
            self._defer_schedule = False
        self._try_schedule()
        return ids

    # ------------------------------------------------------------------ queries

    def queue(self) -> list[dict]:
        rows = []
        for j in sorted(self._active.values(), key=lambda j: (j.base_id, j.array_task_id or 0)):
            if j.state in _TERMINAL:
                continue
            used = int((self.now - j.started_at).total_seconds()) if j.started_at else 0
            left = max(0, j.time_limit_s - used) if j.state == "RUNNING" else 0
            rows.append(
                {
                    "jobid": j.jobid,
                    "user": j.user,
                    "queue": j.partition,
                    "name": j.name,
                    "state": j.state,
                    "time_used": format_slurm_time(used),
                    "time_left": format_slurm_time(left),
                    "time_limit": format_slurm_time(j.time_limit_s),
                    "nodelist": j.node or "",
                    "reason": j.reason,
                    "cpus": str(j.cpus),
                    "memory": str(j.memory_mb),
                }
            )
        return rows

    def accounting(self) -> list[SimJob]:
        return sorted(self.jobs.values(), key=lambda j: (j.base_id, j.array_task_id or 0))

    def get(self, jobid) -> SimJob | None:
        jid = str(jobid)
        if jid in self.jobs:
            return self.jobs[jid]
        for j in self._by_base.get(jid, ()):
            return j
        return None

    def states_of(self, base_id: int) -> list[str]:
        return [j.state for j in self._by_base.get(str(int(base_id)), ())]

    def nodes_info(self) -> list[dict]:
        return [
            {"name": n.name, "cpus": n.cpus, "memory_mb": n.memory_mb,
             "state": n.state, "used_cpus": n.used_cpus}
            for n in self.nodes
        ]

    # ------------------------------------------------------------------ control

    def cancel(self, jobids: list) -> None:
        targets = set()
        for jid in jobids:
            jid = str(jid)
            if jid in self.jobs:
                targets.add(jid)
            for j in self._by_base.get(jid, ()):
                targets.add(j.jobid)
        for jid in targets:
            j = self.jobs[jid]
            if j.state in _TERMINAL:
                continue
            if j.state == "RUNNING":
                self._release(j)
                self._charge(j, (self.now - j.started_at).total_seconds())
            j.state = "CANCELLED"
            j.finished_at = self.now
            self._retire(j)
            self._log(f"cancel {jid}")
            self._emit(ev.CANCELLED, j)
        self._try_schedule()

    def release(self, jobids: list) -> None:
        released = False
        for jid in jobids:
            jid = str(jid)
            exact = self.jobs.get(jid)
            cands = ([exact] if exact is not None else []) + [
                j for j in self._by_base.get(jid, ()) if j is not exact
            ]
            for j in cands:
                if not j.held or j.state in _TERMINAL:
                    continue
                j.held = False
                if j.reason == ev.HELD_REASON:
                    j.reason = ""
                released = True
                self._log(f"release {j.jobid}")
                self._emit(ev.RELEASED, j)
        if released:
            self._try_schedule()

    def fail_node(self, name: str, at: datetime | None = None) -> None:
        if at is not None and at > self.now:
            self._failures.append((at, name))
            self._failures.sort()
            return
        node = self._node(name)
        node.state = "DOWN"
        self._log(f"node_fail {name}")
        for j in list(self._active.values()):
            if j.state == "RUNNING" and j.node == name:
                self._release(j, node_down=True)
                self._charge(j, (self.now - j.started_at).total_seconds())
                if j.requeue:
                    j.state = "PENDING"
                    j.reason = "BeginTime" if j.begin and j.begin > self.now else "Resources"
                    j.node = None
                    j.started_at = None
                    j.restarts += 1
                    self._log(f"requeue {j.jobid}")
                    self._emit(ev.REQUEUED, j)
                else:
                    j.state = "NODE_FAIL"
                    j.finished_at = self.now
                    self._retire(j)
                    self._emit(ev.NODE_FAIL, j)
        self._try_schedule()

    def restore_node(self, name: str) -> None:
        self._node(name).state = "UP"
        self._cap_bump += 1
        self._log(f"node_up {name}")
        self._try_schedule()

    # ------------------------------------------------------------------ clock

    def advance(self, seconds: float = 0, *, to: datetime | None = None):
        target = to if to is not None else self.now + timedelta(seconds=seconds)
        while True:
            t = self._next_event_time(target)
            if t is None:
                break
            self.now = t
            self._process_due_events()
            self._try_schedule()
            self._tick()
        self.now = max(self.now, target)
        self._process_due_events()
        self._try_schedule()
        self._tick()
        return self

    def wake_at(self, t: datetime) -> None:
        if t > self.now and t not in self._wakeups:
            self._wakeups.append(t)
            self._wakeups.sort()

    def add_tick_hook(self, fn) -> None:
        if fn not in self.tick_hooks:
            self.tick_hooks.append(fn)

    def remove_tick_hook(self, fn) -> None:
        if fn in self.tick_hooks:
            self.tick_hooks.remove(fn)

    def _tick(self) -> None:
        self._wakeups = [t for t in self._wakeups if t > self.now]
        for fn in list(self.tick_hooks):
            fn(self, self.now)

    def run_until_idle(self, max_days: int = 30):
        deadline = self.now + timedelta(days=max_days)
        while self.now < deadline:
            active = [j for j in self._active.values() if j.state not in _TERMINAL
                      and j.reason != "DependencyNeverSatisfied"]
            if not active:
                break
            t = self._next_event_time(deadline)
            if t is None:
                break
            self.advance(to=t)
        return self

    # ------------------------------------------------------------------ internals

    def _node(self, name: str) -> SimNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def _next_event_time(self, target: datetime) -> datetime | None:
        times = []
        for j in self._active.values():
            if j.state == "RUNNING":
                end = j.started_at + timedelta(
                    seconds=min(j.duration_s, j.time_limit_s)
                )
                times.append(end)
            elif j.state == "PENDING" and j.begin and j.begin > self.now:
                times.append(j.begin)
        times += [t for t, _ in self._failures]
        times += self._wakeups
        future = [t for t in times if self.now < t <= target]
        return min(future) if future else None

    def _process_due_events(self) -> None:
        due = [(t, n) for t, n in self._failures if t <= self.now]
        self._failures = [(t, n) for t, n in self._failures if t > self.now]
        for _, name in due:
            self.fail_node(name)
        # completions, in numeric (base, task) order — NOT jobid string order
        for j in sorted(self._active.values(),
                        key=lambda j: (j.base_id, j.array_task_id or 0)):
            if j.state != "RUNNING":
                continue
            runtime = min(j.duration_s, j.time_limit_s)
            end = j.started_at + timedelta(seconds=runtime)
            if end <= self.now:
                self._finish(j)

    def _finish(self, j: SimJob) -> None:
        self._release(j)
        j.finished_at = self.now
        self._charge(j, min(j.duration_s, j.time_limit_s))
        if j.duration_s > j.time_limit_s:
            j.state = "TIMEOUT"
            self._retire(j)
            self._log(f"timeout {j.jobid}")
            self._emit(ev.TIMEOUT, j)
            return
        if self.execute and j.script_path and os.path.exists(j.script_path):
            env = dict(os.environ)
            env["SLURM_JOB_ID"] = str(j.base_id)
            env["SLURM_CPUS_PER_TASK"] = str(j.cpus)
            if j.array_task_id is not None:
                env["SLURM_ARRAY_TASK_ID"] = str(j.array_task_id)
                env["SLURM_ARRAY_JOB_ID"] = str(j.base_id)
            proc = subprocess.run(
                ["bash", j.script_path],
                env=env,
                capture_output=True,
                text=True,
            )
            j.state = "COMPLETED" if proc.returncode == 0 else "FAILED"
            if proc.returncode != 0:
                j.reason = f"NonZeroExitCode({proc.returncode})"
        else:
            j.state = "COMPLETED"
        self._retire(j)
        self._log(f"finish {j.jobid} state={j.state}")
        self._emit(ev.COMPLETED if j.state == "COMPLETED" else ev.FAILED, j)

    def _charge(self, j: SimJob, seconds: float) -> None:
        j.energy_j += self.watts_per_cpu * j.cpus * max(0.0, seconds)

    def _retire(self, j: SimJob) -> None:
        self._active.pop(j.jobid, None)

    def _release(self, j: SimJob, node_down: bool = False) -> None:
        self._cap_bump += 1
        if j.node:
            node = self._node(j.node)
            if not node_down or node.state == "UP":
                node.used_cpus -= j.cpus
                node.used_mem -= j.memory_mb
            else:
                node.used_cpus = max(0, node.used_cpus - j.cpus)
                node.used_mem = max(0, node.used_mem - j.memory_mb)

    def _deps_state(self, j: SimJob) -> str:
        for dep in j.dependencies:
            dep_jobs = self._by_base.get(str(dep), [])
            if not dep_jobs:
                return "wait"
            for d in dep_jobs:
                if d.state in ("FAILED", "CANCELLED", "TIMEOUT", "NODE_FAIL"):
                    return "never"
                if d.state != "COMPLETED":
                    return "wait"
        return "ok"

    def _try_schedule(self) -> None:
        if self._defer_schedule:
            return
        pending = sorted(
            (j for j in self._active.values() if j.state == "PENDING"),
            key=lambda j: (j.base_id, j.array_task_id or 0),
        )
        failed: list[tuple[int, int]] = []
        bump0 = self._cap_bump
        for j in pending:
            if j.state != "PENDING":
                continue
            if j.held:
                j.reason = ev.HELD_REASON
                continue
            if j.begin and self.now < j.begin:
                j.reason = "BeginTime"
                continue
            deps = self._deps_state(j)
            if deps == "never":
                j.reason = "DependencyNeverSatisfied"
                continue
            if deps == "wait":
                j.reason = "Dependency"
                continue
            if self._cap_bump != bump0:
                failed.clear()
                bump0 = self._cap_bump
            if any(fc <= j.cpus and fm <= j.memory_mb for fc, fm in failed):
                j.reason = "Resources"
                continue
            placed = False
            for node in self.nodes:
                if node.fits(j.cpus, j.memory_mb):
                    node.used_cpus += j.cpus
                    node.used_mem += j.memory_mb
                    j.node = node.name
                    j.state = "RUNNING"
                    j.reason = ""
                    j.started_at = self.now
                    placed = True
                    self._log(f"start {j.jobid} on {node.name}")
                    self._emit(ev.STARTED, j)
                    break
            if not placed:
                j.reason = "Resources"
                if len(failed) < 32:
                    failed.append((j.cpus, j.memory_mb))

    def _log(self, msg: str) -> None:
        self.events_log.append((self.now, msg))

    def _emit(self, type_: str, j: SimJob) -> None:
        self.bus.emit(JobEvent(
            type=type_, jobid=j.jobid, at=self.now, name=j.name,
            user=j.user, state=j.state, node=j.node or "", reason=j.reason,
        ))
