"""``EventBus`` / ``JobEvent`` — the event-driven core.

Every job state transition in the stack is announced as a typed
:class:`JobEvent` on an :class:`EventBus`, replacing the poll-everywhere
pattern where each consumer (waitjobs, the viewjobs TUI, accounting)
rediscovered state changes by diffing ``squeue`` snapshots on its own
schedule:

* :class:`~repro.core.simcluster.SimCluster` emits natively — one event at
  the exact simulated instant of every transition inside ``advance()`` /
  ``cancel()`` / ``fail_node()`` / ``release()``;
* real SLURM cannot push, so :class:`PollingEventAdapter` diffs consecutive
  squeue/sacct snapshots into the *same* synthetic events — subscribers are
  backend-agnostic.

Consumers: ``waitjobs`` blocks on terminal events (one snapshot per clock
advance instead of one poll per tick), ``QueueCache`` invalidates on events
instead of pure TTL expiry, accounting's :class:`~repro.accounting.collect.
EventCollector` archives each job at its terminal event without full-archive
rescans, and the :class:`~repro.core.ecocontroller.EcoController` releases
held eco jobs when observed load drops (see ``docs/architecture.md``).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from datetime import datetime

# ---------------------------------------------------------------------------
# Event vocabulary
# ---------------------------------------------------------------------------

SUBMITTED = "SUBMITTED"
STARTED = "STARTED"
RELEASED = "RELEASED"  # a held job was released (eco hold-and-release)
COMPLETED = "COMPLETED"
FAILED = "FAILED"
TIMEOUT = "TIMEOUT"
CANCELLED = "CANCELLED"
NODE_FAIL = "NODE_FAIL"
REQUEUED = "REQUEUED"

#: every event type, in rough lifecycle order
EVENT_TYPES = (
    SUBMITTED, STARTED, RELEASED,
    COMPLETED, FAILED, TIMEOUT, CANCELLED, NODE_FAIL, REQUEUED,
)

#: events after which the job is gone from the queue for good
TERMINAL_EVENTS = frozenset({COMPLETED, FAILED, TIMEOUT, CANCELLED, NODE_FAIL})

#: queue/sacct state → the terminal event announcing it
_STATE_TO_TERMINAL = {
    "COMPLETED": COMPLETED,
    "FAILED": FAILED,
    "TIMEOUT": TIMEOUT,
    "CANCELLED": CANCELLED,
    "NODE_FAIL": NODE_FAIL,
    "OUT_OF_MEMORY": FAILED,
}


def terminal_event_for_state(state: str) -> str:
    """Map a (possibly decorated) terminal queue state to its event type.

    Unknown states — including a job that simply vanished between two
    snapshots with no accounting trail — read as ``COMPLETED``, mirroring
    the long-standing waitjobs convention that *gone from the queue* means
    *done*.
    """
    state = (state or "").split(" ")[0]
    if state in _STATE_TO_TERMINAL:
        return _STATE_TO_TERMINAL[state]
    # sacct may truncate/decorate (OUT_OF_ME+, CANCELLED by 123)
    if state.startswith("OUT_OF_ME"):
        return FAILED
    if state.startswith("CANCELLED"):
        return CANCELLED
    return COMPLETED


@dataclass(frozen=True)
class JobEvent:
    """One job state transition, as observed by the emitting backend."""

    type: str  # one of EVENT_TYPES
    jobid: str
    at: datetime
    name: str = ""
    user: str = ""
    state: str = ""  # queue state after the transition ("" when implied)
    node: str = ""
    reason: str = ""
    #: federation member the transition happened on ("" outside a
    #: FederatedBackend; the jobid is then cluster-prefixed to match)
    cluster: str = ""

    @property
    def is_terminal(self) -> bool:
        return self.type in TERMINAL_EVENTS


class EventBus:
    """Synchronous pub/sub for :class:`JobEvent` (in-process, ordered).

    Subscribers are plain callables ``fn(event)``; ``types`` narrows the
    subscription. Delivery is in subscription order at the emitting call
    site, so a simulator test observes events at the exact simulated
    instant they happen. A misbehaving subscriber must not corrupt the
    emitter mid-transition: its exception is recorded on ``bus.errors``
    (bounded) and delivery continues.

    ``history`` keeps the most recent events for late joiners (the TUI's
    live ticker, test assertions); it is a debugging aid, not a replay log.
    """

    def __init__(self, history: int = 256):
        self._subs: dict[int, tuple] = {}  # token → (fn, frozenset|None)
        self._next_token = 1
        self.history: deque[JobEvent] = deque(maxlen=history)
        self.emitted = 0  # events emitted
        self.delivered = 0  # subscriber callbacks invoked
        self.errors: deque = deque(maxlen=16)  # (event, exception)

    def subscribe(self, fn, types=None) -> int:
        """Register ``fn(event)``; returns a token for :meth:`unsubscribe`.

        ``types``: iterable of event types to receive (default: all).
        """
        token = self._next_token
        self._next_token += 1
        self._subs[token] = (fn, frozenset(types) if types is not None else None)
        return token

    def unsubscribe(self, token: int) -> None:
        self._subs.pop(token, None)

    def emit(self, event: JobEvent) -> None:
        self.emitted += 1
        self.history.append(event)
        # snapshot: a subscriber may (un)subscribe during delivery
        for fn, types in list(self._subs.values()):
            if types is not None and event.type not in types:
                continue
            try:
                fn(event)
                self.delivered += 1
            except Exception as e:  # noqa: BLE001 — isolate subscribers
                self.errors.append((event, e))
                # cold path only: the happy path stays obs-free so native
                # emission keeps its ~1M events/s
                from repro.obs.metrics import get_registry

                get_registry().counter(
                    "nbi_bus_subscriber_errors_total",
                    "subscriber exceptions swallowed by EventBus.emit",
                    labels=("type",),
                ).labels(type=event.type).inc()

    def __len__(self) -> int:
        return len(self._subs)


# ---------------------------------------------------------------------------
# Polling adapter: snapshot diffs → synthetic events (real-SLURM side)
# ---------------------------------------------------------------------------

#: squeue reason marking a user/controller hold (real SLURM and SimCluster)
HELD_REASON = "JobHeldUser"


def diff_snapshots(prev, cur, now: datetime) -> "list[JobEvent]":
    """Diff two ``{jobid: row}`` queue snapshots into synthetic events.

    ``prev is None`` marks the first observation: it establishes the
    baseline and yields no events (pre-existing jobs did not *transition*).
    Vanished jobs yield a terminal event with ``state=""`` — the caller
    (:class:`PollingEventAdapter`) refines it through accounting when it
    can. Pure function, unit-testable without a backend.
    """
    if prev is None:
        return []
    events: list[JobEvent] = []

    def ev(type_, row, state="", reason=""):
        events.append(JobEvent(
            type=type_, jobid=row["jobid"], at=now,
            name=row.get("name", ""), user=row.get("user", ""),
            state=state or row.get("state", ""),
            node=row.get("nodelist", ""), reason=reason or row.get("reason", ""),
            cluster=row.get("cluster", ""),
        ))

    for jid, row in cur.items():
        old = prev.get(jid)
        state = row.get("state", "")
        if old is None:
            ev(SUBMITTED, row)
            if state == "RUNNING":  # appeared already running
                ev(STARTED, row)
            continue
        old_state = old.get("state", "")
        if old_state != "RUNNING" and state == "RUNNING":
            ev(STARTED, row)
        elif old_state == "RUNNING" and state == "PENDING":
            ev(REQUEUED, row)
        elif (
            old.get("reason", "") == HELD_REASON
            and row.get("reason", "") != HELD_REASON
            and state == "PENDING"
        ):
            ev(RELEASED, row)
    for jid, row in prev.items():
        if jid not in cur:
            # vanished: terminal, but the last-seen state is stale — leave
            # state empty so the adapter resolves it through accounting
            events.append(JobEvent(
                type=terminal_event_for_state(""), jobid=jid, at=now,
                name=row.get("name", ""), user=row.get("user", ""),
                state="", node=row.get("nodelist", ""),
                cluster=row.get("cluster", ""),
            ))
    return events


class PollingEventAdapter:
    """Synthesises :class:`JobEvent` s for backends that cannot push.

    Each :meth:`poll` takes ONE queue snapshot, diffs it against the
    previous one, resolves the terminal state of vanished jobs (via the
    backend's ``get``/``accounting`` when available, defaulting to
    ``COMPLETED``) and emits the events on :attr:`bus`. Subscribers see
    exactly the vocabulary the simulator emits natively — they cannot
    tell which backend they are watching.
    """

    def __init__(self, backend, bus: EventBus | None = None, *, clock=None):
        self.backend = backend
        self.bus = bus if bus is not None else EventBus()
        self._clock = clock or datetime.now
        self._prev: "dict[str, dict] | None" = None
        self._acct: "dict | None" = None  # per-poll accounting lookup
        self.polls = 0  # snapshots taken

    def poll(self, now: datetime | None = None) -> "list[JobEvent]":
        """One snapshot → the events since the previous poll (also emitted)."""
        now = now or self._clock()
        self._acct = None  # at most one accounting call per poll
        rows = {r["jobid"]: dict(r) for r in self.backend.queue()}
        self.polls += 1
        from repro.obs.metrics import get_registry

        get_registry().counter(
            "nbi_adapter_polls_total",
            "queue snapshots taken by PollingEventAdapter",
        ).inc()
        events = diff_snapshots(self._prev, rows, now)
        self._prev = rows
        events = [self._resolve_terminal(e) if e.is_terminal and not e.state
                  else e for e in events]
        for e in events:
            self.bus.emit(e)
        return events

    # -- internals -------------------------------------------------------------

    def _resolve_terminal(self, event: JobEvent) -> JobEvent:
        """Refine a vanished job's event via the backend's accounting."""
        state = self._final_state(event.jobid)
        if not state:
            return event
        from dataclasses import replace

        return replace(event, type=terminal_event_for_state(state), state=state)

    def _final_state(self, jobid: str) -> str:
        get = getattr(self.backend, "get", None)
        if get is not None:  # simulator-shaped backend: exact answer
            job = get(jobid)
            return getattr(job, "state", "") if job is not None else ""
        accounting = getattr(self.backend, "accounting", None)
        if accounting is None:
            return ""
        if self._acct is None:  # one sacct call per poll, not per job
            try:
                self._acct = {str(r.get("jobid", "")): r for r in accounting()}
            except Exception:  # noqa: BLE001 — sacct may be unavailable
                self._acct = {}
        row = self._acct.get(str(jobid))
        return str(row.get("state", "")) if row else ""
