"""Sharded functional optimizers: AdamW, 8-bit AdamW, Lion.

No optax dependency. Each optimizer is a pair of pure functions plus a
*logical-axis mirror* so the dry-run can lower trillion-parameter update
steps without allocating:

  init(params)                 → opt state (tree of arrays)
  update(grads, state, params) → (new_params, new_state)
  state_logical(param_logical) → logical axes for every state leaf

``adamw8bit`` stores m/v block-quantized to int8 with per-row absmax scales
(bitsandbytes-style) — 4 bytes/param of optimizer state instead of 8. This
is what lets the kimi-k2 (≈1.03 T params) train_step fit the dry-run memory
budget (see EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)
    state_logical: Callable  # (param_logical_tree) -> state logical tree


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


# ---------------------------------------------------------------------------
# AdamW (fp32 moments)
# ---------------------------------------------------------------------------


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        return {
            "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads = _tmap(lambda g: g.astype(jnp.float32), grads)
        grads = _clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        lr_t = sched(count)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        m = _tmap(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = _tmap(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)

        def step(p, m_, v_):
            upd = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)

        new_params = _tmap(step, params, m, v)
        return new_params, {"m": m, "v": v, "count": count}

    def state_logical(param_logical):
        return {"m": param_logical, "v": param_logical, "count": ()}

    return Optimizer("adamw", init, update, state_logical)


# ---------------------------------------------------------------------------
# 8-bit AdamW (block-quantized moments, error kept implicitly via requant)
# ---------------------------------------------------------------------------


def _quant(x):
    """Per-row int8 absmax quantisation. x: f32 (..., N) → (int8, f32 scales)."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale[..., None]


def adamw8bit(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        def zq(p):
            return {
                "q": jnp.zeros(p.shape, jnp.int8),
                "scale": jnp.zeros(p.shape[:-1], jnp.float32),
            }

        return {
            "m": _tmap(zq, params),
            "v": _tmap(zq, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads = _tmap(lambda g: g.astype(jnp.float32), grads)
        grads = _clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        lr_t = sched(count)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_m = treedef.flatten_up_to(state["m"])
        leaves_v = treedef.flatten_up_to(state["v"])
        new_p, new_m, new_v = [], [], []
        for g, p, mq, vq in zip(leaves_g, leaves_p, leaves_m, leaves_v):
            m = b1 * _dequant(mq["q"], mq["scale"]) + (1 - b1) * g
            v = b2 * _dequant(vq["q"], vq["scale"]) + (1 - b2) * g * g
            upd = (m / c1) / (jnp.sqrt(jnp.maximum(v, 0.0) / c2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr_t * upd).astype(p.dtype))
            qm, sm = _quant(m)
            qv, sv = _quant(v)
            new_m.append({"q": qm, "scale": sm})
            new_v.append({"q": qv, "scale": sv})
        unf = jax.tree_util.tree_unflatten
        return unf(treedef, new_p), {
            "m": unf(treedef, new_m),
            "v": unf(treedef, new_v),
            "count": count,
        }

    def state_logical(param_logical):
        def mirror(lg):
            return {"q": lg, "scale": lg[:-1]}

        wrap = lambda tree: jax.tree_util.tree_map(
            mirror, tree, is_leaf=lambda x: isinstance(x, tuple)
        )
        return {"m": wrap(param_logical), "v": wrap(param_logical), "count": ()}

    return Optimizer("adamw8bit", init, update, state_logical)


# ---------------------------------------------------------------------------
# Lion (single moment) — lowest-memory fp option
# ---------------------------------------------------------------------------


def lion(lr=1e-4, b1=0.9, b2=0.99, weight_decay=0.1, clip_norm=1.0):
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        return {
            "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads = _tmap(lambda g: g.astype(jnp.float32), grads)
        grads = _clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        lr_t = sched(count)

        def step(p, m, g):
            upd = jnp.sign(b1 * m + (1 - b1) * g) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)

        new_params = _tmap(step, params, state["m"], grads)
        m = _tmap(lambda m, g: b2 * m + (1 - b2) * g, state["m"], grads)
        return new_params, {"m": m, "count": count}

    def state_logical(param_logical):
        return {"m": param_logical, "count": ()}

    return Optimizer("lion", init, update, state_logical)


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adamw8bit": adamw8bit, "lion": lion}[name](**kw)


# ---------------------------------------------------------------------------


def _clip_by_global_norm(grads, max_norm: float):
    if not max_norm or max_norm <= 0:
        return grads
    sq = sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return _tmap(lambda g: g * scale, grads)
