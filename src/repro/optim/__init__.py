from .optimizers import Optimizer, adamw, adamw8bit, lion, make_optimizer
from .schedules import constant, cosine_warmup

__all__ = [
    "Optimizer", "adamw", "adamw8bit", "lion", "make_optimizer",
    "constant", "cosine_warmup",
]
