"""Learning-rate schedules (callables step → lr, jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def schedule(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = peak * jnp.minimum(1.0, step / max(1, warmup_steps))
        frac = jnp.clip(
            (step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0
        )
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
