"""Multi-pod submission: from `nbilaunch train arch=...` to a 64-node sbatch.

    PYTHONPATH=src python examples/multipod_submit.py

Shows the full production path for a big run:
  1. TrainLauncher derives chips/hosts/host-RAM from the model config
     (the paper's Kraken2 inflation pattern at pod scale);
  2. the generated command is a multi-task `srun` whose topology is picked
     up by repro.launch.distributed (SLURM env → jax.distributed);
  3. `sbatch_script()` emits the standalone deploy artifact;
  4. eco mode defers the whole pod job to the next low-energy window —
     same EcoScheduler, now moving megawatt-scale work off peak hours.
"""

import sys
from datetime import datetime
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import SimCluster
from repro.launch.submit import TrainLauncher

sim = SimCluster(default_user="ml-platform")
tl = TrainLauncher(
    arch="mistral-large-123b", steps=20000, global_batch=256, seq=4096,
    outdir="/scratch/mistral-run", backend=sim,
)
s = tl.sizing
print(f"derived resources for mistral-large-123b:")
print(f"  chips={s['chips']}  hosts={s['hosts']}  "
      f"host_mem={tl.opts.memory_mb // 1024} GB  wall={tl.opts.slurm_time}")
print(f"\ncommand:\n  {tl.make_command()}\n")
print("sbatch script:")
print("-" * 68)
print(tl.sbatch_script())
print("-" * 68)

# eco-mode submission: Wednesday 10:00 → deferred into the night window
jid = tl.submit(now=datetime(2026, 3, 18, 10, 0))
job = sim.get(jid)
print(f"\nsubmitted as {jid}: state={job.state} reason={job.reason} "
      f"begin={job.begin}")
assert job.begin is not None and job.begin.hour == 0
print("multipod_submit OK")
