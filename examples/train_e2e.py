"""End-to-end training driver: the framework's ~100M reference model.

    PYTHONPATH=src python examples/train_e2e.py                 # mini (CPU)
    PYTHONPATH=src python examples/train_e2e.py --scale full    # real 100M
    PYTHONPATH=src python examples/train_e2e.py --steps 300

Exercises every substrate layer at once: synthetic data pipeline →
sharding rules → jit'd train step (remat, grad clip, cosine LR) →
async checkpointing → kill/resume. The loss must fall monotonically-ish on
the Zipf/Markov synthetic stream; the script asserts a real decrease and
then restarts from the checkpoint to prove resume works.

``--scale mini`` (default) is a ~4M-param same-code-path model sized for a
CPU container; ``--scale full`` is the true nbi-100m (use on real hardware).
"""

import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config
from repro.launch.train import build_argparser, train


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["mini", "full"], default="mini")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="nbi100m-ckpt-")
    if args.scale == "full":
        base = ["--arch", "nbi-100m", "--global-batch", "16", "--seq", "512"]
    else:
        # mini: same family/code paths, CPU-sized
        import repro.configs.nbi100m as mod

        orig = mod.config
        mod.config = lambda: orig().replace(
            name="nbi-100m-mini", n_layers=4, d_model=256, n_heads=8,
            n_kv_heads=8, head_dim=32, d_ff=512, vocab_size=2048,
        )
        base = ["--arch", "nbi-100m", "--global-batch", "8", "--seq", "128"]

    targs = build_argparser().parse_args(
        base + ["--steps", str(args.steps), "--ckpt-dir", ckpt,
                "--ckpt-every", "50", "--log-every", "10", "--warmup", "20"]
    )
    result = train(targs)
    losses = [m["loss"] for m in result["metrics"]]
    print(f"\nloss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    assert losses[-1] < losses[0] - 0.15, "training did not learn"

    # resume: 20 more steps from the checkpoint
    targs2 = build_argparser().parse_args(
        base + ["--steps", str(args.steps + 20), "--ckpt-dir", ckpt,
                "--ckpt-every", "50", "--log-every", "10", "--warmup", "20"]
    )
    result2 = train(targs2)
    assert result2["completed_steps"] == args.steps + 20
    print(f"resumed and reached step {result2['completed_steps']} — e2e OK "
          f"(checkpoints in {ckpt})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
