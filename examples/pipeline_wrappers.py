"""Declarative wrappers + manifests + pipelines (paper §Wrappers).

    PYTHONPATH=src python examples/pipeline_wrappers.py

Demonstrates:
  1. the Kraken2 wrapper: inputs from env-var defaults, threads synced from
     --cpus, and the submission-time memory inflation (1.4× db + 100 GB);
  2. the JSON manifest written at submit time and *patched in place by the
     job script itself* on completion (simulator executes the script);
  3. a three-step pipeline (assemble → annotate → report) wired with
     automatic afterok dependencies;
  4. the TPU-era TrainLauncher: chip/host/memory sizing derived from the
     model config (the same inflation pattern at pod scale).
"""

import json
import os
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import Job, Kraken2, Manifest, Opts, Pipeline, SimCluster
from repro.launch.submit import TrainLauncher

workdir = Path(tempfile.mkdtemp(prefix="nbi-wrappers-"))
os.environ["NBI_TMPDIR"] = str(workdir / "scripts")

# -- 1/2: Kraken2 with manifest lifecycle -------------------------------------
db = workdir / "k2db"
db.mkdir()
(db / "hash.k2d").write_bytes(b"\0" * 50_000_000)  # 50 MB "database"

sim = SimCluster(execute=True)  # executes job scripts at completion time
kr = Kraken2(
    reads1="sample_R1.fastq", reads2="sample_R2.fastq", db=str(db),
    outdir=str(workdir / "kraken-out"), backend=sim, eco=False,
)
print(f"kraken2 memory request: {kr.opts.memory_mb / 1024:.1f} GB "
      f"(db 0.05 GB × 1.4 + 100 GB overhead)")
jid = kr.submit()
manifest_path = kr.manifest_path()
rec = json.loads(Path(manifest_path).read_text())
print(f"manifest at submit: status={rec['status']} jobid={rec['jobid']}")
sim.run_until_idle()
rec = json.loads(Path(manifest_path).read_text())
print(f"manifest after run : status={rec['status']} exit={rec['exit_status']} "
      f"finished={rec['finished_at'] is not None}")
# the command 'kraken2 ...' does not exist in this container → the script
# fails, and the manifest honestly records the failure — that's the point.

# -- 3: a pipeline with automatic afterok wiring -------------------------------
sim2 = SimCluster()
pipe = Pipeline("asm-annotate", backend=sim2)
pipe.add("assemble", Job(name="assemble", command="flye ...",
                         opts=Opts.new(threads=18, memory="64GB", time=12)))
pipe.add("annotate", Job(name="annotate", command="prokka asm/ ...",
                         opts=Opts.new(threads=8, memory="16GB", time=6)),
         after="assemble")
pipe.add("report", Job(name="report", command="python report.py",
                       opts=Opts.new(threads=1, memory="2GB", time="30m")),
         after=["annotate"])
ids = pipe.run(eco=False)
print(f"\npipeline submitted: {ids}")
dep = sim2.get(ids["report"])
print(f"report dependencies: {dep.dependencies} (afterok)")
sim2.run_until_idle()
assert all(j.state == "COMPLETED" for j in sim2.accounting())
print("pipeline completed in dependency order")

# -- 4: the TPU-era TrainLauncher ----------------------------------------------
for arch in ("nbi-100m", "starcoder2-7b", "mistral-large-123b"):
    tl = TrainLauncher(arch=arch, outdir=str(workdir / "train"), eco=False,
                       backend=SimCluster())
    s = tl.sizing
    print(f"train {arch:>18s}: chips={s['chips']:4d} hosts={s['hosts']:4d} "
          f"host_mem={tl.opts.memory_mb / 1024:.0f}GB time={tl.opts.slurm_time}")
print("pipeline_wrappers OK")
