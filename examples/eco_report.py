"""Carbon accounting end to end: a simulated 1,000-job history walked
through the ``ecoreport`` pipeline.

    PYTHONPATH=src python examples/eco_report.py

Walks through:
  1. a month of eco-mode submissions on the simulator (mixed users and
     tools, padded time limits, true runtimes much shorter);
  2. harvesting the completed jobs into the HistoryStore with
     ``repro.accounting.collect`` (idempotent — run it twice, zero dupes);
  3. the per-user and per-tool ``ecoreport`` tables: energy, carbon, and
     the deferred-vs-counterfactual "carbon saved by eco mode" column;
  4. the learning step: re-submitting the same workload with a
     RuntimePredictor fed from the archive — padded 12 h requests are
     priced at their observed ~1 h runtimes and jump from tier 2 to
     tier 1 (completing inside the night window).
"""

import sys
import tempfile
from datetime import datetime, timedelta
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.accounting import (
    EnergyModel,
    HistoryStore,
    RuntimePredictor,
    collect,
    render_report,
    report_dict,
)
from repro.core import EcoScheduler, Job, Opts, SimCluster, SubmitEngine

WEEKDAY = [(0, 360)]  # 00:00-06:00
WEEKEND = [(0, 420), (660, 960)]
PEAK = [(1020, 1200)]  # 17:00-20:00

rng = np.random.default_rng(42)
workdir = Path(tempfile.mkdtemp(prefix="eco-report-"))
store = HistoryStore(workdir / "history.jsonl")
sched = EcoScheduler(
    weekday_windows=WEEKDAY, weekend_windows=WEEKEND, peak_hours=PEAK,
    horizon_days=14, min_delay_s=0,
)

# -- 1. a month of eco submissions on the simulator ---------------------------
print("=== 1. simulate a month of eco-mode submissions (1,000 jobs) ===")
sim = SimCluster(now=datetime(2026, 3, 2, 9, 0), default_user="alice")
for node in sim.nodes:
    node.cpus = 1024  # wide cluster: this example is about accounting
engine = SubmitEngine(sim, eco=True, coalesce=False, scheduler=sched,
                      now=sim.now)
TOOLS = ["kraken2", "align", "assembly", "qc"]
jobs = []
for i in range(1000):
    tool = TOOLS[i % len(TOOLS)]
    jobs.append(
        Job(
            name=f"{tool}-{i}",
            command="true",
            opts=Opts.new(threads=4, memory="4GB",
                          time=float(int(rng.integers(4, 13)))),  # padded!
            sim_duration_s=int(rng.uniform(1200, 4800)),  # true: 20-80 min
        )
    )
result = engine.submit_many(jobs)
sim.run_until_idle(max_days=40)
print(f"submitted {len(jobs)}, eco-deferred {result.eco_deferred}, "
      f"terminal states: "
      f"{ {s: sum(1 for j in sim.jobs.values() if j.state == s) for s in ('COMPLETED',)} }")

# -- 2. harvest into the archive ---------------------------------------------
print("\n=== 2. collect() the completed jobs into the HistoryStore ===")
model = EnergyModel()  # deterministic 12 W/core + synthetic intensity curve
n1 = collect(sim, store, model)
n2 = collect(sim, store, model)  # idempotent
print(f"first collect: {n1} records; second collect: {n2} (deduped)")

# -- 3. the ecoreport tables ---------------------------------------------------
print("\n=== 3. ecoreport: per-tool energy/carbon/savings ===")
records = store.records()
print(render_report(records, by="tool", color=False))

payload = report_dict(records, by="tool")
tot = payload["total"]
assert tot["energy_kwh"] > 0 and tot["carbon_gco2"] > 0
assert tot["carbon_saved_gco2"] > 0, "eco mode must show measured savings"
print(f"\n(--json totals: {tot['energy_kwh']} kWh, {tot['carbon_gco2']} g, "
      f"saved {tot['carbon_saved_gco2']} g)")

# -- 4. the learning step: predictor-fed re-submission ------------------------
print("\n=== 4. resubmit the workload with the history-fed predictor ===")
pred_sched = EcoScheduler(
    weekday_windows=WEEKDAY, weekend_windows=WEEKEND, peak_hours=PEAK,
    horizon_days=14, min_delay_s=0, predictor=RuntimePredictor(store),
)
now = datetime(2026, 4, 1, 10, 0)
for tool in TOOLS:
    plain = sched.decide(12 * 3600, now, name=f"{tool}-1")
    learned = pred_sched.decide(12 * 3600, now, name=f"{tool}-1")
    est = pred_sched.effective_duration(12 * 3600, f"{tool}-1")
    print(f"  {tool:9s} 12h request → predicted {est / 60:5.0f} min | "
          f"tier {plain.tier} → {learned.tier}")
print("\nhistorically short jobs now COMPLETE inside the night window (tier 1).")
