"""Eco mode in depth: three-tier windows, carbon-aware scoring, and
eco-preemption of a training run.

    PYTHONPATH=src python examples/eco_submit.py

Walks through:
  1. the paper's deferral example (Wed → next night window, tier 1);
  2. how the tier degrades as the job gets longer (tier 2: overruns the
     window; tier 3: cannot avoid peak hours);
  3. carbon-trace-aware scoring (beyond paper): among same-tier windows
     the scheduler picks the lowest-gCO2/kWh start;
  4. eco-preemption (beyond paper): a training loop that checkpoints and
     exits at the peak-hours boundary, then prints the --begin directive
     for its own resubmission — possible because the substrate has
     fault-tolerant checkpoint/restart.
"""

import sys
import tempfile
from datetime import datetime
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import CarbonTrace, EcoScheduler

WEEKDAY = [(0, 360)]  # 00:00-06:00
WEEKEND = [(0, 420), (660, 960)]  # 00:00-07:00, 11:00-16:00
PEAK = [(1020, 1200)]  # 17:00-20:00

sched = EcoScheduler(
    weekday_windows=WEEKDAY, weekend_windows=WEEKEND, peak_hours=PEAK,
    horizon_days=14, min_delay_s=0,
)
now = datetime(2026, 3, 18, 10, 0)  # Wednesday morning

# -- 1/2: tiers as a function of duration ------------------------------------
print("submitted Wednesday 2026-03-18 10:00; windows = weekday nights 00-06")
for hours in (2, 6, 10, 30):
    d = sched.next_window(hours * 3600, now)
    print(f"  {hours:3d}h job → begin {d.begin_directive}  tier {d.tier} "
          f"({'fits window' if d.tier == 1 else 'overruns' if d.tier == 2 else 'touches peak'})")

# -- 3: carbon-aware choice ---------------------------------------------------
# Trace: weekend grid is much cleaner than weekday nights (e.g. solar+wind).
hourly = np.full(168, 250.0)
for d in range(5):
    hourly[d * 24 : d * 24 + 6] = 180.0  # weekday nights: ok
for d in (5, 6):
    hourly[d * 24 : d * 24 + 7] = 90.0  # weekend nights: great
    hourly[d * 24 + 11 : d * 24 + 16] = 70.0  # weekend midday solar: best
carbon = CarbonTrace(hourly.tolist())
sched_c = EcoScheduler(
    weekday_windows=WEEKDAY, weekend_windows=WEEKEND, peak_hours=PEAK,
    horizon_days=14, min_delay_s=0, carbon_trace=carbon,
)
d_plain = sched.next_window(4 * 3600, now)
d_carbon = sched_c.next_window(4 * 3600, now)
print(f"\n4h job, no trace   → {d_plain.begin_directive} (earliest tier-1)")
print(f"4h job, with trace → {d_carbon.begin_directive} "
      f"({d_carbon.carbon_gco2_kwh:.0f} gCO2/kWh, cheapest tier-1)")
assert d_carbon.carbon_gco2_kwh <= d_plain.carbon_gco2_kwh if d_plain.carbon_gco2_kwh else True

# -- 4: eco-preemption of a real training loop --------------------------------
from repro.launch.train import build_argparser, train
import repro.configs.nbi100m as mod

orig = mod.config
mod.config = lambda: orig().replace(
    name="nbi-100m-nano", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512,
)
ckpt = tempfile.mkdtemp(prefix="eco-preempt-")
# virtual clock starts 3 s before the 17:00 peak — the loop trains until the
# boundary, then checkpoints and exits.
args = build_argparser().parse_args([
    "--arch", "nbi-100m", "--steps", "10000", "--global-batch", "4",
    "--seq", "64", "--ckpt-dir", ckpt, "--eco-preempt",
    "--now", "2026-03-18T16:59:57", "--log-every", "5",
])
result = train(args)
print(f"\neco-preempt: stopped={result['stopped']!r} "
      f"after {result['completed_steps']} steps; "
      f"resubmit --begin={result.get('resubmit_begin')}")
assert result["stopped"] == "eco-preempt"
assert result.get("resubmit_begin", "").startswith("2026-03-19T00:00")
print("eco_submit OK")
