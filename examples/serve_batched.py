"""Batched serving demo: fixed-shape engine + dynamic request batching.

    PYTHONPATH=src python examples/serve_batched.py

Builds a small dense model, then serves 10 variable-length requests
through the :class:`repro.launch.serve.ServeEngine`: prompts are grouped
into fixed (batch, seq) blocks (compile once, reuse for every group),
prefilled, and decoded token-by-token against the padded KV cache.
Prints per-phase throughput. Greedy decoding on a random-init model is
gibberish — the assert is determinism: the same request always yields the
same tokens regardless of which batch it lands in.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import ServeEngine

cfg = get_smoke_config("codeqwen1.5-7b").replace(name="serve-demo")
engine = ServeEngine(cfg, batch=4, max_seq=64, seed=0)

rng = np.random.default_rng(7)
requests = [
    rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
    for n in rng.integers(4, 32, size=10)
]
outs = engine.serve_requests(requests, gen_len=12)
for i, (req, out) in enumerate(zip(requests, outs)):
    print(f"req{i}: len={len(req):2d} → {out.tolist()}")

# determinism: rerun one request alone in a different grouping
again = engine.serve_requests([requests[3]], gen_len=12)[0]
assert np.array_equal(again, outs[3]), "batching changed a request's output"
s = engine.stats
print(f"\nprefill {s['prefill_tokens']} tok in {s['prefill_s']:.2f}s | "
      f"decode {s['decode_tokens']} tok in {s['decode_s']:.2f}s")
print("serve_batched OK")
