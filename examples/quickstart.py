"""Quickstart — the paper's API, end to end, no Slurm required.

Reproduces every example from the paper against the in-process simulator:

  1. ``runjob``-style submission with human-friendly resources
  2. a job array from a file list (#FILE# placeholder)
  3. eco-mode deferral (--begin injection, three-tier windows)
  4. programmatic job chaining (NBI::Job + afterok dependencies)
  5. the queue tools (lsjobs table, whojobs utilisation)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys
from datetime import datetime
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import EcoScheduler, Job, Opts, Queue, SimCluster
from repro.cli.lsjobs import HEADERS, queue_rows
from repro.cli.render import render_table
from repro.cli.whojobs import utilisation_rows

sim = SimCluster(default_user="bioinfo-user")

# -- 1. paper example: 18 cores, 64 GB, 12 h assembly ----------------------
opts = Opts.new(queue="genomics-fast", threads=18, memory="64GB", time=12)
job = Job(
    name="assembly",
    command="flye --nano-raw reads.fastq --out-dir asm",
    opts=opts,
    backend=sim,
)
jid = job.run()
print(f"submitted assembly as job {jid}")
print("\n".join(job.script().splitlines()[:10]))

# -- 2. paper example: one alignment job per FASTQ file ---------------------
samples = [f"sample_{i:02d}.fastq" for i in range(6)]
array = Job(
    name="align",
    command="bwa mem ref.fa #FILE# > #FILE#.bam",
    opts=Opts.new(threads=8, memory="16GB", time="4h"),
    files=samples,
    backend=sim,
)
aid = array.run()
print(f"\nsubmitted array {aid} with {len(samples)} tasks")

# -- 3. paper example: eco-mode deferral ------------------------------------
# Submitted Wed 2026-03-18 10:00; a 6 h annotation job fits the next
# weekday-night window exactly → tier 1, --begin=2026-03-19T00:00:00.
now = datetime(2026, 3, 18, 10, 0, 0)
sched = EcoScheduler(weekday_windows=[(0, 360)],
                     weekend_windows=[(0, 420), (660, 960)],
                     peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0)
decision = sched.next_window(6 * 3600, now)
print(f"\neco: 6h job submitted {now} → begin={decision.begin_directive} "
      f"(tier {decision.tier})")
eco_opts = Opts.new(threads=4, memory="8GB", time=6)
eco_opts.set_begin(decision.begin_directive)
Job(name="annotate", command="prokka genome.fa", opts=eco_opts, backend=sim).run()

# -- 4. paper example: programmatic chaining ---------------------------------
step1 = Job(
    name="step1",
    command="bash analyse.sh",
    opts=Opts.new(threads=4, memory=8 * 1024, time="1h"),
    backend=sim,
)
id1 = step1.run()
step2 = Job(
    name="step2",
    command="python report.py --input results/",
    opts=Opts.new(threads=1, memory="2GB", time="30m"),
    backend=sim,
)
step2.set_dependencies(id1)
id2 = step2.run()
print(f"\nchained: step1={id1} → step2={id2} (afterok)")

# -- 5. the queue tools -------------------------------------------------------
q = Queue(backend=sim)
print("\nlsjobs view:")
print(render_table(HEADERS, queue_rows(q), enabled=False))
print("\nwhojobs view:")
print(render_table(["User", "Running", "Pending", "CPUs", "Mem(GB)", "Share"],
                   utilisation_rows(q), enabled=False))

# let the simulator run everything to completion
sim.run_until_idle()
states = {j.jobid: j.state for j in sim.accounting()}
print(f"\nafter run_until_idle: {len(states)} jobs, "
      f"states={sorted(set(states.values()))}")
assert set(states.values()) == {"COMPLETED"}
print("quickstart OK")
