"""Multi-cluster federation end to end: eco jobs migrate to the green grid.

    PYTHONPATH=src python examples/federation_demo.py

Walks through:
  1. a two-member federation built from ``[cluster.<name>]`` stanzas —
     ``coal`` (dirty grid, the default member) and ``hydro`` (green grid,
     overnight eco windows), both deterministic in-process simulators;
  2. a mixed workload routed through the ``SubmitEngine`` placement
     stage: eco-tier jobs migrate to the green member, an urgent batch
     stays wherever the queue is shortest;
  3. the federated queue view (namespaced ids, per-cluster rows) and a
     cross-cluster wait on the aggregated event bus;
  4. the accounting close-out: per-cluster ``ecoreport`` totals with the
     placement counterfactual — carbon saved by routing away from the
     default member.

Everything runs in simulated time; the whole demo takes well under a
second of wall clock.
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.accounting import EnergyModel, HistoryStore, collect, render_report
from repro.core import (
    ClusterRegistry,
    FederatedBackend,
    Job,
    Opts,
    SubmitEngine,
    load_config,
    write_config,
)

workdir = Path(tempfile.mkdtemp(prefix="federation-demo-"))

# ---------------------------------------------------------------------------
# 1. two sim clusters on divergent grids, declared exactly as a user would
# ---------------------------------------------------------------------------

dirty_csv = workdir / "coal.csv"
green_csv = workdir / "hydro.csv"
dirty_csv.write_text("\n".join(f"{h},620" for h in range(168)))  # gCO2/kWh
green_csv.write_text("\n".join(f"{h},35" for h in range(168)))

cfg_path = workdir / "nbislurm.config"
cfg_path.write_text(f"""\
economy_mode = 1

[cluster.coal]
kind = sim
nodes = 4
cpus_per_node = 32
carbon_trace = {dirty_csv}

[cluster.hydro]
kind = sim
nodes = 2
cpus_per_node = 32
watts_per_cpu = 9.0
carbon_trace = {green_csv}
eco_weekday_windows = 22:00-06:00
""")
cfg = load_config(str(cfg_path))
registry = ClusterRegistry.from_config(cfg)
fed = FederatedBackend(registry)
print(f"federation: {', '.join(registry.names())} "
      f"(default: {registry.default_name})")

# ---------------------------------------------------------------------------
# 2. route a mixed workload: eco sweep + an urgent batch
# ---------------------------------------------------------------------------

now = fed.now  # the lockstep simulated clock (a Wednesday morning)
engine = SubmitEngine(fed, eco=True, coalesce=False, now=now)
sweep = [
    Job(name=f"sweep-{i}", command=f"echo {i}",
        opts=Opts(threads=4, memory_mb=4096, time_s=3600),
        sim_duration_s=1800)
    for i in range(12)
]
result = engine.submit_many(sweep)
print(f"\neco sweep: {len(result.ids)} jobs, {result.eco_deferred} deferred, "
      f"placed on {sorted(result.placements)}")
print("  ids:", " ".join(result.ids[:4]), "...")

urgent_engine = SubmitEngine(fed, eco=False, coalesce=False, now=now)
urgent = urgent_engine.submit_many([
    Job(name=f"urgent-{i}", command="echo now",
        opts=Opts(threads=8, memory_mb=2048, time_s=900),
        sim_duration_s=300)
    for i in range(6)
])
spread: dict = {}
for jid in urgent.ids:
    spread[jid.split(":")[0]] = spread.get(jid.split(":")[0], 0) + 1
print(f"urgent batch: spread by queue wait → {spread}")

# ---------------------------------------------------------------------------
# 3. one federated queue, one cross-cluster wait
# ---------------------------------------------------------------------------

rows = fed.queue()
per_cluster: dict = {}
for r in rows:
    per_cluster.setdefault(r["cluster"], []).append(r["jobid"])
print(f"\nfederated queue: {len(rows)} rows")
for name, ids in sorted(per_cluster.items()):
    print(f"  {name:6s} {len(ids)} job(s)   e.g. {ids[0]}")

done = []
fed.bus.subscribe(lambda e: done.append(e.jobid) if e.is_terminal else None)
fed.run_until_idle()
print(f"after run_until_idle: {len(done)} terminal events "
      f"across both members, queue empty: {not fed.queue()}")

# ---------------------------------------------------------------------------
# 4. accounting close-out: the placement counterfactual
# ---------------------------------------------------------------------------

store = HistoryStore(workdir / "history.jsonl")
model = EnergyModel.from_config(cfg)
n = collect(fed, store, model)
print(f"\narchived {n} records; per-cluster report:\n")
print(render_report(store.records(), by="cluster", color=False))
print("\n(the eco sweep ran on hydro's grid — the placement line above is"
      "\n the carbon it would have cost on the default coal member)")
