"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + gradient path.

Every kernel runs in interpret mode (CPU container); the same pallas_call
lowers to Mosaic on TPU. Tolerances: f32 ≈ 1e-5 absolute; bf16 inputs get
looser bounds (bf16 has ~3 decimal digits)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
given, settings, st = hypothesis.given, hypothesis.settings, hypothesis.strategies

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref, lru_ref, rmsnorm_ref, wkv6_ref
from repro.kernels.rglru_scan import lru_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "B,Hq,Hkv,Sq,Skv,d",
        [
            (1, 2, 2, 64, 64, 32),
            (2, 4, 1, 128, 128, 64),   # GQA 4:1
            (1, 8, 2, 96, 160, 32),    # ragged + GQA
            (1, 2, 2, 33, 65, 16),     # pad-needing odd sizes
            (1, 1, 1, 256, 256, 128),  # MXU-aligned
        ],
    )
    def test_shape_sweep_causal(self, B, Hq, Hkv, Sq, Skv, d):
        q, k, v = rand((B, Hq, Sq, d)), rand((B, Hkv, Skv, d)), rand((B, Hkv, Skv, d))
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(attention_ref(q, k, v, causal=True)),
            atol=2e-5, rtol=1e-4,
        )

    @pytest.mark.parametrize("window", [16, 64])
    def test_local_window(self, window):
        q, k, v = rand((1, 2, 128, 32)), rand((1, 2, 128, 32)), rand((1, 2, 128, 32))
        out = flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)

    def test_non_causal_cross_attention(self):
        q, k, v = rand((2, 2, 40, 32)), rand((2, 2, 100, 32)), rand((2, 2, 100, 32))
        out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)

    def test_logit_cap(self):
        q, k, v = rand((1, 2, 64, 32), scale=4), rand((1, 2, 64, 32), scale=4), rand((1, 2, 64, 32))
        out = flash_attention(q, k, v, logit_cap=30.0, block_q=32, block_k=32)
        ref = attention_ref(q, k, v, logit_cap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)

    def test_bf16(self):
        q = rand((1, 2, 64, 64), jnp.bfloat16)
        k = rand((1, 2, 64, 64), jnp.bfloat16)
        v = rand((1, 2, 64, 64), jnp.bfloat16)
        out = flash_attention(q, k, v)
        ref = attention_ref(q, k, v)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=0.05
        )

    def test_gradient_via_custom_vjp(self):
        """ops.attention(use_pallas=True) must match XLA-path gradients."""
        q, k, v = rand((1, 2, 64, 32)), rand((1, 2, 64, 32)), rand((1, 2, 64, 32))

        def loss_pallas(q, k, v):
            return ops.attention(q, k, v, use_pallas=True).sum()

        def loss_xla(q, k, v):
            return ops.attention(q, k, v, use_pallas=False, kv_chunk=32).sum()

        gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
        gx = jax.grad(loss_xla, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gp, gx):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        sq=st.integers(8, 96), skv=st.integers(8, 96),
        d=st.sampled_from([8, 16, 32]), g=st.sampled_from([1, 2, 4]),
    )
    def test_property_random_shapes(self, sq, skv, d, g):
        q = rand((1, 2 * g, sq, d))
        k = rand((1, 2, skv, d))
        v = rand((1, 2, skv, d))
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=1e-3)


class TestWKV6:
    @pytest.mark.parametrize(
        "B,H,T,dk,dv,chunk",
        [
            (1, 2, 64, 32, 32, 32),
            (2, 2, 128, 64, 64, 64),
            (1, 1, 192, 16, 64, 64),   # dk != dv
            (1, 3, 64, 64, 64, 16),    # small chunks
        ],
    )
    def test_shape_sweep(self, B, H, T, dk, dv, chunk):
        r, k = rand((B, H, T, dk)), rand((B, H, T, dk))
        v = rand((B, H, T, dv))
        w = jnp.asarray(RNG.uniform(0.3, 0.999, (B, H, T, dk)), jnp.float32)
        u = rand((H, dk))
        s0 = rand((B, H, dk, dv))
        y, sf = wkv6_pallas(r, k, v, w, u, s0, chunk=chunk)
        yr, sr = wkv6_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), atol=5e-4, rtol=1e-3)

    def test_chunking_invariance(self):
        """Same answer for any chunk size — the blocking must be invisible."""
        shapes = (1, 2, 128, 32, 32)
        r, k = rand(shapes[:3] + (32,)), rand(shapes[:3] + (32,))
        v = rand((1, 2, 128, 32))
        w = jnp.asarray(RNG.uniform(0.5, 0.99, (1, 2, 128, 32)), jnp.float32)
        u, s0 = rand((2, 32)), rand((1, 2, 32, 32))
        outs = [wkv6_pallas(r, k, v, w, u, s0, chunk=c)[0] for c in (16, 32, 64, 128)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=5e-4)

    def test_matches_model_xla_path(self):
        from repro.models.rwkv6 import wkv6_chunked

        r, k = rand((1, 2, 128, 64)), rand((1, 2, 128, 64))
        v = rand((1, 2, 128, 64))
        w = jnp.asarray(RNG.uniform(0.3, 0.999, (1, 2, 128, 64)), jnp.float32)
        u, s0 = rand((2, 64)), jnp.zeros((1, 2, 64, 64), jnp.float32)
        y_p, s_p = wkv6_pallas(r, k, v, w, u, s0)
        y_x, s_x = wkv6_chunked(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_x), atol=5e-4)
        np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_x), atol=5e-4)

    def test_gradients_match_xla(self):
        r, k = rand((1, 1, 64, 16)), rand((1, 1, 64, 16))
        v = rand((1, 1, 64, 16))
        w = jnp.asarray(RNG.uniform(0.5, 0.99, (1, 1, 64, 16)), jnp.float32)
        u, s0 = rand((1, 16)), jnp.zeros((1, 1, 16, 16), jnp.float32)

        def f(use_pallas):
            def loss(r, k, v, u):
                y, _ = ops.wkv6(r, k, v, w, u, s0, chunk=16, use_pallas=use_pallas)
                return (y**2).sum()

            return jax.grad(loss, argnums=(0, 1, 2, 3))(r, k, v, u)

        for a, b in zip(f(True), f(False)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3)


class TestLRU:
    @pytest.mark.parametrize(
        "B,T,W,chunk,bw",
        [(1, 64, 128, 32, 128), (2, 128, 256, 64, 64), (1, 256, 64, 128, 64)],
    )
    def test_shape_sweep(self, B, T, W, chunk, bw):
        a = jnp.asarray(RNG.uniform(0.2, 0.999, (B, T, W)), jnp.float32)
        b = rand((B, T, W), scale=0.3)
        h0 = rand((B, W))
        y, hf = lru_pallas(a, b, h0, chunk=chunk, block_w=bw)
        yr, hr = lru_ref(a, b, h0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), atol=1e-5, rtol=1e-5)

    def test_xla_associative_scan_matches(self):
        a = jnp.asarray(RNG.uniform(0.2, 0.999, (2, 64, 32)), jnp.float32)
        b = rand((2, 64, 32), scale=0.3)
        h0 = rand((2, 32))
        y_p, h_p = ops.lru_scan(a, b, h0, use_pallas=True)
        y_x, h_x = ops.lru_scan(a, b, h0, use_pallas=False)
        np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_x), atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_x), atol=1e-5)

    def test_gradients_match(self):
        a = jnp.asarray(RNG.uniform(0.3, 0.99, (1, 32, 16)), jnp.float32)
        b = rand((1, 32, 16), scale=0.3)
        h0 = rand((1, 16))

        def mk(use_pallas):
            def loss(a, b, h0):
                y, hf = ops.lru_scan(a, b, h0, use_pallas=use_pallas)
                return (y**2).sum() + (hf**2).sum()

            return jax.grad(loss, argnums=(0, 1, 2))(a, b, h0)

        for g1, g2 in zip(mk(True), mk(False)):
            np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


class TestRMSNorm:
    @pytest.mark.parametrize(
        "shape,dtype",
        [
            ((64, 768), jnp.float32),
            ((7, 33), jnp.float32),        # ragged rows/width
            ((4, 16, 256), jnp.float32),   # 3-D input
            ((128, 512), jnp.bfloat16),
        ],
    )
    def test_sweep(self, shape, dtype):
        x = rand(shape, dtype)
        w = rand(shape[-1:], dtype)
        out = rmsnorm_pallas(x, w, block_rows=16)
        ref = rmsnorm_ref(x, w)
        atol = 1e-5 if dtype == jnp.float32 else 0.05
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
        )

    def test_gradients_match(self):
        x, w = rand((8, 64)), rand((64,))

        def mk(use_pallas):
            return jax.grad(
                lambda x, w: (ops.rmsnorm(x, w, use_pallas=use_pallas) ** 2).sum(),
                argnums=(0, 1),
            )(x, w)

        for a, b in zip(mk(True), mk(False)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


class TestMoEGating:
    @pytest.mark.parametrize(
        "G,N,E,k,cap",
        [(2, 64, 16, 2, 12), (1, 128, 32, 4, 20), (3, 32, 8, 1, 5)],
    )
    def test_vs_oracle(self, G, N, E, k, cap):
        from repro.kernels.moe_gating import moe_gating_pallas
        from repro.kernels.ref import moe_gating_ref

        logits = rand((G, N, E))
        ip, gp, pp = moe_gating_pallas(logits, top_k=k, capacity=cap)
        ir, gr, pr = moe_gating_ref(logits, top_k=k, capacity=cap)
        np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(pp), np.asarray(pr))
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), atol=1e-6)

    def test_matches_model_routing(self):
        """dispatch/combine rebuilt from (idx, gate, pos) == top_k_routing."""
        from repro.configs import get_smoke_config
        from repro.kernels.moe_gating import moe_gating_pallas
        from repro.models.moe import top_k_routing

        cfg = get_smoke_config("deepseek-moe-16b").replace(n_experts=16, top_k=3)
        logits = rand((2, 64, 16))
        cap = 16
        dispatch, combine, _ = top_k_routing(logits, cfg, cap)
        ip, gp, pp = moe_gating_pallas(
            jax.nn.log_softmax(logits), top_k=3, capacity=cap
        )
        d2 = np.zeros(dispatch.shape, bool)
        c2 = np.zeros(combine.shape, np.float32)
        ipn, gpn, ppn = map(np.asarray, (ip, gp, pp))
        for g in range(2):
            for n in range(64):
                for j in range(3):
                    if ppn[g, n, j] >= 0:
                        d2[g, n, ipn[g, n, j], ppn[g, n, j]] = True
                        c2[g, n, ipn[g, n, j], ppn[g, n, j]] += gpn[g, n, j]
        np.testing.assert_array_equal(np.asarray(dispatch), d2)
        np.testing.assert_allclose(np.asarray(combine), c2, atol=1e-5)

    def test_drops_marked_minus_one(self):
        from repro.kernels.moe_gating import moe_gating_pallas

        # everyone wants expert 0 → only `cap` survive at rank 0
        logits = jnp.zeros((1, 32, 4)).at[:, :, 0].set(10.0)
        _, _, pos = moe_gating_pallas(logits, top_k=1, capacity=5)
        p = np.asarray(pos)[0, :, 0]
        assert (p >= 0).sum() == 5
        assert np.array_equal(np.sort(p[p >= 0]), np.arange(5))
