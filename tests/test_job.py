"""NBI::Job — sbatch script generation, arrays, submission (paper §Job)."""

import pytest

from repro.core import FILE_PLACEHOLDER, Job, Opts


class TestScript:
    def test_paper_assembly_script(self):
        job = Job(
            name="assembly",
            command="flye --nano-raw reads.fastq --out-dir asm",
            opts=Opts.new(threads=18, memory="64GB", time=12, output_dir="./logs/"),
        )
        s = job.script()
        assert s.startswith("#!/bin/bash\n")
        assert "#SBATCH --cpus-per-task=18" in s
        assert "#SBATCH --mem=65536" in s
        assert "#SBATCH --time=0-12:00:00" in s
        assert "flye --nano-raw reads.fastq --out-dir asm" in s
        assert "set -euo pipefail" in s

    def test_multiple_commands(self):
        job = Job(name="multi", command=["echo a", "echo b"])
        body = job.script().split("set -euo pipefail")[1]
        assert body.index("echo a") < body.index("echo b")

    def test_no_command_raises(self):
        with pytest.raises(ValueError):
            Job(name="x").script()

    def test_add_command_chainable(self):
        job = Job(name="x", command="echo 1").add_command("echo 2")
        assert "echo 2" in job.script()

    def test_workdir_cd(self):
        job = Job(name="x", command="pwd", workdir="/data/run1")
        assert "cd /data/run1" in job.script()

    def test_name_sanitised(self):
        assert Job(name="my job!!").name == "my_job"
        assert Job(name="  ").name == "job"


class TestArrays:
    def test_paper_array_example(self, tmp_path):
        """runjob --files samples.txt 'bwa mem ref.fa #FILE# > #FILE#.bam'"""
        listing = tmp_path / "samples.txt"
        listing.write_text("a.fq\nb.fq\n# comment\n\nc.fq\n")
        job = Job(
            name="align",
            command=f"bwa mem ref.fa {FILE_PLACEHOLDER} > {FILE_PLACEHOLDER}.bam",
            opts=Opts.new(threads=8, memory="16GB", time="4h"),
            files=str(listing),
        )
        s = job.script()
        assert job.files == ["a.fq", "b.fq", "c.fq"]
        assert "#SBATCH --array=0-2" in s
        assert 'FILE="${NBI_FILES[$SLURM_ARRAY_TASK_ID]}"' in s
        assert 'bwa mem ref.fa "$FILE" > "$FILE".bam' in s

    def test_files_as_list(self):
        job = Job(name="x", command="cat #FILE#", files=["f1", "f 2"])
        s = job.script()
        assert "NBI_FILES=(f1 'f 2')" in s

    def test_array_sim_execution(self, sim):
        job = Job(name="arr", command="echo #FILE#", files=["a", "b", "c"],
                  opts=Opts.new(threads=1, memory="1GB", time="1h"))
        base = job.run(sim)
        assert sim.states_of(base) == ["PENDING"] * 3 or all(
            s in ("PENDING", "RUNNING") for s in sim.states_of(base)
        )
        sim.run_until_idle()
        assert sim.states_of(base) == ["COMPLETED"] * 3


class TestSubmission:
    def test_run_returns_id_and_writes_script(self, sim, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "s"))
        job = Job(name="j", command="true", opts=Opts.new())
        jid = job.run(sim)
        assert isinstance(jid, int)
        assert job.script_path and job.script_path.endswith(".sh")
        with open(job.script_path) as fh:
            assert "true" in fh.read()

    def test_dependencies_render(self, sim):
        j1 = Job(name="a", command="true")
        id1 = j1.run(sim)
        j2 = Job(name="b", command="true")
        j2.set_dependencies(id1)
        assert f"afterok:{id1}" in j2.script()
