"""MoE dispatch invariants: top-k routing, capacity, load-balance loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
given, settings, st = hypothesis.given, hypothesis.settings, hypothesis.strategies

from repro.configs import get_smoke_config
from repro.models.config import ArchConfig
from repro.models.moe import capacity, moe_ffn, top_k_routing


def mini_cfg(**kw):
    base = get_smoke_config("deepseek-moe-16b")
    return base.replace(**kw) if kw else base


class TestRouting:
    def _route(self, G=1, N=16, E=8, k=2, cf=1.25, seed=0):
        cfg = mini_cfg(n_experts=E, top_k=k, capacity_factor=cf)
        cap = capacity(cfg, N)
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(rng.standard_normal((G, N, E)), jnp.float32)
        dispatch, combine, aux = top_k_routing(logits, cfg, cap)
        return cfg, cap, dispatch, combine, aux

    def test_each_slot_holds_one_token(self):
        _, cap, dispatch, _, _ = self._route()
        per_slot = np.asarray(dispatch).sum(axis=1)  # (G,E,C)
        assert per_slot.max() <= 1

    def test_token_routed_at_most_k_times(self):
        cfg, _, dispatch, _, _ = self._route()
        per_token = np.asarray(dispatch).sum(axis=(2, 3))  # (G,N)
        assert per_token.max() <= cfg.top_k

    def test_combine_weights_normalised(self):
        """Kept tokens' gate weights sum ≤ 1 (DeepSeek renormalisation)."""
        _, _, dispatch, combine, _ = self._route(cf=8.0)  # no drops
        w = np.asarray(combine).sum(axis=(2, 3))
        np.testing.assert_allclose(w, 1.0, atol=1e-5)

    def test_capacity_drops_excess(self):
        # adversarial: all tokens want expert 0
        cfg = mini_cfg(n_experts=4, top_k=1, capacity_factor=1.0)
        N = 16
        cap = capacity(cfg, N)
        logits = jnp.zeros((1, N, 4)).at[:, :, 0].set(10.0)
        dispatch, _, _ = top_k_routing(logits, cfg, cap)
        kept = np.asarray(dispatch)[0, :, 0].sum()
        assert kept == cap  # exactly capacity survive, rest dropped

    def test_aux_loss_uniform_low_skewed_high(self):
        cfg = mini_cfg(n_experts=8, top_k=2, capacity_factor=8.0)
        rng = np.random.default_rng(0)
        uniform = jnp.asarray(rng.standard_normal((1, 256, 8)) * 0.01, jnp.float32)
        skewed = uniform.at[:, :, 0].add(8.0)
        cap = capacity(cfg, 256)
        _, _, aux_u = top_k_routing(uniform, cfg, cap)
        _, _, aux_s = top_k_routing(skewed, cfg, cap)
        assert float(aux_s) > float(aux_u)
        # uniform: f_e ≈ k/E, p_e ≈ 1/E → aux = E·Σ f·p ≈ k
        assert float(aux_u) == pytest.approx(cfg.top_k, rel=0.1)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.sampled_from([8, 16, 32]),
        e=st.sampled_from([4, 8]),
        k=st.integers(1, 3),
        seed=st.integers(0, 100),
    )
    def test_property_dispatch_within_bounds(self, n, e, k, seed):
        cfg = mini_cfg(n_experts=e, top_k=k)
        cap = capacity(cfg, n)
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(rng.standard_normal((2, n, e)), jnp.float32)
        dispatch, combine, aux = top_k_routing(logits, cfg, cap)
        d = np.asarray(dispatch)
        assert d.sum(axis=1).max() <= 1  # slot exclusive
        assert d.sum(axis=(2, 3)).max() <= k
        assert np.asarray(combine).min() >= 0
        assert np.isfinite(float(aux))


class TestMoEFFN:
    def test_shared_experts_always_active(self):
        """With capacity 0ish routing (all dropped), shared experts still
        contribute — outputs differ from zero."""
        cfg = mini_cfg(capacity_factor=8.0)
        from repro.models.moe import moe_param_defs
        from repro.models.common import init_params

        params = init_params(moe_param_defs(cfg), jax.random.PRNGKey(0))
        layer = jax.tree_util.tree_map(lambda a: a[0], params["moe_blocks"])
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal((1, 32, cfg.d_model)),
            jnp.float32,
        )
        y, aux = moe_ffn(layer["moe"], x, cfg)
        assert y.shape == x.shape
        assert float(jnp.abs(y).max()) > 0
        assert np.isfinite(float(aux))

    def test_dropless_ffn_equals_dense_expert_sum(self):
        """With cf high enough for zero drops, the dispatch einsum must equal
        explicitly evaluating each token through its top-k experts."""
        cfg = mini_cfg(capacity_factor=16.0, n_shared_experts=0)
        from repro.models.moe import moe_param_defs
        from repro.models.common import init_params

        params = init_params(moe_param_defs(cfg), jax.random.PRNGKey(1))
        layer = jax.tree_util.tree_map(lambda a: a[0], params["moe_blocks"])["moe"]
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)
        y, _ = moe_ffn(layer, x, cfg)

        # naive oracle
        logits = np.asarray(
            jnp.einsum("bsd,de->bse", x, layer["router"].astype(jnp.float32))
        )
        probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
        vals, idx = jax.lax.top_k(probs, cfg.top_k)
        vals = vals / vals.sum(-1, keepdims=True)
        want = np.zeros_like(np.asarray(x))
        for b in range(1):
            for s in range(16):
                for j in range(cfg.top_k):
                    e = int(idx[b, s, j])
                    xin = np.asarray(x[b, s])
                    g = np.asarray(layer["wg"])[e].T @ xin
                    h = np.asarray(layer["wi"])[e].T @ xin
                    act = (g / (1 + np.exp(-g))) * h
                    want[b, s] += float(vals[b, s, j]) * (
                        np.asarray(layer["wo"])[e].T @ act
                    )
        np.testing.assert_allclose(np.asarray(y), want, atol=2e-4)
