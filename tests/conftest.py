"""Shared fixtures: isolated config, fresh simulator, tiny-jax knobs.

IMPORTANT: no XLA_FLAGS here — smoke tests and benches must see the 1 real
CPU device (the 512-device override belongs ONLY to repro.launch.dryrun).
"""

import os
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


@pytest.fixture(autouse=True)
def _isolated_env(tmp_path, monkeypatch):
    """Every test gets default config + simulator backend + tmp scriptdir."""
    monkeypatch.setenv("NBISLURM_CONFIG", str(tmp_path / "nbislurm.config"))
    monkeypatch.setenv("REPRO_BACKEND", "sim")
    monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "scripts"))
    monkeypatch.setenv("NBI_HISTORY", str(tmp_path / "history.jsonl"))
    monkeypatch.setenv("REPRO_DISABLE_DISTRIBUTED", "1")
    monkeypatch.delenv("KRAKEN2_DB", raising=False)
    from repro.core import reset_shared_sim

    reset_shared_sim()
    yield
    reset_shared_sim()


@pytest.fixture
def sim():
    from repro.core import SimCluster

    return SimCluster(default_user="testuser")


@pytest.fixture
def exec_sim():
    from repro.core import SimCluster

    return SimCluster(default_user="testuser", execute=True)
