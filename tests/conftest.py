"""Shared fixtures: isolated config, fresh simulator, tiny-jax knobs.

IMPORTANT: no XLA_FLAGS here — smoke tests and benches must see the 1 real
CPU device (the 512-device override belongs ONLY to repro.launch.dryrun).
"""

import os
import signal
import sys
import threading
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# Per-test wall-clock timeout (seconds). An event-wait bug — a waitjobs
# loop whose terminal event never fires, an advance() that stops making
# progress — must fail the one test promptly instead of hanging the whole
# CI job. pytest-timeout is not in the platform image, so this is a plain
# SIGALRM watchdog (POSIX main thread only; a no-op elsewhere).
TEST_TIMEOUT_S = int(os.environ.get("NBI_TEST_TIMEOUT_S", "300"))


@pytest.fixture(autouse=True)
def _per_test_timeout(request):
    if (
        TEST_TIMEOUT_S <= 0
        or not hasattr(signal, "SIGALRM")
        or threading.current_thread() is not threading.main_thread()
    ):
        yield
        return

    # slow-marked tests (opt-in full-scale stress runs) get a much wider
    # budget: they assert their own wall-clock bounds internally
    budget = TEST_TIMEOUT_S * (8 if request.node.get_closest_marker("slow") else 1)

    def _timed_out(signum, frame):
        pytest.fail(
            f"test exceeded {budget}s (NBI_TEST_TIMEOUT_S={TEST_TIMEOUT_S}) "
            f"({request.node.nodeid})", pytrace=False,
        )

    old = signal.signal(signal.SIGALRM, _timed_out)
    signal.setitimer(signal.ITIMER_REAL, budget)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(autouse=True)
def _isolated_env(tmp_path, monkeypatch):
    """Every test gets default config + simulator backend + tmp scriptdir."""
    monkeypatch.setenv("NBISLURM_CONFIG", str(tmp_path / "nbislurm.config"))
    monkeypatch.setenv("REPRO_BACKEND", "sim")
    monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "scripts"))
    monkeypatch.setenv("NBI_HISTORY", str(tmp_path / "history.jsonl"))
    monkeypatch.setenv("REPRO_DISABLE_DISTRIBUTED", "1")
    monkeypatch.delenv("KRAKEN2_DB", raising=False)
    from repro.core import reset_shared_sim

    reset_shared_sim()
    yield
    reset_shared_sim()


@pytest.fixture
def sim():
    from repro.core import SimCluster

    return SimCluster(default_user="testuser")


@pytest.fixture
def exec_sim():
    from repro.core import SimCluster

    return SimCluster(default_user="testuser", execute=True)
