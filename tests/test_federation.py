"""Multi-cluster federation: registry, routing, placement, and the
single-cluster bit-identity pin.

Covers the PR-5 tentpole end to end — ``[cluster.<name>]`` stanzas →
ClusterRegistry → FederatedBackend (namespaced ids, aggregated events) →
Placer (greenest-feasible vs fastest) → SubmitEngine placement stage →
per-cluster EcoController — plus the ``get_backend`` selection satellite.
"""

from datetime import datetime, timedelta

import pytest

from repro.core import (
    ClusterHandle,
    ClusterRegistry,
    EcoController,
    EcoScheduler,
    FederatedBackend,
    Job,
    Opts,
    Placer,
    Queue,
    SimCluster,
    SimNode,
    SubmitEngine,
    get_backend,
    join_cluster_id,
    reset_shared_sim,
    split_cluster_id,
)
from repro.core.config import load_config
from repro.core.eco import CarbonTrace

T0 = datetime(2026, 3, 18, 10, 0, 0)  # a Wednesday morning


def flat_trace(gco2: float) -> CarbonTrace:
    return CarbonTrace([float(gco2)] * 168)


def make_handle(name, intensity=None, *, nodes=2, cpus=8, mem=32768,
                windows="00:00-06:00"):
    """A sim-backed member with an optional flat carbon trace."""
    trace = flat_trace(intensity) if intensity is not None else None
    sched = EcoScheduler(
        weekday_windows=[(0, 360)] if windows else [],
        weekend_windows=[(0, 360)] if windows else [],
        peak_hours=[(1020, 1200)],
        horizon_days=7,
        min_delay_s=0,
        carbon_trace=trace,
    )
    backend = SimCluster(
        nodes=[SimNode(f"{name}-n{i}", cpus=cpus, memory_mb=mem)
               for i in range(nodes)],
        now=T0,
        default_user="testuser",
        name=name,
    )
    return ClusterHandle(
        name=name, kind="sim", backend=backend, carbon_trace=trace,
        scheduler=sched, nodes=nodes, cpus_per_node=cpus,
        memory_mb_per_node=mem,
    )


def make_fed(*specs, default="", tracker=True):
    """specs: (name, intensity) pairs → a two-plus-member federation."""
    reg = ClusterRegistry([make_handle(n, i) for n, i in specs], default=default)
    return FederatedBackend(reg, tracker=tracker)


def job(name="j", cpus=1, mem=1024, time_s=1800, duration=60, **kw):
    return Job(name=name, command="echo hi",
               opts=Opts(threads=cpus, memory_mb=mem, time_s=time_s),
               sim_duration_s=duration, **kw)


# ---------------------------------------------------------------------------
# Namespaced ids
# ---------------------------------------------------------------------------


class TestClusterIds:
    def test_round_trip(self):
        assert split_cluster_id(join_cluster_id("green", "123_4")) == ("green", "123_4")

    def test_bare_id_passthrough(self):
        assert split_cluster_id("1000001") == ("", "1000001")
        assert join_cluster_id("", 1000001) == "1000001"

    def test_int_ids_accepted(self):
        assert join_cluster_id("green", 123) == "green:123"
        assert split_cluster_id(123) == ("", "123")


# ---------------------------------------------------------------------------
# Config stanzas → registry
# ---------------------------------------------------------------------------


class TestConfigStanzas:
    def _write(self, tmp_path, monkeypatch, text):
        p = tmp_path / "cfg"
        p.write_text(text)
        monkeypatch.setenv("NBISLURM_CONFIG", str(p))
        return load_config()

    def test_stanza_keys_flattened(self, tmp_path, monkeypatch):
        cfg = self._write(tmp_path, monkeypatch, (
            "economy_mode=1\n"
            "[cluster.green]\nkind=sim\nnodes=8\n"
            "[cluster.dirty]\nkind=sim\n"
        ))
        assert cfg.get("economy_mode") == "1"
        assert cfg.cluster_names() == ["green", "dirty"]
        assert cfg.cluster_section("green") == {"kind": "sim", "nodes": "8"}

    def test_no_stanzas_parses_exactly_as_before(self, tmp_path, monkeypatch):
        cfg = self._write(tmp_path, monkeypatch, "queue=short\n")
        assert cfg.cluster_names() == []
        assert cfg.get("queue") == "short"

    def test_registry_from_config_heterogeneous(self, tmp_path, monkeypatch):
        trace = tmp_path / "t.csv"
        trace.write_text("\n".join(f"{h},75" for h in range(168)))
        cfg = self._write(tmp_path, monkeypatch, (
            f"[cluster.big]\nkind=sim\nnodes=8\ncpus_per_node=128\n"
            f"watts_per_cpu=9.5\ncarbon_trace={trace}\n"
            "[cluster.small]\nkind=sim\nnodes=1\ncpus_per_node=4\n"
        ))
        reg = ClusterRegistry.from_config(cfg)
        big, small = reg.get("big"), reg.get("small")
        assert big.total_cpus == 8 * 128
        assert big.watts_per_cpu == 9.5
        assert big.carbon_trace is not None
        assert big.backend.watts_per_cpu == 9.5  # TDP flows into the sim
        assert [n.cpus for n in small.backend.nodes] == [4]
        assert reg.default_name == "big"  # first declared

    def test_registry_default_cluster_key(self, tmp_path, monkeypatch):
        cfg = self._write(tmp_path, monkeypatch, (
            "default_cluster=b\n[cluster.a]\nkind=sim\n[cluster.b]\nkind=sim\n"
        ))
        assert ClusterRegistry.from_config(cfg).default_name == "b"

    def test_registry_unknown_kind_raises(self, tmp_path, monkeypatch):
        cfg = self._write(tmp_path, monkeypatch,
                          "[cluster.x]\nkind=warp\n")
        with pytest.raises(ValueError, match="warp"):
            ClusterRegistry.from_config(cfg)

    def test_registry_bad_default_raises(self):
        with pytest.raises(ValueError, match="default_cluster"):
            ClusterRegistry([make_handle("a")], default="nope")

    def test_registry_no_stanzas_raises(self):
        with pytest.raises(ValueError, match="cluster"):
            ClusterRegistry.from_config(load_config())

    def test_per_cluster_eco_window_override(self, tmp_path, monkeypatch):
        cfg = self._write(tmp_path, monkeypatch, (
            "eco_weekday_windows=00:00-06:00\n"
            "[cluster.n]\nkind=sim\neco_weekday_windows=01:00-03:00\n"
            "[cluster.d]\nkind=sim\n"
        ))
        reg = ClusterRegistry.from_config(cfg)
        assert reg.get("n").scheduler.weekday_windows == [(60, 180)]
        assert reg.get("d").scheduler.weekday_windows == [(0, 360)]


# ---------------------------------------------------------------------------
# get_backend selection (satellite)
# ---------------------------------------------------------------------------


class TestBackendSelection:
    def test_unknown_env_kind_raises_naming_valid_kinds(self, monkeypatch):
        monkeypatch.setenv("REPRO_BACKEND", "slrum")  # the classic typo
        with pytest.raises(ValueError) as e:
            get_backend()
        msg = str(e.value)
        assert "slrum" in msg
        for kind in ("slurm", "sim", "federated"):
            assert kind in msg

    def test_unknown_argument_kind_raises(self):
        with pytest.raises(ValueError, match="'bogus'"):
            get_backend("bogus")

    def test_argument_beats_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_BACKEND", "bogus")
        assert isinstance(get_backend("sim"), SimCluster)

    def test_sim_selected_by_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_BACKEND", "sim")
        be = get_backend()
        assert isinstance(be, SimCluster)
        assert get_backend() is be  # shared instance

    def test_federated_kind_without_stanzas_is_a_clear_error(self, monkeypatch):
        with pytest.raises(ValueError, match=r"\[cluster\.<name>\]"):
            get_backend("federated")

    def test_stanzas_resolve_to_federation_by_default(self, tmp_path, monkeypatch):
        p = tmp_path / "cfg"
        p.write_text("[cluster.a]\nkind=sim\n[cluster.b]\nkind=sim\n")
        monkeypatch.setenv("NBISLURM_CONFIG", str(p))
        monkeypatch.delenv("REPRO_BACKEND", raising=False)
        reset_shared_sim()
        be = get_backend()
        assert isinstance(be, FederatedBackend)
        assert be.names() == ["a", "b"]
        assert get_backend() is be  # cached per config contents
        assert get_backend("federated") is be


# ---------------------------------------------------------------------------
# FederatedBackend mechanics
# ---------------------------------------------------------------------------


class TestFederatedBackend:
    def test_submit_namespaces_and_routes_pin(self):
        fed = make_fed(("a", 300), ("b", 100))
        j = job()
        j.cluster = "b"
        jid = fed.submit(j)
        assert jid == "b:1000001"
        assert fed.registry.get("b").backend.get("1000001") is not None
        assert fed.registry.get("a").backend.get("1000001") is None

    def test_unknown_pin_raises_naming_members(self):
        fed = make_fed(("a", None), ("b", None))
        j = job()
        j.cluster = "zz"
        with pytest.raises(KeyError, match="a, b"):
            fed.submit(j)

    def test_queue_rows_cluster_tagged_no_loss_or_double_count(self):
        fed = make_fed(("a", None), ("b", None))
        ids = []
        for name in ("a", "b", "a"):
            jx = job(name=f"on-{name}")
            jx.cluster = name
            ids.append(fed.submit(jx))
        rows = fed.queue()
        assert sorted(r["jobid"] for r in rows) == sorted(ids)
        assert len(set(r["jobid"] for r in rows)) == 3  # never double-counted
        by_cluster = {r["jobid"]: r["cluster"] for r in rows}
        assert by_cluster["a:1000001"] == "a"
        assert by_cluster["b:1000001"] == "b"

    def test_cancel_routes_by_prefix(self):
        fed = make_fed(("a", None), ("b", None))
        for name in ("a", "b"):
            jx = job()
            jx.cluster = name
            fed.submit(jx)
        fed.cancel(["a:1000001"])
        assert fed.registry.get("a").backend.get("1000001").state == "CANCELLED"
        # same bare id on the other member must be untouched
        assert fed.registry.get("b").backend.get("1000001").state != "CANCELLED"

    def test_get_resolves_namespaced_copy(self):
        fed = make_fed(("a", None), ("b", None))
        jx = job()
        jx.cluster = "b"
        fed.submit(jx)
        got = fed.get("b:1000001")
        assert got.jobid == "b:1000001" and got.cluster == "b"
        # the member's own record is never mutated
        assert fed.registry.get("b").backend.get("1000001").jobid == "1000001"

    def test_accounting_fans_out_cluster_tagged(self):
        fed = make_fed(("a", None), ("b", None))
        for name in ("a", "b"):
            jx = job()
            jx.cluster = name
            fed.submit(jx)
        fed.run_until_idle()
        rows = fed.accounting()
        assert sorted((r.jobid, r.cluster) for r in rows) == [
            ("a:1000001", "a"), ("b:1000001", "b"),
        ]
        assert all(r.state == "COMPLETED" for r in rows)

    def test_events_reemitted_namespaced_and_cluster_tagged(self):
        fed = make_fed(("a", None), ("b", None))
        seen = []
        fed.bus.subscribe(lambda e: seen.append((e.type, e.jobid, e.cluster)))
        jx = job()
        jx.cluster = "b"
        fed.submit(jx)
        fed.run_until_idle()
        assert ("SUBMITTED", "b:1000001", "b") in seen
        assert ("COMPLETED", "b:1000001", "b") in seen

    def test_advance_moves_members_in_lockstep(self):
        fed = make_fed(("a", None), ("b", None))
        fed.advance(3600)
        clocks = {h.backend.now for h in fed.registry}
        assert clocks == {T0 + timedelta(seconds=3600)}

    def test_submit_many_batches_per_member_in_input_order(self):
        fed = make_fed(("a", None), ("b", None))
        jobs = []
        for i, name in enumerate(("a", "b", "a", "b")):
            jx = job(name=f"j{i}")
            jx.cluster = name
            jobs.append(jx)
        ids = fed.submit_many(j.prepare() for j in jobs)
        assert ids == ["a:1000001", "b:1000001", "a:1000002", "b:1000002"]


# ---------------------------------------------------------------------------
# Placer
# ---------------------------------------------------------------------------


class TestPlacer:
    def test_eco_jobs_go_to_greenest_feasible(self):
        fed = make_fed(("dirty", 600), ("green", 50))
        placement = fed.placer.place(job(), T0, eco=True)
        assert placement.cluster == "green"
        assert placement.carbon_gco2_kwh == pytest.approx(50.0)
        assert {c[0] for c in placement.candidates} == {"dirty", "green"}

    def test_urgent_jobs_go_to_fastest(self):
        fed = make_fed(("dirty", 600), ("green", 50))
        # pile work on green: its backlog makes dirty the faster choice
        for _ in range(6):
            jx = job(cpus=8, time_s=7200)
            jx.cluster = "green"
            fed.submit(jx.prepare())
        placement = fed.placer.place(job(), T0, eco=False)
        assert placement.cluster == "dirty"
        eco_placement = fed.placer.place(job(), T0, eco=True)
        assert eco_placement.cluster == "green"  # eco still prefers green

    def test_infeasible_cluster_never_chosen(self):
        # green's nodes are too small for this job, despite better carbon
        reg = ClusterRegistry([
            make_handle("dirty", 600, cpus=64),
            make_handle("green", 50, cpus=4),
        ])
        fed = FederatedBackend(reg)
        placement = fed.placer.place(job(cpus=16), T0, eco=True)
        assert placement.cluster == "dirty"
        assert [c[0] for c in placement.candidates] == ["dirty"]

    def test_nothing_fits_falls_back_to_all_members(self):
        reg = ClusterRegistry([make_handle("a", None, cpus=2),
                               make_handle("b", None, cpus=2)])
        placement = Placer(reg).place_spec(64, 1024, 3600, T0)
        assert placement.cluster in ("a", "b")  # queued, never dropped

    def test_tie_breaks_deterministically_by_name(self):
        fed = make_fed(("zeta", 100), ("alpha", 100))
        assert fed.placer.place(job(), T0, eco=True).cluster == "alpha"

    def test_predictor_shrinks_backlog_estimate(self):
        handle = make_handle("a", None)

        class TinyPredictor:
            def predict(self, default_s, *, name="", user="", tool=""):
                return 60

        jx = job(cpus=8, time_s=7200)
        jx.cluster = "a"
        FederatedBackend(ClusterRegistry([handle])).submit(jx.prepare())
        raw = Placer(ClusterRegistry([make_handle("a", None)]))
        wait_pred = Placer(ClusterRegistry([handle]),
                           predictor=TinyPredictor()).queue_wait_s(handle)
        # the running job's remaining time is observed, not predicted, so
        # just sanity-check the estimate is finite and nonnegative
        assert wait_pred >= 0.0
        assert raw is not None


# ---------------------------------------------------------------------------
# SubmitEngine placement stage + per-cluster eco pricing
# ---------------------------------------------------------------------------


class TestEngineFederation:
    def test_engine_routes_eco_batch_to_green(self):
        fed = make_fed(("dirty", 600), ("green", 50))
        engine = SubmitEngine(fed, eco=True, coalesce=False, now=T0)
        result = engine.submit_many([job(name=f"j{i}") for i in range(5)])
        assert result.placements == {"green"}
        assert all(i.startswith("green:") for i in result.ids)
        assert result.eco_deferred == 5

    def test_engine_prices_through_member_scheduler(self):
        # green's eco window opens at 01:00, dirty's at 00:00 — the begin
        # directive must come from the PLACED member's windows
        h_green = make_handle("green", 50)
        h_green.scheduler = EcoScheduler(
            weekday_windows=[(60, 360)], weekend_windows=[(60, 360)],
            peak_hours=[], horizon_days=7, min_delay_s=0,
            carbon_trace=flat_trace(50),
        )
        fed = FederatedBackend(ClusterRegistry([make_handle("dirty", 600), h_green]))
        engine = SubmitEngine(fed, eco=True, coalesce=False, now=T0)
        engine.submit_many([job()])
        sim_job = fed.registry.get("green").backend.get("1000001")
        assert sim_job is not None
        assert sim_job.begin == datetime(2026, 3, 19, 1, 0)

    def test_engine_coalesced_array_lands_on_one_cluster(self):
        fed = make_fed(("dirty", 600), ("green", 50))
        engine = SubmitEngine(fed, eco=True, coalesce=True, now=T0)
        result = engine.submit_many([job(name="sweep") for _ in range(8)])
        assert result.sbatch_calls == 1
        assert result.coalesced == 8
        assert len({i.split(":")[0] for i in result.ids}) == 1
        assert result.ids[3] == "green:1000001_3"

    def test_states_tracks_namespaced_ids(self):
        fed = make_fed(("a", None), ("b", None))
        engine = SubmitEngine(fed, coalesce=False)
        result = engine.submit_many([job(name=f"j{i}") for i in range(4)])
        states = engine.states(result)
        assert set(states) == set(result.ids)
        fed.run_until_idle()
        assert set(engine.states(result).values()) == {"COMPLETED"}

    def test_queue_tools_see_federated_rows(self):
        fed = make_fed(("a", None), ("b", None))
        SubmitEngine(fed, coalesce=False).submit_many(
            [job(name=f"j{i}") for i in range(4)]
        )
        q = Queue(backend=fed)
        assert len(q) == 4
        assert {j.cluster for j in q} <= {"a", "b"}
        assert all(j.jobid_num == j.jobid_num for j in q)
        assert all(j.jobid_num >= 1000001 for j in q)


# ---------------------------------------------------------------------------
# Property pin: one configured cluster ⇒ bit-identical decisions
# ---------------------------------------------------------------------------


class TestSingleClusterPin:
    """With exactly one member, engine decisions (tier, begin, deferral)
    and the member's event stream are bit-identical to a plain SimCluster
    run — federation only namespaces the ids at the boundary."""

    WINDOWS = dict(
        weekday_windows=[(0, 360)], weekend_windows=[(0, 420)],
        peak_hours=[(1020, 1200)], horizon_days=7, min_delay_s=0,
    )

    def _submit(self, backend, scheduler, n=6):
        engine = SubmitEngine(backend, eco=True, coalesce=False, now=T0,
                              scheduler=scheduler)
        jobs = [job(name=f"j{i}", time_s=1800 * (1 + i % 3)) for i in range(n)]
        return engine.submit_many(jobs), jobs

    def test_decisions_and_events_bit_identical(self):
        # plain single-cluster stack
        plain = SimCluster(
            nodes=[SimNode(f"p-n{i}", cpus=8, memory_mb=32768) for i in range(2)],
            now=T0, default_user="testuser",
        )
        plain_events = []
        plain.bus.subscribe(lambda e: plain_events.append(
            (e.type, e.jobid, e.at, e.state, e.reason)))
        res_plain, jobs_plain = self._submit(
            plain, EcoScheduler(**self.WINDOWS))

        # one-member federation, same windows, no carbon trace
        handle = make_handle("only", None)
        handle.scheduler = EcoScheduler(**self.WINDOWS)
        fed = FederatedBackend(ClusterRegistry([handle]))
        fed_events = []
        fed.bus.subscribe(lambda e: fed_events.append(
            (e.type, split_cluster_id(e.jobid)[1], e.at, e.state, e.reason)))
        res_fed, jobs_fed = self._submit(fed, None)  # per-member scheduler

        # identical eco pricing...
        assert res_fed.eco_deferred == res_plain.eco_deferred
        for jp, jf in zip(jobs_plain, jobs_fed):
            assert jf.opts.begin == jp.opts.begin
            assert jf.eco_meta == jp.eco_meta
        # ...identical ids modulo the cluster prefix...
        assert [split_cluster_id(i)[1] for i in res_fed.ids] == res_plain.ids
        # ...and, after running both to completion, identical event streams
        plain.run_until_idle()
        fed.run_until_idle()
        assert fed_events == plain_events

    def test_single_member_accounting_matches_plain(self):
        handle = make_handle("only", None)
        fed = FederatedBackend(ClusterRegistry([handle]))
        jx = job()
        fed.submit(jx.prepare())
        fed.run_until_idle()
        (rec,) = fed.accounting()
        assert rec.state == "COMPLETED"
        assert split_cluster_id(rec.jobid) == ("only", "1000001")


# ---------------------------------------------------------------------------
# Per-cluster EcoController
# ---------------------------------------------------------------------------


class TestFederatedEcoController:
    def test_held_jobs_release_against_their_own_cluster(self):
        # green's eco window is open at T0; dirty's is not — only the
        # green-held job may release early
        h_dirty = make_handle("dirty", 600)
        h_green = make_handle("green", 50)
        h_green.scheduler = EcoScheduler(
            weekday_windows=[(0, 24 * 60)], weekend_windows=[(0, 24 * 60)],
            peak_hours=[], horizon_days=7, min_delay_s=0,
        )
        fed = FederatedBackend(ClusterRegistry([h_dirty, h_green]))
        controller = EcoController(fed, EcoScheduler(
            weekday_windows=[(0, 360)], weekend_windows=[(0, 360)],
            peak_hours=[], horizon_days=7, min_delay_s=0,
        ), now=T0)
        assert controller.registry is fed.registry
        deadline = T0 + timedelta(hours=20)
        from repro.core.eco import EcoDecision

        dec = EcoDecision(begin=deadline, tier=2, deferred=True)
        for name in ("dirty", "green"):
            jx = job(name=f"held-{name}")
            jx.opts.hold = True
            jx.cluster = name
            fed.submit(jx.prepare())
            controller.register(f"{name}:1000001", dec, now=T0, duration_s=60)
        released = controller.tick(T0 + timedelta(minutes=5))
        assert released == ["green:1000001"]
        assert "dirty:1000001" in controller.held
        # at the deadline the dirty job releases unconditionally
        released = controller.tick(deadline)
        assert released == ["dirty:1000001"]

    def test_per_cluster_load_fraction(self):
        fed = make_fed(("a", None), ("b", None))
        jx = job(cpus=8)
        jx.cluster = "a"
        fed.submit(jx.prepare())
        controller = EcoController(fed, EcoScheduler(
            weekday_windows=[], weekend_windows=[], peak_hours=[],
            horizon_days=1, min_delay_s=0,
        ), now=T0)
        assert controller.load_fraction(cluster="a") == pytest.approx(0.5)
        assert controller.load_fraction(cluster="b") == 0.0
        assert controller.load_fraction() == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# Cross-cluster CLI behaviour
# ---------------------------------------------------------------------------


@pytest.fixture
def fed_env(tmp_path, monkeypatch):
    """Config with two sim clusters on divergent flat grids; shared backend."""
    green = tmp_path / "green.csv"
    dirty = tmp_path / "dirty.csv"
    green.write_text("\n".join(f"{h},50" for h in range(168)))
    dirty.write_text("\n".join(f"{h},600" for h in range(168)))
    cfg = tmp_path / "cfg"
    cfg.write_text(
        "economy_mode=0\n"
        f"[cluster.dirty]\nkind=sim\ncarbon_trace={dirty}\n"
        f"[cluster.green]\nkind=sim\ncarbon_trace={green}\n"
    )
    monkeypatch.setenv("NBISLURM_CONFIG", str(cfg))
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    reset_shared_sim()
    yield get_backend()
    reset_shared_sim()


class TestFederatedCLI:
    def test_runjob_pins_and_routes(self, fed_env, capsys):
        from repro.cli import runjob

        assert runjob.main(["-n", "x", "--cluster", "green", "echo hi"]) == 0
        out = capsys.readouterr().out
        assert "green:1000001" in out

    def test_runjob_unknown_cluster_names_members(self, fed_env, capsys):
        from repro.cli import runjob

        rc = runjob.main(["-n", "x", "--cluster", "nope", "echo hi"])
        assert rc == 2
        err = capsys.readouterr().err
        assert "nope" in err and "green" in err and "dirty" in err

    def test_runjob_cluster_and_anywhere_conflict(self, fed_env, capsys):
        from repro.cli import runjob

        with pytest.raises(SystemExit):
            runjob.main(["--cluster", "green", "--anywhere", "echo hi"])

    def test_runjob_flags_require_federation(self, tmp_path, monkeypatch, capsys):
        from repro.cli import runjob

        monkeypatch.setenv("REPRO_BACKEND", "sim")
        reset_shared_sim()
        with pytest.raises(SystemExit):
            runjob.main(["--cluster", "green", "echo hi"])

    def test_runjob_default_goes_to_default_cluster(self, fed_env, capsys):
        from repro.cli import runjob

        assert runjob.main(["-n", "x", "echo hi"]) == 0
        assert "dirty:1000001" in capsys.readouterr().out  # first declared

    def test_lsjobs_shows_cluster_column_and_all_jobs(self, fed_env, capsys):
        from repro.cli import lsjobs, runjob

        runjob.main(["-n", "a", "--cluster", "green", "echo hi"])
        runjob.main(["-n", "b", "--cluster", "dirty", "echo hi"])
        capsys.readouterr()
        assert lsjobs.main(["--all", "--no-color"]) == 0
        out = capsys.readouterr().out
        assert "Cluster" in out
        assert "green:1000001" in out and "dirty:1000001" in out
        assert "2 job(s)" in out  # nothing lost, nothing double-counted

    def test_lsjobs_cluster_filter(self, fed_env, capsys):
        from repro.cli import lsjobs, runjob

        runjob.main(["-n", "a", "--cluster", "green", "echo hi"])
        runjob.main(["-n", "b", "--cluster", "dirty", "echo hi"])
        capsys.readouterr()
        lsjobs.main(["--all", "--no-color", "--cluster", "green"])
        out = capsys.readouterr().out
        assert "green:1000001" in out and "dirty:1000001" not in out

    def test_lsjobs_json_carries_cluster(self, fed_env, capsys):
        import json

        from repro.cli import lsjobs, runjob

        runjob.main(["-n", "a", "--cluster", "green", "echo hi"])
        capsys.readouterr()
        lsjobs.main(["--all", "--json"])
        rows = json.loads(capsys.readouterr().out)
        assert rows[0]["cluster"] == "green"

    def test_waitjobs_drains_across_clusters(self, fed_env, capsys):
        from repro.cli import runjob, waitjobs

        runjob.main(["-n", "a", "--cluster", "green", "echo hi"])
        runjob.main(["-n", "b", "--cluster", "dirty", "echo hi"])
        capsys.readouterr()
        rc = waitjobs.main(["green:1000001", "dirty:1000001",
                            "--poll", "120", "--timeout", "60", "--quiet"])
        assert rc == 0

    def test_waitjobs_sees_cross_cluster_failure(self, fed_env, capsys):
        from repro.cli import waitjobs

        fed = fed_env
        jx = job(name="boom", time_s=30, duration=600)  # hits its limit
        jx.cluster = "green"
        fed.submit(jx.prepare())
        rc = waitjobs.main(["green:1000001",
                            "--poll", "120", "--timeout", "60", "--quiet"])
        assert rc == 1  # TIMEOUT on the green member drives the exit code

    def test_viewjobs_once_shows_cluster_column(self, fed_env, capsys):
        from repro.cli import runjob, viewjobs

        runjob.main(["-n", "a", "--cluster", "green", "echo hi"])
        capsys.readouterr()
        assert viewjobs.main(["--all", "--once"]) == 0
        out = capsys.readouterr().out
        assert "Cluster" in out and "green" in out

    def test_whojobs_breaks_down_clusters(self, fed_env, capsys):
        import json

        from repro.cli import runjob, whojobs

        runjob.main(["-n", "a", "--cluster", "green", "-c", "2", "echo hi"])
        capsys.readouterr()
        whojobs.main(["--json"])
        recs = json.loads(capsys.readouterr().out)
        assert recs[0]["clusters"] == {"green": 2}

    def test_ecoreport_by_cluster(self, fed_env, capsys, monkeypatch, tmp_path):
        import json

        from repro.cli import ecoreport, runjob, waitjobs

        monkeypatch.setenv("NBI_HISTORY", str(tmp_path / "hist.jsonl"))
        runjob.main(["-n", "a", "--cluster", "green", "echo hi"])
        runjob.main(["-n", "b", "--cluster", "dirty", "echo hi"])
        waitjobs.main(["--poll", "120", "--timeout", "60", "--quiet"])
        capsys.readouterr()
        assert ecoreport.main(["--collect", "--by-cluster", "--json"]) == 0
        rep = json.loads(capsys.readouterr().out)
        groups = {g["key"]: g for g in rep["groups"]}
        assert set(groups) == {"green", "dirty"}
        assert rep["total"]["jobs"] == 2  # every job exactly once
        # the green member ran on a cleaner grid than the default (dirty):
        # routing shows positive placement savings
        assert groups["green"]["placement_saved_gco2"] > 0
        assert groups["dirty"]["placement_saved_gco2"] == pytest.approx(0.0)


class TestReviewRegressions:
    """Pins for the post-review fixes."""

    def test_coalesced_array_keeps_cluster_pin(self):
        # a --cluster-pinned batch folded into one array must stay pinned
        fed = make_fed(("dirty", 600), ("green", 50))
        jobs = []
        for i in range(4):
            jx = job(name="sweep")
            jx.cluster = "green"
            jobs.append(jx)
        result = SubmitEngine(fed, coalesce=True, now=T0).submit_many(jobs)
        assert result.sbatch_calls == 1
        assert all(i.startswith("green:") for i in result.ids)

    def test_jobs_pinned_to_different_clusters_never_coalesce(self):
        fed = make_fed(("a", None), ("b", None))
        jobs = []
        for name in ("a", "a", "b", "b"):
            jx = job(name="sweep")
            jx.cluster = name
            jobs.append(jx)
        result = SubmitEngine(fed, coalesce=True, now=T0).submit_many(jobs)
        assert result.sbatch_calls == 2  # one array per member
        assert {i.split(":")[0] for i in result.ids} == {"a", "b"}

    def test_waitjobs_matches_prefixed_array_base(self, fed_env, capsys):
        from repro.cli import runjob, waitjobs

        runjob.main(["--from-file", "/dev/null", "-n", "x"])  # exercises parser
        capsys.readouterr()
        fed = fed_env
        jobs = []
        for i in range(3):
            jx = job(name="arr")
            jx.cluster = "green"
            jobs.append(jx)
        SubmitEngine(fed, coalesce=True).submit_many(jobs)
        rc = waitjobs.main(["green:1000001",
                            "--poll", "120", "--timeout", "60", "--quiet"])
        assert rc == 0  # the base id covers every green:1000001_k task

    def test_array_base_id_with_underscore_cluster_name(self):
        from repro.core import array_base_id

        assert array_base_id("hpc_a:123_4") == "hpc_a:123"
        assert array_base_id("123_4") == "123"
        assert array_base_id("hpc_a:123") == "hpc_a:123"

    def test_states_with_underscore_cluster_name(self):
        reg = ClusterRegistry([make_handle("hpc_a", None)])
        fed = FederatedBackend(reg)
        engine = SubmitEngine(fed, coalesce=True)
        result = engine.submit_many([job(name="arr") for _ in range(3)])
        assert result.ids[0] == "hpc_a:1000001_0"
        states = engine.states(result)
        # tasks are live in the queue — never misreported COMPLETED
        assert set(states.values()) <= {"RUNNING", "PENDING"}

    def test_placer_no_snapshots_with_tracker(self):
        # the event-driven BacklogTracker replaces per-batch snapshots:
        # placement must not call queue() at all
        fed = make_fed(("a", None), ("b", None))
        counts = {"a": 0, "b": 0}
        for h in fed.registry:
            orig = h.backend.queue

            def counted(name=h.name, orig=orig):
                counts[name] += 1
                return orig()

            h.backend.queue = counted
        SubmitEngine(fed, coalesce=False).submit_many(
            [job(name=f"j{i}") for i in range(20)]
        )
        assert counts == {"a": 0, "b": 0}

    def test_placer_snapshots_once_per_batch_without_tracker(self):
        # without a tracker (e.g. real-SLURM members) the old guarantee
        # holds: one queue() per member per batch, not per job
        fed = make_fed(("a", None), ("b", None), tracker=False)
        assert fed.tracker is None
        counts = {"a": 0, "b": 0}
        for h in fed.registry:
            orig = h.backend.queue

            def counted(name=h.name, orig=orig):
                counts[name] += 1
                return orig()

            h.backend.queue = counted
        SubmitEngine(fed, coalesce=False).submit_many(
            [job(name=f"j{i}") for i in range(20)]
        )
        assert counts == {"a": 1, "b": 1}  # one snapshot per member per batch

    def test_tracker_backlog_matches_snapshot(self):
        # charge on SUBMITTED, move on STARTED, discharge at terminal —
        # at every point the incremental backlog equals a fresh snapshot
        fed = make_fed(("a", None), ("b", None))
        tracker = fed.tracker
        assert tracker is not None

        def fresh(handle):
            p = Placer(fed.registry)  # snapshot-path reference
            return p._snapshot_backlog(handle)

        def check():
            for h in fed.registry:
                assert tracker.backlog_cpu_s(h.name) == fresh(h)

        check()  # empty
        engine = SubmitEngine(fed, coalesce=False)
        engine.submit_many([job(name=f"j{i}", cpus=2) for i in range(30)])
        check()  # mix of RUNNING and PENDING
        fed.advance(90)  # running jobs have less time left now
        check()
        fed.run_until_idle()
        check()  # all drained
        drift = tracker.reconcile()
        assert all(v == 0.0 for v in drift.values())
        assert tracker.max_drift_cpu_s == 0.0

    def test_uncharged_probe_does_not_skew_routing(self):
        fed = make_fed(("a", None), ("b", None))
        for _ in range(10):
            fed.placer.place_spec(8, 1024, 7200, T0, charge=False)
        assert fed.placer._inflight == {}
        charged = fed.placer.place_spec(8, 1024, 7200, T0)
        assert fed.placer._inflight != {}
        assert charged.cluster in ("a", "b")


class TestClusterScopedWake:
    """wake_at(cluster=) routes a controller deadline to one member's
    event calendar instead of stamping every cluster with the stop."""

    def test_wake_targets_one_member(self):
        fed = make_fed(("a", 100), ("b", 500))
        t = T0 + timedelta(hours=2)
        fed.wake_at(t, cluster="b")
        a = fed.registry.get("a").backend
        b = fed.registry.get("b").backend
        assert t not in a._wake_set
        assert t in b._wake_set
        fed.wake_at(t)  # no cluster: legacy fan-out to everyone
        assert t in a._wake_set

    def test_eco_register_wakes_only_held_jobs_cluster(self):
        from repro.core.eco import EcoDecision

        fed = make_fed(("a", 100), ("b", 500))
        controller = EcoController(fed, EcoScheduler(
            weekday_windows=[(0, 360)], weekend_windows=[(0, 360)],
            peak_hours=[], horizon_days=7, min_delay_s=0,
        ), now=T0)
        jx = job(name="held-b")
        jx.opts.hold = True
        jx.cluster = "b"
        fed.submit(jx.prepare())
        deadline = T0 + timedelta(hours=20)
        controller.register(
            "b:1000001", EcoDecision(begin=deadline, tier=2, deferred=True),
            now=T0, duration_s=60,
        )
        assert deadline in fed.registry.get("b").backend._wake_set
        assert deadline not in fed.registry.get("a").backend._wake_set

    def test_plain_backend_wake_unaffected(self, tmp_path):
        """EcoController._wake falls back cleanly when the backend's
        wake_at has no cluster routing (standalone SimCluster)."""
        sim = SimCluster(now=T0)
        controller = EcoController(sim, EcoScheduler(
            weekday_windows=[(0, 360)], weekend_windows=[(0, 360)],
            peak_hours=[], horizon_days=7, min_delay_s=0,
        ), now=T0)
        from repro.core.eco import EcoDecision

        jx = job(name="held")
        jx.opts.hold = True
        sim.submit(jx.prepare())
        deadline = T0 + timedelta(hours=20)
        controller.register(
            "1000001", EcoDecision(begin=deadline, tier=2, deferred=True),
            now=T0, duration_s=60,
        )
        assert deadline in sim._wake_set
