"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, one forward + one train step + one decode step on CPU — asserting
output shapes, finite losses, and decode/prefill consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_smoke_config
from repro.models.registry import build_model, padded_vocab
from repro.optim import make_optimizer
from repro.parallel.sharding import rules_for
from repro.training.steps import init_train_state, make_train_step

B, S = 2, 32


def batch_for(cfg, batch=B, seq=S):
    out = {}
    rng = np.random.default_rng(0)
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_len, cfg.d_model)), cfg.dtype
        )
    if cfg.n_patches:
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_patches, cfg.d_model)), cfg.dtype
        )
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq - cfg.n_patches)), jnp.int32
        )
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32
        )
    out["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32
    )
    return out


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ASSIGNED)
class TestForward:
    def test_loss_finite_and_shapes(self, arch, built):
        cfg, model, params = built(arch)
        loss, metrics = jax.jit(model.loss_fn)(params, batch_for(cfg))
        assert loss.shape == ()
        assert np.isfinite(float(loss)), arch
        assert 0 <= float(metrics["accuracy"]) <= 1

    def test_train_step_updates(self, arch, built):
        cfg, model, params = built(arch)
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
        opt = make_optimizer("adamw", lr=1e-3)
        rules = rules_for(cfg, mesh, param_defs=model.param_defs, batch_size=B)
        step = jax.jit(make_train_step(model, opt, rules, mesh))
        state = init_train_state(model, opt, jax.random.PRNGKey(1))
        before = jax.tree_util.tree_leaves(state["params"])[0].copy()
        with mesh:
            state2, metrics = step(state, batch_for(cfg))
        assert int(state2["step"]) == 1
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        after = jax.tree_util.tree_leaves(state2["params"])[0]
        assert not np.allclose(np.asarray(before), np.asarray(after)), arch


@pytest.mark.parametrize("arch", ASSIGNED)
class TestDecode:
    def test_prefill_then_decode_matches_full_forward(self, arch, built):
        """Greedy decode-step logits at position S must equal a full forward
        over the S+1 tokens — the KV-cache/recurrent-state correctness law.

        MoE archs are rebuilt drop-free (capacity_factor=16): with token
        dropping the law intentionally does not hold exactly, because the
        drop pattern depends on the dispatch group's size (documented MoE
        semantics; the drop path itself is covered by test_moe_dispatch)."""
        cfg, model, params = built(arch)
        if not cfg.has_decoder:
            pytest.skip("encoder-only")
        if cfg.family == "moe":
            cfg = cfg.replace(capacity_factor=16.0)
            model = build_model(get_smoke_config(arch).replace(capacity_factor=16.0))
            params = model.init(jax.random.PRNGKey(0))
        data = batch_for(cfg, batch=1, seq=16)
        toks = data["tokens"]
        pre_in = {k: v for k, v in data.items() if k != "labels"}
        logits_last, cache = jax.jit(model.prefill_fn)(params, pre_in)
        assert logits_last.shape[0] == 1 and logits_last.shape[1] == 1

        # feed token S (argmax of prefill) through one decode step
        from repro.launch.serve import pad_cache_to

        max_seq = toks.shape[1] + 8 + (cfg.n_patches or 0) + (
            0 if cfg.family != "encdec" else 0
        )
        cache = pad_cache_to(cache, model.cache_defs_fn(1, max_seq))
        nxt = jnp.argmax(logits_last[:, -1], -1)[:, None].astype(jnp.int32)
        pos = jnp.asarray(toks.shape[1] + (cfg.n_patches or 0), jnp.int32)
        step_logits, _ = jax.jit(model.decode_fn)(params, cache, nxt, pos)

        # ground truth: full forward over [toks ; nxt]
        full_in = dict(pre_in)
        full_in["tokens"] = jnp.concatenate([toks, nxt], axis=1)
        full_last, _ = jax.jit(model.prefill_fn)(params, full_in)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, -1]), np.asarray(full_last[:, -1]),
            rtol=2e-2, atol=2e-2,
        )

    def test_decode_cache_shapes_stable(self, arch, built):
        cfg, model, params = built(arch)
        if not cfg.has_decoder:
            pytest.skip("encoder-only")
        cache_defs = model.cache_defs_fn(1, 24)
        cache = jax.tree_util.tree_map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype), cache_defs
        )
        tok = jnp.zeros((1, 1), jnp.int32)
        logits, new_cache = jax.jit(model.decode_fn)(
            params, cache, tok, jnp.asarray(0, jnp.int32)
        )
        assert logits.shape == (1, 1, padded_vocab(get_smoke_config(arch)))
        for a, b in zip(
            jax.tree_util.tree_leaves(cache), jax.tree_util.tree_leaves(new_cache)
        ):
            assert a.shape == b.shape and a.dtype == b.dtype


class TestConfigs:
    @pytest.mark.parametrize("arch", ASSIGNED)
    def test_full_config_matches_assignment(self, arch):
        """The published numbers from the assignment table, verbatim."""
        cfg = get_config(arch)
        table = {
            "deepseek_moe_16b": (28, 2048, 16, 16, 102400),
            "kimi_k2_1t_a32b": (61, 7168, 64, 8, 163840),
            "rwkv6_7b": (32, 4096, 0, 0, 65536),
            "codeqwen15_7b": (32, 4096, 32, 32, 92416),
            "minicpm3_4b": (62, 2560, 40, 40, 73448),
            "mistral_large_123b": (88, 12288, 96, 8, 32768),
            "starcoder2_7b": (32, 4608, 36, 4, 49152),
            "recurrentgemma_2b": (26, 2560, 10, 1, 256000),
            "whisper_small": (12, 768, 12, 12, 51865),
            "llava_next_mistral_7b": (32, 4096, 32, 8, 32000),
        }
        L, D, H, KV, V = table[arch]
        assert cfg.n_layers == L and cfg.d_model == D and cfg.vocab_size == V
        if H:
            assert cfg.n_heads == H and cfg.n_kv_heads == KV

    def test_param_counts_plausible(self):
        """Analytic param counts near the models' nominal sizes."""
        expect = {
            "deepseek_moe_16b": (14e9, 18e9),
            "kimi_k2_1t_a32b": (0.9e12, 1.2e12),
            "rwkv6_7b": (6e9, 9e9),
            "codeqwen15_7b": (6e9, 8.5e9),  # assigned d_ff=13440, MHA kv=32
            "minicpm3_4b": (3.5e9, 5e9),
            "mistral_large_123b": (115e9, 130e9),
            # framework uses SwiGLU (3 MLP mats) uniformly; the original's
            # GELU MLP (2 mats) would be ~7.2B — see DESIGN §Arch notes
            "starcoder2_7b": (6.5e9, 10.5e9),
            "recurrentgemma_2b": (2e9, 3.5e9),
            "whisper_small": (0.15e9, 0.4e9),
            "llava_next_mistral_7b": (6.5e9, 8e9),
        }
        for arch, (lo, hi) in expect.items():
            n = get_config(arch).param_count()
            assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"

    def test_moe_active_params(self):
        cfg = get_config("kimi_k2_1t_a32b")
        active = cfg.active_param_count()
        assert 25e9 <= active <= 40e9  # "A32B"
        assert active < cfg.param_count() / 10
