"""Training substrate: optimizers, schedules, grad accumulation, the
train driver (learning + resume-equivalence + eco-preempt)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim import adamw, adamw8bit, cosine_warmup, lion, make_optimizer
from repro.optim.optimizers import _dequant, _quant
from repro.parallel.sharding import rules_for
from repro.training.steps import init_train_state, make_train_step


def quad_params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}


def quad_grads(params):
    return {"w": 2 * params["w"]}  # d/dw of w²


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adamw8bit", "lion"])
    def test_descends_quadratic(self, name):
        opt = make_optimizer(name, lr=0.05, weight_decay=0.0)
        params = quad_params()
        state = opt.init(params)
        for _ in range(50):
            params, state = opt.update(quad_grads(params), state, params)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_adamw8bit_tracks_adamw(self):
        """Same trajectory within quantisation error for tens of steps."""
        o1, o2 = adamw(lr=0.01, weight_decay=0.0), adamw8bit(lr=0.01, weight_decay=0.0)
        p1 = p2 = {"w": jnp.linspace(-1, 1, 64)[None, :].repeat(4, 0)}
        s1, s2 = o1.init(p1), o2.init(p2)
        rng = np.random.default_rng(0)
        for _ in range(20):
            g = {"w": jnp.asarray(rng.standard_normal((4, 64)) * 0.1, jnp.float32)}
            p1, s1 = o1.update(g, s1, p1)
            p2, s2 = o2.update(g, s2, p2)
        a, b = np.asarray(p1["w"]), np.asarray(p2["w"])
        # int8 moments drift like bitsandbytes: tight on average, loose tail
        assert np.abs(a - b).mean() < 3e-3
        np.testing.assert_allclose(a, b, atol=0.03)

    def test_quant_roundtrip(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 128)), jnp.float32)
        q, s = _quant(x)
        assert q.dtype == jnp.int8
        np.testing.assert_allclose(
            np.asarray(_dequant(q, s)), np.asarray(x),
            atol=float(jnp.abs(x).max()) / 127 + 1e-6,
        )

    def test_grad_clipping(self):
        opt = adamw(lr=1e-3, clip_norm=1.0)
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        huge = {"w": jnp.full(4, 1e6)}
        p1, s1 = opt.update(huge, state, params)
        # post-clip first moment has norm ≤ (1-b1)·1.0
        assert float(jnp.linalg.norm(s1["m"]["w"])) <= 0.1 + 1e-6

    def test_state_logical_mirrors(self):
        plog = {"w": ("embed", "ff")}
        assert adamw().state_logical(plog)["m"] == plog
        l8 = adamw8bit().state_logical(plog)
        assert l8["m"]["w"]["q"] == ("embed", "ff")
        assert l8["m"]["w"]["scale"] == ("embed",)

    def test_cosine_warmup_schedule(self):
        sched = cosine_warmup(1e-3, warmup_steps=10, total_steps=100, floor=1e-4)
        assert float(sched(jnp.asarray(0))) < 2e-4
        assert float(sched(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
        assert float(sched(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


class TestGradAccumulation:
    def test_microbatched_equals_full_batch(self):
        """mb=4 grad-accum must reproduce the mb=1 update (same math)."""
        cfg = get_smoke_config("codeqwen1.5-7b")
        mesh = make_host_mesh()
        batch = {
            "tokens": jnp.tile(jnp.arange(16, dtype=jnp.int32)[None], (8, 1)),
            "labels": jnp.tile(jnp.arange(16, dtype=jnp.int32)[None], (8, 1)),
        }
        results = {}
        for mb in (1, 4):
            model = build_model(cfg.replace(microbatch=mb))
            opt = make_optimizer("adamw", lr=1e-3)
            rules = rules_for(cfg, mesh, param_defs=model.param_defs, batch_size=8)
            step = jax.jit(make_train_step(model, opt, rules, mesh))
            state = init_train_state(model, opt, jax.random.PRNGKey(0))
            with mesh:
                new_state, metrics = step(state, batch)
            results[mb] = (new_state["params"], float(metrics["loss"]))
        np.testing.assert_allclose(results[1][1], results[4][1], rtol=1e-5)
        # params: f32 reassociation noise is amplified by Adam's m/√v̂ near
        # v̂≈0 — allow ~10% of one lr=1e-3 update, far below signal
        for a, b in zip(
            jax.tree_util.tree_leaves(results[1][0]),
            jax.tree_util.tree_leaves(results[4][0]),
        ):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


class TestTrainDriver:
    def _mini(self, monkeypatch):
        import repro.configs.nbi100m as mod

        orig = mod.config
        monkeypatch.setattr(
            mod, "config",
            lambda: orig().replace(
                name="nano", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                head_dim=16, d_ff=128, vocab_size=512,
            ),
        )

    def test_loss_decreases(self, tmp_path, monkeypatch):
        from repro.launch.train import build_argparser, train

        self._mini(monkeypatch)
        args = build_argparser().parse_args([
            "--arch", "nbi-100m", "--steps", "30", "--global-batch", "8",
            "--seq", "64", "--log-every", "5",
        ])
        result = train(args)
        losses = [m["loss"] for m in result["metrics"]]
        assert losses[-1] < losses[0]

    def test_resume_equivalence(self, tmp_path, monkeypatch):
        """20 straight steps ≡ 10 steps + checkpoint + restart + 10 steps
        (bitwise on params) — the fault-tolerance guarantee."""
        from repro.launch.train import build_argparser, train
        from repro.checkpoint import CheckpointManager

        self._mini(monkeypatch)

        def run(steps, ckpt_dir, every):
            args = build_argparser().parse_args([
                "--arch", "nbi-100m", "--steps", str(steps), "--global-batch",
                "4", "--seq", "32", "--ckpt-dir", str(ckpt_dir),
                "--ckpt-every", str(every), "--log-every", "100",
            ])
            return train(args)

        run(20, tmp_path / "straight", 20)
        run(10, tmp_path / "split", 10)   # stops at 10, checkpoints
        run(20, tmp_path / "split", 10)   # resumes 10 → 20

        a, _, _ = CheckpointManager(tmp_path / "straight").restore(
            _params_target(tmp_path / "straight")
        )
        b, _, _ = CheckpointManager(tmp_path / "split").restore(
            _params_target(tmp_path / "split")
        )
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_eco_preempt_saves_and_reports(self, tmp_path, monkeypatch):
        from repro.launch.train import build_argparser, train

        self._mini(monkeypatch)
        args = build_argparser().parse_args([
            "--arch", "nbi-100m", "--steps", "100000", "--global-batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path / "eco"),
            "--eco-preempt", "--now", "2026-03-18T16:59:58",
            "--log-every", "50",
        ])
        result = train(args)
        assert result["stopped"] == "eco-preempt"
        assert result["resubmit_begin"].startswith("2026-03-19T00:00:00")
        from repro.checkpoint import CheckpointManager

        assert CheckpointManager(tmp_path / "eco").latest_step() is not None


def _params_target(ckpt_dir):
    """Build a matching abstract target from the checkpoint's own manifest."""
    import json
    from pathlib import Path

    from repro.checkpoint.manager import MANIFEST

    steps = sorted(Path(ckpt_dir).glob("step_*"))
    rec = json.loads((steps[-1] / MANIFEST).read_text())
    leaves = [
        jax.ShapeDtypeStruct(tuple(r["shape"]), np.dtype(r["dtype"]))
        for r in rec["leaves"]
    ]
    return leaves  # flat list is a valid pytree with the same leaf count
