"""NBI::Pipeline — automatic afterok wiring over Jobs and Launchers."""

import pytest

from repro.core import (
    InputSpec, Job, Launcher, Opts, Pipeline, PipelineError, SimCluster,
)


def mkjob(name, duration=30):
    return Job(name=name, command="true",
               opts=Opts.new(threads=1, memory="1GB", time="1h"),
               sim_duration_s=duration)


class TestGraph:
    def test_toposort_order(self, sim):
        p = Pipeline(backend=sim)
        p.add("c", mkjob("c"), after=["b"])
        p.add("b", mkjob("b"), after="a")
        p.add("a", mkjob("a"))
        order = [s.name for s in p.toposort()]
        assert order.index("a") < order.index("b") < order.index("c")

    def test_cycle_detected(self):
        p = Pipeline()
        p.add("a", mkjob("a"), after=["b"])
        p.add("b", mkjob("b"), after=["a"])
        with pytest.raises(PipelineError, match="cycle"):
            p.toposort()

    def test_unknown_dependency(self):
        p = Pipeline()
        p.add("a", mkjob("a"), after=["ghost"])
        with pytest.raises(PipelineError, match="unknown"):
            p.toposort()

    def test_duplicate_step(self):
        p = Pipeline()
        p.add("a", mkjob("a"))
        with pytest.raises(PipelineError, match="duplicate"):
            p.add("a", mkjob("a2"))


class TestRun:
    def test_ids_threaded_into_dependencies(self, sim):
        p = Pipeline(backend=sim)
        p.add("assemble", mkjob("assemble"))
        p.add("annotate", mkjob("annotate"), after="assemble")
        p.add("report", mkjob("report"), after=["annotate"])
        ids = p.run()
        ann = sim.get(ids["annotate"])
        rep = sim.get(ids["report"])
        assert ann.dependencies == [str(ids["assemble"])]
        assert rep.dependencies == [str(ids["annotate"])]

    def test_dependency_order_execution(self, sim):
        p = Pipeline(backend=sim)
        p.add("a", mkjob("a", 60))
        p.add("b", mkjob("b", 60), after="a")
        ids = p.run()
        assert sim.get(ids["b"]).state == "PENDING"
        sim.run_until_idle()
        a, b = sim.get(ids["a"]), sim.get(ids["b"])
        assert a.state == b.state == "COMPLETED"
        assert b.started_at >= a.finished_at

    def test_fan_out_fan_in(self, sim):
        p = Pipeline(backend=sim)
        p.add("prep", mkjob("prep"))
        for i in range(4):
            p.add(f"shard{i}", mkjob(f"shard{i}"), after="prep")
        p.add("merge", mkjob("merge"), after=[f"shard{i}" for i in range(4)])
        ids = p.run()
        merge = sim.get(ids["merge"])
        assert len(merge.dependencies) == 4
        sim.run_until_idle()
        assert all(j.state == "COMPLETED" for j in sim.accounting())

    def test_launcher_payload(self, sim, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "s"))

        class T(Launcher):
            tool_name = "t"
            inputs_spec = [InputSpec("x", kind="str")]

            def make_command(self):
                return f"echo {self.inputs['x']}"

        p = Pipeline(backend=sim)
        p.add("one", mkjob("one"))
        p.add("two", T(x="hi", outdir=str(tmp_path), eco=False), after="one")
        ids = p.run()
        assert sim.get(ids["two"]).dependencies == [str(ids["one"])]

    def test_failed_upstream_blocks_downstream(self, sim):
        bad = Job(name="bad", command="true",
                  opts=Opts.new(threads=1, memory="1GB", time="1h"),
                  sim_duration_s=7200)  # exceeds 1h limit → TIMEOUT
        p = Pipeline(backend=sim)
        p.add("bad", bad)
        p.add("after", mkjob("after"), after="bad")
        ids = p.run()
        sim.run_until_idle()
        assert sim.get(ids["bad"]).state == "TIMEOUT"
        assert sim.get(ids["after"]).reason == "DependencyNeverSatisfied"
