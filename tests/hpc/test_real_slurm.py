"""Optional tests against a REAL Slurm installation (paper: `xt/hpc-*.t`).

The paper ships author-facing tests that exercise the live scheduler:
"To check the ability to interact with Slurm, there are optional tests that
can be executed with prove -lv xt/hpc-*.t". This is the pytest analogue —
the whole module skips unless ``sbatch`` is on PATH, so CI and the
simulator-backed suite never depend on a cluster.

    pytest tests/hpc/ -v        # on a login node
"""

import shutil
import subprocess
import time

import pytest

pytestmark = pytest.mark.skipif(
    shutil.which("sbatch") is None, reason="no Slurm installation on PATH"
)


@pytest.fixture
def slurm_backend(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "slurm")
    from repro.core.backend import SlurmBackend

    return SlurmBackend()


class TestRealSlurm:
    def test_submit_query_cancel_roundtrip(self, slurm_backend, tmp_path,
                                           monkeypatch):
        from repro.core import Job, Opts, Queue

        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path))
        job = Job(
            name="nbi-hpc-roundtrip",
            command="sleep 60",
            opts=Opts.new(threads=1, memory="100MB", time="5m"),
        )
        jid = job.run(slurm_backend)
        assert isinstance(jid, int)
        try:
            deadline = time.monotonic() + 60
            seen = False
            while time.monotonic() < deadline:
                q = Queue(name="nbi-hpc-roundtrip", backend=slurm_backend)
                if any(j.jobid_num == jid for j in q):
                    seen = True
                    break
                time.sleep(2)
            assert seen, "job never appeared in squeue"
        finally:
            slurm_backend.cancel([jid])

    def test_sinfo_nodes(self, slurm_backend):
        nodes = slurm_backend.nodes_info()
        assert nodes and all("name" in n and n["cpus"] > 0 for n in nodes)

    def test_eco_begin_accepted_by_sbatch(self, slurm_backend, tmp_path,
                                          monkeypatch):
        """A --begin directive injected by the eco scheduler must be accepted
        verbatim by a real sbatch (format compatibility)."""
        from datetime import datetime, timedelta

        from repro.core import EcoScheduler, Job, Opts

        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path))
        sched = EcoScheduler(weekday_windows=[(0, 360)], weekend_windows=[],
                             peak_hours=[], horizon_days=7, min_delay_s=60)
        d = sched.next_window(600, datetime.now() + timedelta(minutes=2))
        opts = Opts.new(threads=1, memory="100MB", time="5m")
        opts.set_begin(d.begin_directive)
        jid = Job(name="nbi-hpc-eco", command="true", opts=opts).run(slurm_backend)
        try:
            assert isinstance(jid, int)
        finally:
            slurm_backend.cancel([jid])
