"""NBI::Launcher — declarative wrappers: validation, activation, resource
inflation (Kraken2 1.4×+100GB; TrainLauncher chip sizing), discovery."""

import json
from pathlib import Path

import pytest

from repro.core import (
    InputSpec, Kraken2, Launcher, LauncherError, Manifest, SimCluster,
    discover_launchers,
)
from repro.core.resources import Opts
from repro.launch.submit import ServeLauncher, TrainLauncher, train_memory_model


class Echo(Launcher):
    tool_name = "echo"
    inputs_spec = [InputSpec("text", required=True, kind="str")]
    params_spec = [InputSpec("upper", required=False, kind="flag", default=0)]

    def make_command(self) -> str:
        return f"echo {self.inputs['text']}"


class TestBase:
    def test_missing_required_input(self):
        with pytest.raises(LauncherError, match="missing required input"):
            Echo(eco=False)

    def test_unknown_argument(self):
        with pytest.raises(LauncherError, match="unknown arguments"):
            Echo(text="hi", bogus=1, eco=False)

    def test_env_default(self, monkeypatch, tmp_path):
        class EnvTool(Launcher):
            tool_name = "envtool"
            inputs_spec = [InputSpec("db", default_env="MY_DB")]

            def make_command(self):
                return f"tool {self.inputs['db']}"

        monkeypatch.setenv("MY_DB", "/dbs/x")
        t = EnvTool(eco=False)
        assert t.inputs["db"] == "/dbs/x"

    def test_submit_writes_manifest_and_defers(self, sim, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "s"))
        from datetime import datetime

        e = Echo(text="hello", outdir=str(tmp_path), backend=sim)
        # eco defaults ON (paper): submitted Wed 10:00 → deferred to 00:00
        jid = e.submit(now=datetime(2026, 3, 18, 10, 0))
        assert e.opts.begin == "2026-03-19T00:00:00"
        rec = Manifest.load(str(Path(tmp_path) / "echo.manifest.json"))
        assert rec["status"] == "submitted"
        assert rec["jobid"] == jid
        assert rec["resources"]["begin"] == "2026-03-19T00:00:00"

    def test_no_eco_runs_now(self, sim, tmp_path, monkeypatch):
        monkeypatch.setenv("NBI_TMPDIR", str(tmp_path / "s"))
        e = Echo(text="hello", outdir=str(tmp_path), backend=sim, eco=False)
        e.submit()
        assert e.opts.begin == ""

    def test_activation_lines(self):
        class ModTool(Echo):
            activation = ("module", "bwa/0.7.17")

        assert ModTool(text="x", eco=False).activation_lines() == [
            "module load bwa/0.7.17"
        ]

        class SingTool(Echo):
            activation = ("singularity", "img.sif")

        assert "singularity exec img.sif" in SingTool(
            text="x", eco=False
        ).command_with_activation()


class TestKraken2Inflation:
    def test_memory_formula(self, tmp_path):
        """paper: mem = db_size × 1.4 + 100 GB."""
        db = tmp_path / "db"
        db.mkdir()
        (db / "hash.k2d").write_bytes(b"\0" * 10_000_000_000 if False else b"\0" * 10_000_000)
        kr = Kraken2(reads1="r.fq", db=str(db), eco=False)
        expect_gb = (10_000_000 / 1e9) * 1.4 + 100
        assert kr.opts.memory_mb == pytest.approx(expect_gb * 1024, rel=0.01)

    def test_threads_sync_from_cpus(self, tmp_path):
        db = tmp_path / "db"
        db.mkdir()
        kr = Kraken2(reads1="r.fq", db=str(db), eco=False,
                     opts=Opts.new(threads=16, memory="1GB", time="1h"))
        assert kr.params["threads"] == 16
        assert "--threads 16" in kr.make_command()

    def test_paired_and_single(self, tmp_path):
        db = tmp_path / "db"
        db.mkdir()
        single = Kraken2(reads1="r1.fq", db=str(db), eco=False)
        assert "--paired" not in single.make_command()
        paired = Kraken2(reads1="r1.fq", reads2="r2.fq", db=str(db), eco=False)
        assert "--paired r1.fq r2.fq" in paired.make_command()

    def test_db_from_env(self, tmp_path, monkeypatch):
        db = tmp_path / "db"
        db.mkdir()
        monkeypatch.setenv("KRAKEN2_DB", str(db))
        kr = Kraken2(reads1="r.fq", eco=False)
        assert kr.inputs["db"] == str(db)


class TestTrainLauncher:
    def test_chip_sizing_monotonic(self):
        small = train_memory_model(100e6)
        large = train_memory_model(123e9)
        assert small["chips"] == 1
        assert large["chips"] >= 128
        assert large["hosts"] == -(-large["chips"] // 4)

    def test_adamw8bit_needs_fewer_chips(self):
        n = 1.03e12
        assert train_memory_model(n, "adamw8bit")["chips"] < train_memory_model(n, "adamw")["chips"]

    def test_derived_resources(self):
        tl = TrainLauncher(arch="mistral-large-123b", eco=False,
                           backend=SimCluster())
        assert tl.opts.nodes == tl.sizing["hosts"]
        assert tl.opts.gres.startswith("tpu:v5e:")
        assert tl.opts.memory_mb >= 100 * 1024  # paper's fixed overhead
        assert "repro.launch.train --arch mistral-large-123b" in tl.make_command()

    def test_serve_launcher(self):
        sl = ServeLauncher(arch="starcoder2-7b", eco=False, backend=SimCluster())
        assert "repro.launch.serve --arch starcoder2-7b" in sl.make_command()
        assert sl.opts.nodes >= 1


class TestDiscovery:
    def test_builtins_present(self):
        found = discover_launchers("/nonexistent")
        assert {"kraken2", "train", "serve"} <= set(found)

    def test_third_party_discovery(self, tmp_path):
        (tmp_path / "mytool.py").write_text(
            "from repro.core import Launcher, InputSpec\n"
            "class MyTool(Launcher):\n"
            "    tool_name = 'mytool'\n"
            "    inputs_spec = [InputSpec('x')]\n"
            "    def make_command(self): return 'mytool'\n"
        )
        found = discover_launchers(str(tmp_path))
        assert "mytool" in found
        assert found["mytool"].tool_name == "mytool"

    def test_broken_module_skipped(self, tmp_path):
        (tmp_path / "broken.py").write_text("raise RuntimeError('nope')\n")
        found = discover_launchers(str(tmp_path))  # must not raise
        assert "kraken2" in found

    def test_default_home_dir_scanned(self, tmp_path, monkeypatch):
        """With no explicit dir, ``~/.nbi/launchers/*.py`` is the search
        path — the contract the docs promise third-party wrapper authors."""
        home = tmp_path / "home"
        launcher_dir = home / ".nbi" / "launchers"
        launcher_dir.mkdir(parents=True)
        (launcher_dir / "hometool.py").write_text(
            "from repro.core import Launcher, InputSpec\n"
            "class HomeTool(Launcher):\n"
            "    tool_name = 'hometool'\n"
            "    inputs_spec = [InputSpec('x', required=False, default='1')]\n"
            "    def make_command(self): return 'hometool'\n"
        )
        monkeypatch.setenv("HOME", str(home))
        found = discover_launchers()
        assert "hometool" in found

    def test_non_launcher_symbols_ignored(self, tmp_path):
        (tmp_path / "mixed.py").write_text(
            "from repro.core import Launcher, InputSpec\n"
            "class NotALauncher:\n"
            "    tool_name = 'imposter'\n"
            "helper = 42\n"
            "class Real(Launcher):\n"
            "    tool_name = 'real'\n"
            "    def make_command(self): return 'real'\n"
        )
        found = discover_launchers(str(tmp_path))
        assert "real" in found and "imposter" not in found

    def test_third_party_overrides_builtin_name(self, tmp_path):
        (tmp_path / "k2.py").write_text(
            "from repro.core import Launcher\n"
            "class MyKraken(Launcher):\n"
            "    tool_name = 'kraken2'\n"
            "    def make_command(self): return 'my-kraken2'\n"
        )
        found = discover_launchers(str(tmp_path))
        assert found["kraken2"].__name__ == "MyKraken"


class TestNbilaunchDiscoveryCli:
    WRAPPER = (
        "from repro.core import Launcher, InputSpec\n"
        "class Greet(Launcher):\n"
        "    '''Say hello from a third-party wrapper.'''\n"
        "    tool_name = 'greet'\n"
        "    inputs_spec = [InputSpec('who', required=True, kind='str')]\n"
        "    def make_command(self):\n"
        "        return f\"echo hello {self.inputs['who']}\"\n"
    )

    def test_list_includes_third_party_with_docstring(self, tmp_path, capsys):
        from repro.cli import nbilaunch

        (tmp_path / "greet.py").write_text(self.WRAPPER)
        rc = nbilaunch.main(["--list", "--launcher-dir", str(tmp_path)])
        out = capsys.readouterr().out
        assert rc == 0
        assert "greet" in out and "Say hello from a third-party wrapper." in out
        assert "kraken2" in out  # built-ins still listed

    def test_no_tool_behaves_as_list(self, capsys):
        from repro.cli import nbilaunch

        rc = nbilaunch.main([])
        out = capsys.readouterr().out
        assert rc == 0 and "kraken2" in out

    def test_third_party_dry_run(self, tmp_path, capsys):
        from repro.cli import nbilaunch

        (tmp_path / "greet.py").write_text(self.WRAPPER)
        rc = nbilaunch.main([
            "greet", "who=world", "--launcher-dir", str(tmp_path),
            "--outdir", str(tmp_path / "out"), "--dry-run", "--no-eco",
        ])
        out = capsys.readouterr().out
        assert rc == 0
        assert "echo hello world" in out and "#SBATCH" in out

    def test_third_party_submit_to_sim(self, tmp_path, capsys):
        from repro.cli import nbilaunch
        from repro.core import get_backend

        (tmp_path / "greet.py").write_text(self.WRAPPER)
        rc = nbilaunch.main([
            "greet", "who=sim", "--launcher-dir", str(tmp_path),
            "--outdir", str(tmp_path / "out"), "--no-eco",
        ])
        out = capsys.readouterr().out
        assert rc == 0
        jid = int(out.strip().splitlines()[-1])
        job = get_backend().get(jid)
        assert job is not None and job.name == "greet"
        assert job.tool == "greet"  # accounting/predictor key survives

    def test_missing_wrapper_arg_reported(self, tmp_path, capsys):
        from repro.cli import nbilaunch

        (tmp_path / "greet.py").write_text(self.WRAPPER)
        rc = nbilaunch.main(
            ["greet", "--launcher-dir", str(tmp_path), "--no-eco"])
        out = capsys.readouterr().out
        assert rc == 1 and "missing required input 'who'" in out
