"""Sharding rules: logical→mesh derivation, divisibility self-disable,
spec resolution. Uses tiny meshes over the single CPU device where a real
Mesh is needed; rule logic itself is pure."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.common import ParamDef
from repro.models.registry import build_model
from repro.parallel.sharding import (
    dp_axes, mesh_axis_sizes, rules_for, spec_for,
)


class FakeMesh:
    """Duck-typed mesh: rules_for only reads axis_names and devices.shape."""

    class _Dev:
        def __init__(self, shape):
            self.shape = shape
            self.size = 1
            for s in shape:
                self.size *= s

    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = self._Dev(shape)


SINGLE = FakeMesh((16, 16), ("data", "model"))
MULTI = FakeMesh((2, 16, 16), ("pod", "data", "model"))


class TestRules:
    def test_divisible_dims_shard(self):
        cfg = get_config("codeqwen1.5-7b")  # 32 heads, kv 32, ff 13440
        model = build_model(cfg)
        rules = rules_for(cfg, SINGLE, param_defs=model.param_defs,
                          batch_size=256)
        assert rules["heads"] == "model"      # 32 % 16 == 0
        assert rules["kv_heads"] == "model"
        assert rules["ff"] == "model"         # 13440 % 16 == 0
        assert rules["vocab"] == "model"      # padded vocab
        assert rules["batch"] == "data"

    def test_non_divisible_self_disables(self):
        cfg = get_config("starcoder2-7b")  # 36 heads, kv 4 on 16-way axis
        model = build_model(cfg)
        rules = rules_for(cfg, SINGLE, param_defs=model.param_defs,
                          batch_size=256)
        assert rules["heads"] is None      # 36 % 16 != 0
        assert rules["kv_heads"] is None   # 4 % 16 != 0
        assert rules["ff"] == "model"      # 18432 % 16 == 0

    def test_batch_needs_divisibility(self):
        cfg = get_config("codeqwen1.5-7b")
        rules = rules_for(cfg, SINGLE, batch_size=1)  # long_500k: batch 1
        assert rules["batch"] is None

    def test_multipod_batch_spans_pod_and_data(self):
        cfg = get_config("codeqwen1.5-7b")
        rules = rules_for(cfg, MULTI, batch_size=256)  # 256 % 32 == 0
        assert rules["batch"] == ("pod", "data")

    def test_kv_seq_rule_from_extra_dims(self):
        cfg = get_config("mistral-large-123b")
        r1 = rules_for(cfg, SINGLE, extra_dims={"kv_seq": 32768})
        assert r1["kv_seq"] == "model"
        r2 = rules_for(cfg, SINGLE, extra_dims={"kv_seq": 100})
        assert r2["kv_seq"] is None

    def test_experts_rule(self):
        cfg = get_config("deepseek-moe-16b")  # 64 experts
        model = build_model(cfg)
        rules = rules_for(cfg, SINGLE, param_defs=model.param_defs)
        assert rules["experts"] == "model"

    def test_spec_for(self):
        rules = {"batch": ("pod", "data"), "heads": "model", "embed": None}
        spec = spec_for(("batch", None, "heads"), rules)
        assert spec == P(("pod", "data"), None, "model")

    def test_helpers(self):
        assert mesh_axis_sizes(MULTI) == {"pod": 2, "data": 16, "model": 16}
        assert dp_axes(MULTI) == ("pod", "data")
        assert dp_axes(SINGLE) == ("data",)

    def test_param_defs_checked_per_dim(self):
        """A ParamDef with a non-divisible 'ff' disables the whole rule."""
        cfg = get_config("codeqwen1.5-7b")
        defs = {"w": ParamDef((10, 17), ("embed", "ff"))}
        rules = rules_for(cfg, SINGLE, param_defs=defs)
        assert rules["ff"] is None


class TestRealMeshIntegration:
    def test_host_mesh_lower(self):
        """rules_for + resolve_tree on a real (1,1) mesh lowers a train step."""
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_host_mesh
        from repro.optim import make_optimizer
        from repro.parallel.sharding import resolve_tree
        from repro.training.steps import (
            abstract_train_state, make_train_step, train_state_logical,
        )

        cfg = get_smoke_config("codeqwen1.5-7b")
        model = build_model(cfg)
        mesh = make_host_mesh()
        opt = make_optimizer("adamw")
        rules = rules_for(cfg, mesh, param_defs=model.param_defs, batch_size=2)
        state = abstract_train_state(model, opt)
        state_sh = resolve_tree(mesh, train_state_logical(model, opt), rules)
        step = make_train_step(model, opt, rules, mesh)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(state_sh, None), out_shardings=(state_sh, None)
            ).lower(state, model.train_inputs(2, 32))
            assert lowered.compile() is not None
