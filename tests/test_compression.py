"""Gradient compression: int8 quant, error feedback, compressed-DP training.

The multi-device integration runs in a subprocess (own XLA_FLAGS=4 devices)
so the main test process keeps the 1-device invariant from conftest."""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.compression import (
    dequant_int8, init_ef_state, quant_int8, wire_bytes_per_param,
)

SRC = Path(__file__).resolve().parent.parent / "src"

# Pre-existing seed failures: these integration tests drive multi-device
# collectives through jax.shard_map, which old jax builds don't expose.
# Keyed on the attribute so the mark lifts itself on a modern jax.
needs_shard_map = pytest.mark.xfail(
    not hasattr(jax, "shard_map"),
    reason="seed failure: this jax build has no jax.shard_map",
    strict=False,
)


class TestQuant:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)
        q, s = quant_int8(x)
        assert q.dtype == jnp.int8 and s.shape == (16,)
        err = jnp.abs(dequant_int8(q, s) - x)
        per_row_bound = jnp.max(jnp.abs(x), axis=1) / 127 * 0.5 + 1e-6
        assert bool(jnp.all(err <= per_row_bound[:, None] + 1e-6))

    def test_zero_row_safe(self):
        q, s = quant_int8(jnp.zeros((2, 8)))
        assert not np.any(np.isnan(np.asarray(dequant_int8(q, s))))

    def test_wire_accounting(self):
        assert wire_bytes_per_param(False) == 4.0
        assert wire_bytes_per_param(True) < 1.1

    def test_ef_state_mirrors_grads(self):
        grads = {"a": jnp.ones((2, 3), jnp.bfloat16), "b": jnp.ones(4)}
        ef = init_ef_state(grads)
        assert ef["a"].shape == (2, 3) and ef["a"].dtype == jnp.float32
        assert float(jnp.abs(ef["b"]).max()) == 0.0


class TestErrorFeedback:
    @needs_shard_map
    def test_carry_recycles_quantisation_loss(self):
        """Over many steps, mean(sent) → mean(target): EF is unbiased."""
        from repro.parallel.compression import ef_compressed_psum

        mesh = jax.make_mesh((1, 1), ("pod", "data"))
        g_const = {"w": jnp.full((4, 64), 0.003, jnp.float32)}  # tiny, quantises badly

        def step(e):
            def inner(e):
                return ef_compressed_psum(g_const, e, "pod", 1)

            return jax.shard_map(
                inner, mesh=mesh,
                in_specs=(jax.sharding.PartitionSpec(),),
                out_specs=(jax.sharding.PartitionSpec(),) * 2,
                check_vma=False,
            )(e)

        e = init_ef_state(g_const)
        sent_sum = jnp.zeros((4, 64))
        n = 50
        for _ in range(n):
            synced, e = step(e)
            sent_sum = sent_sum + synced["w"]
        mean_sent = sent_sum / n
        np.testing.assert_allclose(
            np.asarray(mean_sent), 0.003, rtol=0.02
        )


@pytest.mark.slow
class TestCompressedDPTraining:
    @needs_shard_map
    def test_tracks_exact_on_2x2_mesh(self, tmp_path):
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, sys.argv[1])
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_smoke_config
            from repro.models.registry import build_model
            from repro.optim import make_optimizer
            from repro.training.dp_step import init_dp_state, make_dp_train_step

            mesh = jax.make_mesh((2, 2), ("pod", "data"))
            cfg = get_smoke_config("codeqwen1.5-7b")
            model = build_model(cfg)
            opt = make_optimizer("adamw", lr=1e-3)
            rng = np.random.default_rng(0)
            batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
            batch["labels"] = batch["tokens"]
            out = {}
            for compress in (False, True):
                step = jax.jit(make_dp_train_step(model, opt, mesh, compress=compress))
                with mesh:
                    state = init_dp_state(model, opt, jax.random.PRNGKey(0), compress=compress)
                    for _ in range(6):
                        state, m = step(state, batch)
                out[compress] = float(m["loss"])
            diff = abs(out[True] - out[False])
            assert out[True] < 6.0, out
            assert diff < 0.05, (out, diff)
            print(f"OK exact={out[False]:.4f} compressed={out[True]:.4f} diff={diff:.5f}")
        """)
        proc = subprocess.run(
            [sys.executable, "-c", script, str(SRC)],
            capture_output=True, text=True, timeout=540,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "OK" in proc.stdout
