"""Stress test: a simulated day of jobs through the full federated stack.

Drives hourly cohorts through SubmitEngine → Placer → FederatedBackend →
SimCluster members → EventBus → EventCollector → HistoryStore and asserts
the invariants that must hold at any scale:

* **conservation** — every submitted job appears exactly once in the
  federated queue, exactly once in the archive, and exactly once in the
  report totals; nothing lost, nothing double-counted;
* **incremental backlog == fresh snapshot** — the event-driven
  BacklogTracker's per-member backlog matches a from-scratch queue walk
  at every reconciliation point (drift is identically 0.0: all
  contributions are integral cpu-seconds, so summation order is
  irrelevant even in floats);
* **bounded wall-clock** — the run must finish inside a generous budget,
  so a reintroduced O(n²) path (per-job snapshots, full-archive rescans)
  fails loudly instead of just getting slower.

The default (smoke) size keeps the tier-1 suite fast; the full 100k-job
day runs under ``-m slow`` with ``NBI_STRESS_FULL=1`` (the benchmark
suite exercises the same path at full scale on every publish).
"""

from __future__ import annotations

import os
import time
from datetime import datetime, timedelta

import pytest

from repro.accounting import EnergyModel, EventCollector, HistoryStore, report_dict
from repro.core import (
    ClusterHandle,
    ClusterRegistry,
    EcoScheduler,
    FederatedBackend,
    Job,
    Opts,
    Placer,
    SimCluster,
    SimNode,
    SubmitEngine,
)
from repro.core.eco import CarbonTrace

T0 = datetime(2026, 3, 18, 0, 0, 0)  # Wednesday, midnight

MEMBER_SPECS = [
    ("coal", 600.0, 8, 64),
    ("gas", 350.0, 4, 32),
    ("wind", 80.0, 6, 64),
    ("hydro", 40.0, 4, 48),
]

_WINDOWS = dict(
    weekday_windows=[(0, 360)], weekend_windows=[(0, 420), (660, 960)],
    peak_hours=[(1020, 1200)], horizon_days=14, min_delay_s=0,
)


def make_federation() -> FederatedBackend:
    handles = []
    for name, gco2, nodes, cpus in MEMBER_SPECS:
        trace = CarbonTrace([gco2] * 168)
        handles.append(ClusterHandle(
            name=name, kind="sim",
            backend=SimCluster(
                nodes=[SimNode(f"{name}-n{i:02d}", cpus=cpus, memory_mb=262144)
                       for i in range(nodes)],
                now=T0, default_user="stress", name=name,
            ),
            carbon_trace=trace,
            scheduler=EcoScheduler(carbon_trace=trace, **_WINDOWS),
            nodes=nodes, cpus_per_node=cpus,
        ))
    return FederatedBackend(ClusterRegistry(handles))


def cohort(hour: int, n: int) -> "list[Job]":
    return [
        Job(
            name=f"day-{hour:02d}-{i}",
            command=f"echo {i}",
            opts=Opts(threads=1 + (i % 4), memory_mb=2048,
                      time_s=1800 * (1 + i % 3)),
            sim_duration_s=300 + (i % 7) * 120,
        )
        for i in range(n)
    ]


def snapshot_backlogs(fed: FederatedBackend) -> dict:
    """A from-scratch queue walk per member: the tracker's ground truth."""
    probe = Placer(fed.registry, predictor=fed.placer.predictor)
    return {h.name: probe._snapshot_backlog(h) for h in fed.registry}


def run_day(total_jobs: int, *, wall_budget_s: float, tmp_path) -> dict:
    fed = make_federation()
    engine = SubmitEngine(fed, eco=True, coalesce=False, now=T0)
    store = HistoryStore(tmp_path / "day.jsonl")
    model = EnergyModel(
        cluster_traces={n: CarbonTrace([g] * 168) for n, g, _, _ in MEMBER_SPECS},
        default_cluster=MEMBER_SPECS[0][0],
    )
    coll = EventCollector(fed, store, model, flush_every=512).attach(fed.bus)

    per_hour = total_jobs // 24
    submitted: "list[str]" = []
    t_start = time.perf_counter()
    for hour in range(24):
        n = per_hour + (total_jobs % 24 if hour == 23 else 0)
        result = engine.submit_many(cohort(hour, n))
        submitted.extend(result.ids)
        fed.advance(3600)
        # reconciliation point: the incremental backlog must equal a
        # fresh snapshot bit-for-bit, and the tracker must agree it drifted
        # by exactly nothing
        fresh = snapshot_backlogs(fed)
        for name, backlog in fresh.items():
            assert fed.tracker.backlog_cpu_s(name) == backlog, (hour, name)
        drift = fed.tracker.reconcile()
        assert all(v == 0.0 for v in drift.values()), (hour, drift)
    fed.run_until_idle(max_days=30)
    coll.detach()
    wall = time.perf_counter() - t_start

    assert fed.tracker.max_drift_cpu_s == 0.0
    # drained: every member backlog is zero, incrementally and freshly
    for name, backlog in snapshot_backlogs(fed).items():
        assert backlog == 0.0
        assert fed.tracker.backlog_cpu_s(name) == 0.0

    # conservation: submitted == queue == archive == report
    assert len(submitted) == total_jobs
    assert len(set(submitted)) == total_jobs
    archived_ids = store.ids()
    assert len(archived_ids) == total_jobs
    assert archived_ids == set(submitted)
    rep = report_dict(store.records(), by="cluster")
    assert rep["total"]["jobs"] == total_jobs
    assert sum(g["jobs"] for g in rep["groups"]) == total_jobs
    # every record landed on a real member exactly once
    assert {g["key"] for g in rep["groups"]} <= {n for n, *_ in MEMBER_SPECS}

    assert wall < wall_budget_s, (
        f"simulated day of {total_jobs} jobs took {wall:.1f}s "
        f"(budget {wall_budget_s}s) — an O(n²) path crept back in"
    )
    return {"wall_s": wall, "report": rep}


class TestSimulatedDay:
    def test_smoke_day(self, tmp_path):
        """Tier-1 sized: the same invariants as the full day, in seconds."""
        run_day(1200, wall_budget_s=120.0, tmp_path=tmp_path)

    @pytest.mark.slow
    @pytest.mark.skipif(
        not os.environ.get("NBI_STRESS_FULL"),
        reason="full 100k-job day: set NBI_STRESS_FULL=1 (and -m slow)",
    )
    def test_full_100k_day(self, tmp_path):
        total = int(os.environ.get("NBI_STRESS_JOBS", "100000"))
        run_day(total, wall_budget_s=1800.0, tmp_path=tmp_path)


class TestTrackerUnderChurn:
    def test_requeue_and_node_failure_keep_tracker_exact(self, tmp_path):
        """Node failures requeue/kill jobs mid-flight; the tracker follows
        through REQUEUED and NODE_FAIL events without drifting."""
        fed = make_federation()
        engine = SubmitEngine(fed, eco=False, coalesce=False, now=T0)
        engine.submit_many(cohort(0, 120))
        fed.advance(600)
        for name, *_ in MEMBER_SPECS[:2]:
            h = fed.registry.get(name)
            h.backend.fail_node(f"{name}-n00")
        fed.advance(1800)
        fed.registry.get("coal").backend.restore_node("coal-n00")
        fed.advance(600)
        for name, backlog in snapshot_backlogs(fed).items():
            assert fed.tracker.backlog_cpu_s(name) == backlog, name
        drift = fed.tracker.reconcile()
        assert all(v == 0.0 for v in drift.values()), drift
        fed.run_until_idle(max_days=30)
        assert all(v == 0.0 for v in snapshot_backlogs(fed).values())


def make_tiny_federation() -> FederatedBackend:
    """Capacity ≪ submission rate: one small node per member, so almost
    the whole workload sits PENDING with reason=Resources."""
    handles = []
    for name, gco2 in (("tiny-a", 300.0), ("tiny-b", 90.0)):
        trace = CarbonTrace([gco2] * 168)
        handles.append(ClusterHandle(
            name=name, kind="sim",
            backend=SimCluster(
                nodes=[SimNode(f"{name}-n00", cpus=4, memory_mb=65536)],
                now=T0, default_user="stress", name=name,
            ),
            carbon_trace=trace,
            scheduler=EcoScheduler(carbon_trace=trace, **_WINDOWS),
            nodes=1, cpus_per_node=4,
        ))
    return FederatedBackend(ClusterRegistry(handles))


class TestDeepPendingQueue:
    def test_blocked_pass_is_o_eligible(self, tmp_path):
        """Thousands of Resources-blocked jobs: the tracker stays exact,
        every span conserves through obs.trace, and the scheduler's work
        — measured by the sim_schedule_considered counter — scales with
        the *eligible* set (placements + pass overhead), not with
        O(pending × passes), which is what the pre-calendar full sweep
        cost (≈ millions of considerations for this workload)."""
        from repro.obs.trace import JobTracer

        total = 2400
        fed = make_tiny_federation()
        tracer = JobTracer().attach(fed.bus)
        engine = SubmitEngine(fed, eco=False, coalesce=False, now=T0)
        submitted: "list[str]" = []
        t_start = time.perf_counter()
        for wave in range(4):
            result = engine.submit_many(
                [Job(name=f"deep-{wave}-{i}", command="true",
                     opts=Opts(threads=1, memory_mb=1024, time_s=600),
                     sim_duration_s=60)
                 for i in range(total // 4)]
            )
            submitted.extend(result.ids)
            fed.advance(600)
            # depth check: the backlog really is thousands deep
            pending = sum(1 for row in fed.queue() if row["state"] == "PENDING")
            if wave == 3:
                assert pending > 1000, pending
            drift = fed.tracker.reconcile()
            assert all(v == 0.0 for v in drift.values()), (wave, drift)
        fed.run_until_idle(max_days=30)
        wall = time.perf_counter() - t_start
        tracer.detach()

        # exact span conservation: every submitted job opened exactly one
        # span and closed it with a terminal event
        assert len(submitted) == total
        assert tracer.finished == total
        assert not tracer.open
        assert fed.tracker.max_drift_cpu_s == 0.0

        # O(eligible): each job is considered when it places, plus a
        # bounded number of blocked considerations per pass (the
        # max-free-capacity early exit caps a blocked pass at O(1) once
        # the head requirement dominates). The old sweep re-examined the
        # full pending queue every pass: >> total × 8 for this shape.
        considered = sum(
            h.backend.sched_considered for h in fed.registry
        )
        passes = sum(h.backend.sched_passes for h in fed.registry)
        assert considered < total * 8, (considered, passes)
        assert wall < 60.0, f"deep backlog took {wall:.1f}s"
