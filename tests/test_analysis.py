"""HLO accounting + roofline: trip-count-aware parsing on real lowered HLO."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import V5E, roofline_report


def lowered_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


# Pre-existing seed failure: jax builds old enough to lack jax.shard_map
# also lower elementwise ops to HLO whose buffer traffic our parser (and
# XLA's own cost analysis) reports as zero. Keyed on the attribute so the
# mark lifts itself the moment the platform image ships a modern jax.
old_jax = pytest.mark.xfail(
    not hasattr(jax, "shard_map"),
    reason="seed failure: jax without jax.shard_map reports 0 HBM bytes "
           "for elementwise HLO",
    strict=False,
)


class TestHloAnalysis:
    def test_matmul_flops_counted(self):
        A = jnp.zeros((128, 256), jnp.float32)
        B = jnp.zeros((256, 64), jnp.float32)
        hlo = lowered_text(lambda a, b: a @ b, A, B)
        st = analyze_hlo(hlo)
        want = 2 * 128 * 256 * 64
        # CPU XLA may route tiny matmuls to a custom-call we can't see into;
        # accept exact count or an explicit uncounted note.
        assert st.flops == want or any("uncounted" in n for n in st.notes)

    def test_scan_trip_count_multiplies(self):
        """FLOPs of a scanned body must scale with the trip count — the exact
        failure mode of Compiled.cost_analysis this module exists to fix."""
        W = jnp.eye(64, dtype=jnp.float32)

        def run(n):
            def f(x):
                def body(h, _):
                    return jnp.tanh(h @ W), None

                h, _ = jax.lax.scan(body, x, None, length=n)
                return h

            return analyze_hlo(lowered_text(f, jnp.ones((64, 64))))

        s8, s32 = run(8), run(32)
        assert s8.while_trip_counts and max(s8.while_trip_counts) == 8
        assert s32.while_trip_counts and max(s32.while_trip_counts) == 32
        if s8.flops > 0:
            assert s32.flops == pytest.approx(4 * s8.flops, rel=0.15)
        else:
            assert s32.hbm_bytes == pytest.approx(4 * s8.hbm_bytes, rel=0.3)

    @old_jax
    def test_bytes_counted_for_elementwise(self):
        x = jnp.ones((1024, 1024), jnp.float32)
        st = analyze_hlo(lowered_text(lambda a: a + 1.0, x))
        assert st.hbm_bytes >= 2 * 1024 * 1024 * 4 * 0.9  # read + write

    def test_no_collectives_on_single_device(self):
        x = jnp.ones((32, 32))
        st = analyze_hlo(lowered_text(lambda a: a @ a, x))
        assert st.collective_wire_bytes == 0
        assert st.collective_count == 0

    def test_dryrun_artifacts_have_collectives(self):
        """Every sharded dry-run cell must show nonzero wire bytes — the
        partitioner's collectives are visible to the parser."""
        import glob, json

        files = sorted(glob.glob("results/dryrun/*train_4k__single.json"))
        if not files:
            pytest.skip("dry-run artifacts not present")
        for f in files:
            rec = json.load(open(f))
            if rec.get("status") != "ok":
                continue
            assert rec["collective_wire_bytes_per_device"] > 0, f
            assert rec["hlo_flops_per_device"] > 0, f


class TestRoofline:
    def test_terms_and_bottleneck(self):
        rep = roofline_report(
            per_device_flops=197e12,       # exactly 1 second of compute
            per_device_hbm_bytes=819e9 / 2,  # 0.5 s of memory
            per_device_wire_bytes=50e9 / 4,  # 0.25 s of collectives
            chips=256,
            model_flops=0.5 * 197e12 * 256,
            tokens=1e6,
        )
        assert rep["compute_s"] == pytest.approx(1.0)
        assert rep["memory_s"] == pytest.approx(0.5)
        assert rep["collective_s"] == pytest.approx(0.25)
        assert rep["bottleneck"] == "compute"
        assert rep["roofline_fraction_mfu"] == pytest.approx(0.5)
        assert rep["tokens_per_s_lb"] == pytest.approx(1e6)

    def test_memory_bound_case(self):
        rep = roofline_report(
            per_device_flops=1e12,
            per_device_hbm_bytes=819e9,  # 1 s — dominates
            per_device_wire_bytes=0,
            chips=1,
            model_flops=1e12,
            tokens=1,
        )
        assert rep["bottleneck"] == "memory"
        assert rep["step_time_lb_s"] == pytest.approx(1.0)

    def test_v5e_constants(self):
        assert V5E.peak_flops == 197e12
        assert V5E.hbm_bw == 819e9
        assert V5E.link_bw == 50e9
